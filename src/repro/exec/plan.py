"""ExecPlan: one resolved backend per op slot, chosen once per config.

`resolve_plan(model_cfg, exec_cfg)` turns the declarative `ExecConfig`
(mode / softmax_mode / fidelity / fused_attention / op_overrides) into an
`ExecPlan`: for every `OP_SLOTS` entry, a preference chain of backend names
is built, capability predicates are evaluated, and the first supported
backend wins. Unsupported requests **degrade, never raise** — each degrade
is recorded as a structured `Degrade` (slot, requested, chosen, reason) on
the plan, and `plan.explain()` renders the whole table. A one-time
RuntimeWarning is kept for the fused-attention degrade (back-compat with
the pre-plan `_resolve_fused` behavior).

The model stack calls ``plan.attention_decode(...)`` / ``plan.matmul(...)``
etc. instead of branching on ``exec_cfg.mode`` — `models/` and `serve/`
contain no mode conditionals; registering a new backend (a GQA-native
decode kernel, a TPU-tuned block variant, a new accelerator) is one
`repro.exec.registry.register` call plus, optionally, a preference-chain
entry here.

Resolution is pure and cached: the same (ModelConfig, ExecConfig) pair
always resolves to the same plan object, so per-layer `as_plan` calls are
free and jit closures share one plan.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Optional

from repro.configs.base import ExecConfig, ModelConfig

from .registry import OP_SLOTS, BackendSpec, get_backend, list_backends

__all__ = ["ExecPlan", "ResolvedOp", "Degrade", "resolve_plan", "as_plan",
           "layer_plan", "reset_plan_cache"]

_DEGRADE_WARNED: set = set()  # one-time fused-attention degrade warnings


@dataclasses.dataclass(frozen=True)
class Degrade:
    """Structured record of one resolution downgrade."""

    slot: str
    requested: str
    chosen: str
    reason: str


@dataclasses.dataclass(frozen=True)
class ResolvedOp:
    slot: str
    backend: str          # chosen backend name
    requested: str        # head of the preference chain (what config asked)
    reason: Optional[str]  # why requested != backend (None when equal)
    spec: BackendSpec = dataclasses.field(compare=False, repr=False)


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """Resolved dispatch table: the single operator-dispatch API.

    Layers call the slot methods below; each forwards to the resolved
    backend impl with the plan itself as first argument, so backends read
    quantization knobs from ``plan.exec_cfg`` and perf knobs from
    ``plan.model_cfg`` — no more bare ``ExecConfig(mode="raceit")``
    reconstructions dropping the caller's bit-width settings.
    """

    model_cfg: ModelConfig
    exec_cfg: ExecConfig
    ops: tuple[ResolvedOp, ...]
    degrades: tuple[Degrade, ...] = ()

    # ------------------------------------------------------------ accessors
    @functools.cached_property
    def _by_slot(self) -> dict:
        return {op.slot: op for op in self.ops}

    def op(self, slot: str) -> ResolvedOp:
        return self._by_slot[slot]

    def backend(self, slot: str) -> str:
        return self._by_slot[slot].backend

    # ------------------------------------------------------- slot dispatch
    def matmul(self, x, w, bias=None):
        """x (..., K) @ w (K, ...); w may be a resident `QuantizedWeight`."""
        return self.op("matmul").spec.impl(self, x, w, bias)

    def activation(self, x, name=None):
        """Pointwise nonlinearity. ``name`` comes from the call site's
        ModelConfig (sub-stacks may run a replaced config); None falls back
        to the plan's model_cfg."""
        return self.op("activation").spec.impl(self, x, name)

    def softmax(self, logits, axis=-1):
        return self.op("softmax").spec.impl(self, logits, axis)

    def attention_prefill(self, q, k, v, *, scale, q_offset, kind, window,
                          chunk, probs_dtype=None, pad_lens=None):
        """Full/prefill attention. q (B,Sq,H,hd) flat heads; k/v (B,Sk,KV,hd).

        ``kind`` in ("cross", "bidir", "local", "causal") names the mask
        structure; it comes from the *call site's* ModelConfig (encoder
        sub-stacks pass a replaced config), as do ``window`` and
        ``probs_dtype`` (the float paths' p-matrix dtype). ``pad_lens``
        (B,) int32 marks per-row left-pad key prefixes that must be masked
        on top of the structural mask (batched-serving buckets).
        """
        return self.op("attention_prefill").spec.impl(
            self, q, k, v, scale=scale, q_offset=q_offset, kind=kind,
            window=window, chunk=chunk, probs_dtype=probs_dtype,
            pad_lens=pad_lens)

    def attention_decode(self, q, k, v, *, kv_len, scale, pad_valid=None,
                         block_table=None, page_size=None):
        """Decode step (Sq=1, or an Sq=C chunked-prefill step) vs a
        fixed-shape cache valid to ``kv_len``.

        ``pad_valid`` (B, Smax) bool further restricts each row's
        attendable slots inside the prefix (left-padded buckets); a
        (B, Sq, Smax) form carries the chunk step's per-query causal mask.
        ``block_table``/``page_size`` hand a block-paged KV pool to a
        paged-capable backend (`BackendSpec.paged`) — callers check the
        flag and gather pages to contiguous rows first for non-paged
        backends, so the kwargs are only forwarded when actually paged.
        """
        spec = self.op("attention_decode").spec
        if block_table is None:  # contiguous callers: unchanged interface
            return spec.impl(self, q, k, v, kv_len=kv_len, scale=scale,
                             pad_valid=pad_valid)
        return spec.impl(self, q, k, v, kv_len=kv_len, scale=scale,
                         pad_valid=pad_valid, block_table=block_table,
                         page_size=page_size)

    def dd_matmul(self, a_codes, b_codes):
        """Data-dependent matmul on int8 codes -> int32."""
        return self.op("dd_matmul").spec.impl(self, a_codes, b_codes)

    def lm_head(self, x, w):
        return self.op("lm_head").spec.impl(self, x, w)

    # ------------------------------------------------------------- explain
    def explain(self) -> str:
        """Human-readable slot -> backend table with degrade reasons.

        Renders every resolved slot *and* every plan-level degrade that has
        no slot row — an override naming an unknown slot, or an unknown
        execution mode — so a typo'd ``--exec-plan`` pin is visible in the
        startup table instead of silently ignored.
        """
        lines = [f"ExecPlan(mode={self.exec_cfg.mode!r}, "
                 f"softmax={self.exec_cfg.softmax_mode!r}, "
                 f"fidelity={self.exec_cfg.matmul_fidelity!r})"]
        width = max(len(s) for s in OP_SLOTS)
        for op in self.ops:
            line = f"  {op.slot:<{width}} -> {op.backend}"
            if op.reason is not None:
                line += f"  (requested {op.requested}: {op.reason})"
            if op.spec.notes:
                line += f"  [{op.spec.notes}]"
            lines.append(line)
        slots = {op.slot for op in self.ops}
        for d in self.degrades:
            if d.slot not in slots:  # unknown slot / unknown mode records
                lines.append(f"  ! {d.slot} -> {d.chosen or '(dropped)'}  "
                             f"(requested {d.requested}: {d.reason})")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# resolution policy
# ---------------------------------------------------------------------------

# the digital baseline per slot — also the last-resort landing spot when a
# whole preference chain is unsupported (dd_matmul's baseline is the exact
# integer matmul: there is no float form of a matmul on int8 codes)
_BASELINE = {slot: ("int",) if slot == "dd_matmul" else ("digital",)
             for slot in OP_SLOTS}


def _default_chain(slot: str, exec_cfg: ExecConfig) -> tuple[str, ...]:
    """Preference order for a slot under this ExecConfig (head = requested)."""
    if exec_cfg.mode != "raceit":  # digital baseline (and unknown modes,
        return _BASELINE[slot]     # which degrade below with a reason)
    noisy = exec_cfg.noise is not None
    fused_first = ("raceit_fused", "raceit_staged", "digital")
    staged_first = ("raceit_staged", "digital")
    if noisy:
        # device-noise injection rides the staged path: the noisy backends
        # head the chains, and a fused_attention=True request keeps the
        # fused names at the head so the degrade (the fused kernels model
        # ideal devices) is *recorded* on the plan — plus the one-time
        # warning below, via the existing machinery.
        staged_first = ("raceit_noisy_staged",) + staged_first
        fused_first = ("raceit_fused", "raceit_noisy_staged",
                       "raceit_staged", "digital")
    # decode prefers the per-row GQA-native kernel: per-request kv_len
    # vectors (slot-level continuous batching) decode each row at its own
    # fill level, and scalar-kv_len callers pass through unchanged. The
    # GQA predicates accept only configs with KV-head sharing
    # (n_kv_heads < n_heads), so MHA configs degrade within the fused
    # family to the per-row flat kernel with the reason recorded — same
    # dataflow there, nothing to warn about.
    # ... and, ahead of both row families, their paged twins: the paged
    # backends serve contiguous callers unchanged (block_table=None
    # delegates to the same row/flat adapters) and additionally accept the
    # block-paged KV pool of `repro.serve.continuous`'s paged mode, so
    # resolving them by default costs nothing and makes every serving
    # config paged-capable without an override.
    gqa_first = ("raceit_gqa_paged", "raceit_gqa_rows", "raceit_gqa_native",
                 "raceit_fused_paged", "raceit_fused_rows") + fused_first
    # a model-axis mesh on the config puts the tensor-parallel family at
    # the head of the attention chains: the TP predicates are structural
    # (model_size > 1, n_kv_heads % model_size == 0, fused support), so a
    # 1-device mesh — or a non-dividing head count — degrades to exactly
    # the single-device chain below, recorded on the plan, never raised.
    if getattr(exec_cfg.mesh, "model_size", 1) > 1:
        fused_first = ("raceit_fused_tp",) + fused_first
        gqa_first = ("raceit_gqa_tp", "raceit_fused_tp") + gqa_first
    return {
        "matmul": (("raceit_noisy_int", "raceit_int") if noisy
                   else ("raceit_int",)),
        "activation": (("raceit_noisy_lut", "raceit_lut") if noisy
                       else ("raceit_lut",)),
        "softmax": (("raceit_noisy_acam", "raceit_acam") if noisy
                    else ("raceit_acam",)),
        "dd_matmul": (("acam", "int") if exec_cfg.matmul_fidelity == "acam"
                      else ("int",)),
        "attention_prefill": (fused_first if exec_cfg.fused_attention
                              else staged_first),
        "attention_decode": (gqa_first if exec_cfg.fused_attention
                             else staged_first),
        # the lm head stays full-precision by default even in raceit mode
        # (resident int8 weights still take the quantized path inside the
        # backend); override lm_head=raceit_q8 to quantize it like any
        # other crossbar matmul
        "lm_head": ("digital",),
    }[slot]


def _ensure_backends_loaded() -> None:
    # backend impls live next to the math they wrap (repro.exec.backends
    # imports models.layers); import lazily to avoid a load-time cycle
    from . import backends  # noqa: F401


@functools.lru_cache(maxsize=None)
def resolve_plan(model_cfg: ModelConfig,
                 exec_cfg: ExecConfig = ExecConfig()) -> ExecPlan:
    """Pick one backend per op slot for this (model, execution) config.

    Policy: per slot, start from the ``exec_cfg.op_overrides`` entry when
    present, then the mode's default preference chain; the first backend
    whose capability predicate accepts the config wins. Every skipped
    preference is recorded as a `Degrade`; nothing raises — an impossible
    request serves the best supported backend and says so in
    ``plan.explain()``.
    """
    _ensure_backends_loaded()
    overrides = dict(exec_cfg.op_overrides)
    ops, degrades = [], []
    if exec_cfg.mode not in ("digital", "raceit"):
        degrades.append(Degrade("mode", exec_cfg.mode, "digital",
                                f"unknown mode {exec_cfg.mode!r}; "
                                f"serving the digital baseline"))
    for slot in OP_SLOTS:
        chain = _default_chain(slot, exec_cfg)
        if slot in overrides:
            ov = overrides.pop(slot)
            chain = (ov,) + tuple(n for n in chain if n != ov)
        requested = chain[0]
        chosen: Optional[BackendSpec] = None
        reason: Optional[str] = None
        for name in chain:
            spec = get_backend(slot, name)
            if spec is None:
                why = (f"no backend {name!r} registered for {slot!r} "
                       f"(have: {sorted(list_backends(slot))})")
            else:
                why = spec.supported(model_cfg, exec_cfg)
            if why is None and spec is not None:
                chosen = spec
                break
            degrades.append(Degrade(slot, name, "", why))
            if name == requested:
                reason = why
        if chosen is None:  # last resort: the slot's baseline always exists
            chosen = get_backend(slot, _BASELINE[slot][0])
            assert chosen is not None, \
                f"slot {slot!r} has no {_BASELINE[slot][0]!r} backend"
        # patch the degrade records with what was actually chosen
        degrades = [dataclasses.replace(d, chosen=chosen.name)
                    if d.slot == slot and not d.chosen else d
                    for d in degrades]
        ops.append(ResolvedOp(slot=slot, backend=chosen.name,
                              requested=requested,
                              reason=None if chosen.name == requested
                              else reason, spec=chosen))
    for slot in overrides:  # overrides naming unknown slots: record, not raise
        degrades.append(Degrade(slot, overrides[slot], "",
                                f"unknown op slot {slot!r}; slots are "
                                f"{OP_SLOTS}"))
    plan = ExecPlan(model_cfg=model_cfg, exec_cfg=exec_cfg, ops=tuple(ops),
                    degrades=tuple(degrades))
    _warn_fused_degrades(plan)
    return plan


_FUSED_FAMILY = ("raceit_fused", "raceit_gqa_native",
                 "raceit_fused_rows", "raceit_gqa_rows",
                 "raceit_fused_paged", "raceit_gqa_paged",
                 "raceit_fused_tp", "raceit_gqa_tp")


def _warn_fused_degrades(plan: ExecPlan) -> None:
    """Back-compat one-time warning when fused attention degrades.

    Warns only when a fused-family request landed *outside* the family —
    the GQA-native -> flat-fused step for MHA configs is a layout choice,
    not a lost kernel, and stays silent (the plan records the reason).
    """
    for op in plan.ops:
        if (op.slot.startswith("attention") and op.requested in _FUSED_FAMILY
                and op.backend not in _FUSED_FAMILY and op.reason
                and op.reason not in _DEGRADE_WARNED):
            _DEGRADE_WARNED.add(op.reason)
            warnings.warn(
                f"fused_attention=True requested but unsupported: "
                f"{op.reason}; falling back to the staged attention path",
                RuntimeWarning, stacklevel=3)


def as_plan(model_cfg: ModelConfig, exec_cfg) -> ExecPlan:
    """Normalize an ExecConfig-or-ExecPlan to a resolved plan (cached)."""
    if isinstance(exec_cfg, ExecPlan):
        return exec_cfg
    return resolve_plan(model_cfg, exec_cfg)


def layer_plan(plan: ExecPlan, mixer_kind: str) -> ExecPlan:
    """The per-layer plan for a mixer kind (`ExecConfig.layer_overrides`).

    Merges the kind's pins on top of the plan's ``op_overrides`` (pins win)
    and re-resolves — `resolve_plan` is lru-cached, so every layer of a
    kind shares one plan object and the per-layer call is a dict lookup.
    With no pins for the kind, the incoming plan is returned as-is: the
    default path allocates nothing. The standard recipe for mixed
    local/global stacks — staged attention on sliding-window "attn_local"
    layers, fused on global "attn" — is one config:

        ExecConfig.serving(layer_overrides=(("attn_local",
            (("attention_prefill", "raceit_staged"),
             ("attention_decode", "raceit_staged"))),))
    """
    pins = dict(plan.exec_cfg.layer_overrides).get(mixer_kind)
    if not pins:
        return plan
    merged = dict(plan.exec_cfg.op_overrides)
    merged.update(dict(pins))
    ec = dataclasses.replace(plan.exec_cfg,
                             op_overrides=tuple(sorted(merged.items())))
    return resolve_plan(plan.model_cfg, ec)


def reset_plan_cache() -> None:
    """Testing hook: drop the resolution cache and the warned-reason set."""
    resolve_plan.cache_clear()
    _DEGRADE_WARNED.clear()
