"""RaceOp registry: named backend implementations for every paper operator.

The paper's headline claim is *reconfigurability* — RACE can run arbitrary
computations, so adapting to new DNN architectures is a software mapping
problem, not a hardware one. This module is the software side of that
claim: each operator the model stack dispatches (`OP_SLOTS`) has one or
more named backends registered against it, each with a capability
predicate, and `repro.exec.plan.resolve_plan` picks exactly one per slot
for a given (ModelConfig, ExecConfig).

Adding a backend is one registration, not another ``if`` ladder::

    @register("attention_decode", "raceit_gqa_native",
              supported=lambda mcfg, ecfg: None if mcfg.n_kv_heads < mcfg.n_heads
                        else "no GQA grouping to exploit")
    def _gqa_decode(plan, q, k, v, kv_len, scale):
        ...

The registry holds *implementations*; policy (which backend a config
prefers, degrade order, override surface) lives in `repro.exec.plan`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

__all__ = ["OP_SLOTS", "BackendSpec", "register", "get_backend",
           "list_backends"]

# the dispatchable operator slots of the RACE-IT model stack, one per
# paper operator the execution mode can re-map:
#   matmul            weight matmuls (QKV/FFN/SSM projections; crossbar DPE)
#   activation        pointwise nonlinearity (Compute-ACAM LUT lane)
#   softmax           standalone softmax rows (MoE router, staged decode)
#   attention_prefill full/prefill attention (Fig. 12 pipeline)
#   attention_decode  Sq=1 KV-cache decode step
#   dd_matmul         data-dependent matmul on int8 codes (q.K^T, probs.V)
#   lm_head           the unembedding projection
OP_SLOTS = ("matmul", "activation", "softmax", "attention_prefill",
            "attention_decode", "dd_matmul", "lm_head")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One registered implementation of an op slot.

    ``supported(model_cfg, exec_cfg)`` returns None when the backend can
    serve the config, else a human-readable reason string — the same
    convention as `repro.core.attention.fused_attention_supported`, which
    is exactly what the fused attention backends plug in here. ``notes``
    document runtime (shape-dependent) fallbacks the predicate cannot see.

    ``paged`` marks attention_decode backends that accept block-paged KV
    operands (``block_table``/``page_size`` kwargs — a page pool instead
    of contiguous per-slot cache rows). Callers holding a paged cache
    check it to decide between handing the pool straight to the backend
    and gathering pages back to the contiguous layout first
    (`models.layers.attention`), so pinning a non-paged backend under a
    paged serving cache degrades to a gather, recorded not raised.
    """

    slot: str
    name: str
    impl: Callable
    supported: Callable[[object, object], Optional[str]]
    notes: str = ""
    paged: bool = False


_BACKENDS: dict[str, dict[str, BackendSpec]] = {s: {} for s in OP_SLOTS}


def register(slot: str, name: str, *,
             supported: Optional[Callable] = None, notes: str = "",
             paged: bool = False):
    """Decorator: register ``impl`` as backend ``name`` for ``slot``.

    ``impl`` is called as ``impl(plan, *args, **kwargs)`` — the resolved
    `ExecPlan` comes first so backends read knobs (act_bits, softmax_mode,
    probs dtype, ...) from one place instead of threading them through
    every call site. ``paged=True`` marks attention_decode backends that
    take block-paged KV operands (see `BackendSpec.paged`).
    """
    if slot not in _BACKENDS:
        raise ValueError(f"unknown op slot {slot!r}; slots are {OP_SLOTS}")

    def deco(impl: Callable) -> Callable:
        _BACKENDS[slot][name] = BackendSpec(
            slot=slot, name=name, impl=impl,
            supported=supported or (lambda mcfg, ecfg: None), notes=notes,
            paged=paged)
        return impl

    return deco


def get_backend(slot: str, name: str) -> Optional[BackendSpec]:
    return _BACKENDS.get(slot, {}).get(name)


def list_backends(slot: Optional[str] = None) -> dict:
    """slot -> {name: BackendSpec} (or one slot's mapping).

    Forces the lazy backend import first: callers enumerating the registry
    (tests, plan error messages) must see the full population even when no
    plan has been resolved yet in the process.
    """
    from . import backends  # noqa: F401
    if slot is not None:
        return dict(_BACKENDS[slot])
    return {s: dict(b) for s, b in _BACKENDS.items()}
