"""The ``raceit_noisy_*`` backend family: device variation behind the plan.

Every backend here is its clean ``raceit_*`` counterpart evaluated on
*varied* devices, with the variation drawn from the frozen
`repro.hw.noise.NoiseConfig` riding on ``ExecConfig.noise``:

  slot              backend               injection sites
  ----------------  --------------------  ---------------------------------
  matmul            raceit_noisy_int      stored weight codes (conductance
                                          spread + stuck cells, ISAAC
                                          unsigned domain)
  activation        raceit_noisy_lut      ACAM LUT in/out codes (threshold
                                          jitter + readout noise)
  softmax           raceit_noisy_acam     the three ACAM stages of the
                                          Fig. 8 dataflow
  attention_prefill raceit_noisy_staged   q/k/v/prob codes + ACAM softmax,
                                          optional per-row faults
  attention_decode  raceit_noisy_staged   decode softmax + per-row faults

Determinism: every site derives its key as ``site_key(noise, tag, shape)``
— no ambient RNG, no key threading — so one (seed, NoiseConfig) pair
reproduces bit-identical noisy outputs across runs, and the draws
constant-fold under jit into a *static* per-executable fault map (a real
chip's variation does not re-roll between inferences).

Zero-noise contract: with all knobs at zero every helper below is a
Python-level no-op, so a ``NoiseConfig()`` plan is bit-identical to the
clean backends (tests/test_exec_noise.py enumerates the registry and
asserts it). The fused Pallas kernels model ideal devices; under an
active NoiseConfig they degrade here with the reason recorded on the
plan.

``fault_rate`` (zero in every preset) NaNs out whole batch rows in the
noisy attention backends — the hook the fail-safe serving path
(`repro.serve.continuous`) detects and retires per-slot.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops as acam_ops
from repro.core.ops import LOGIT_FMT
from repro.core.quant import quantize_tensor
from repro.core.softmax import noisy_acam_softmax
from repro.hw.noise import (fault_rows, jitter_codes, perturb_weight_codes,
                            site_key)
from repro.models.layers import QuantizedWeight, _attn_quantize

from .backends import (RACEIT_ATTENTION_MAX_KEYS, _SEQ_NOTE, _decode_combine,
                       _decode_mask_scores, _decode_scores, _decode_valid,
                       _mask_array, _prefill_digital, _resident_matmul)
from .registry import register

# int8 code-domain clip bounds for jittered operand codes (symmetric
# max-abs quantization emits [-127, 127]; the clip only has to contain it)
_I8_LO, _I8_HI = -128, 127


def _noise_supported(model_cfg, exec_cfg):
    if exec_cfg.noise is None:
        return ("no NoiseConfig on ExecConfig.noise (ideal devices) — the "
                "clean raceit_* backends are the same numerics without the "
                "injection plumbing")
    return None


# ---------------------------------------------------------------------------
# matmul — crossbar DPE lane on a device-varied array
# ---------------------------------------------------------------------------

@register("matmul", "raceit_noisy_int", supported=_noise_supported,
          notes="raceit_int on perturbed stored weights (conductance "
                "spread + stuck cells); bit-identical at zero noise")
def _matmul_noisy_int(plan, x, w, bias):
    nz = plan.exec_cfg.noise
    ec = plan.exec_cfg
    if isinstance(w, QuantizedWeight):
        # resident int8 crossbar weight: the codes ARE the programmed
        # conductances — perturb them, keep the calibration scale
        codes = perturb_weight_codes(
            w.codes, nz, site_key(nz, "matmul_resident", w.codes.shape),
            bits=8)
        return _resident_matmul(plan, x, QuantizedWeight(codes, w.scale,
                                                         w.shape), bias)
    k = w.shape[0]
    w2 = w.reshape(k, -1)
    xq = quantize_tensor(x.astype(jnp.float32), bits=ec.act_bits)
    wq = quantize_tensor(w2.astype(jnp.float32), bits=ec.weight_bits, axis=1)
    codes = perturb_weight_codes(wq.codes, nz,
                                 site_key(nz, "matmul_w", w2.shape),
                                 bits=ec.weight_bits)
    y32 = jax.lax.dot(xq.codes.reshape(-1, k).astype(jnp.int32),
                      codes.astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    y = y32.astype(jnp.float32) * (xq.scale * wq.scale)
    y = y.reshape(*x.shape[:-1], *w.shape[1:]).astype(x.dtype)
    if bias is not None:
        y = y + bias.reshape(w.shape[1:]).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# activation — Compute-ACAM LUT under threshold/readout noise
# ---------------------------------------------------------------------------

@register("activation", "raceit_noisy_lut", supported=_noise_supported,
          notes="raceit_lut through AcamFunction.apply_codes_noisy")
def _activation_noisy_lut(plan, x, name=None):
    nz = plan.exec_cfg.noise
    name = name or plan.model_cfg.activation
    op = acam_ops.get_op(name if name in ("gelu", "silu") else "gelu")
    xf = x.astype(jnp.float32)
    out = op.apply_codes_noisy(
        op.in_fmt.encode(xf),
        site_key(nz, f"activation_{op.name}", xf.shape),
        nz.acam_sigma, nz.readout_sigma)
    return op.out_fmt.decode(out).astype(x.dtype)


# ---------------------------------------------------------------------------
# softmax — Fig. 8 dataflow with noisy ACAM stages
# ---------------------------------------------------------------------------

@register("softmax", "raceit_noisy_acam", supported=_noise_supported,
          notes="raceit_acam with the three ACAM stages under variation "
                "(the CMOS adder lanes stay exact)")
def _softmax_noisy_acam(plan, logits, axis):
    nz = plan.exec_cfg.noise
    return noisy_acam_softmax(logits, axis=axis,
                              mode=plan.exec_cfg.softmax_mode, noise=nz,
                              key=site_key(nz, "softmax", logits.shape))


# ---------------------------------------------------------------------------
# attention — staged Fig. 12 pipeline on varied devices (+ row faults)
# ---------------------------------------------------------------------------

def _noisy_staged_attention(q, k, v, mask, scale, plan):
    """`layers._raceit_staged_attention` with ACAM threshold jitter on the
    quantized operand codes and the noisy Fig. 8 softmax. Stage-for-stage
    identical at zero sigma (every injection helper early-returns)."""
    nz = plan.exec_cfg.noise
    sig = nz.acam_sigma
    qq, kq, vq = _attn_quantize(q, k, v, scale)
    qc = jitter_codes(qq.codes, sig, site_key(nz, "attn_q", qq.codes.shape),
                      _I8_LO, _I8_HI)
    kc = jitter_codes(kq.codes, sig, site_key(nz, "attn_k", kq.codes.shape),
                      _I8_LO, _I8_HI)
    vc = jitter_codes(vq.codes, sig, site_key(nz, "attn_v", vq.codes.shape),
                      _I8_LO, _I8_HI)
    s32 = plan.dd_matmul(qc.transpose(0, 2, 1, 3),            # (B,H,Sq,hd)
                         kc.transpose(0, 2, 3, 1))            # (B,H,hd,Sk)
    logits = s32.astype(jnp.float32) * (qq.scale * kq.scale)
    logits = jnp.where(mask[:, None], logits, LOGIT_FMT.min_value)
    probs = noisy_acam_softmax(logits, axis=-1,
                               mode=plan.exec_cfg.softmax_mode, noise=nz,
                               key=site_key(nz, "attn_softmax", logits.shape))
    pq = quantize_tensor(probs, bits=8)
    pc = jitter_codes(pq.codes, sig, site_key(nz, "attn_p", pq.codes.shape),
                      _I8_LO, _I8_HI)
    o32 = plan.dd_matmul(pc,                                  # (B,H,Sq,Sk)
                         vc.transpose(0, 2, 1, 3))            # (B,H,Sk,hd)
    out = o32.astype(jnp.float32) * (pq.scale * vq.scale)
    return out.transpose(0, 2, 1, 3)                          # (B,Sq,H,hd)


def _inject_row_faults(out, nz, tag):
    # per-row catastrophic faults: NaN the whole row. The site key hangs
    # off (seed, tag, batch) alone, so the fail-safe tests can recompute
    # the exact fault map from the slot count without model dims.
    rows = fault_rows(nz, site_key(nz, tag, (out.shape[0],)), out.shape[0])
    if rows is None:
        return out
    return jnp.where(rows[:, None, None, None], jnp.nan, out)


@register("attention_prefill", "raceit_noisy_staged",
          supported=_noise_supported, notes=_SEQ_NOTE)
def _prefill_noisy_staged(plan, q, k, v, *, scale, q_offset, kind, window,
                          chunk, probs_dtype=None, pad_lens=None):
    nz = plan.exec_cfg.noise
    sk = k.shape[1]
    if sk > RACEIT_ATTENTION_MAX_KEYS:
        return _prefill_digital(plan, q, k, v, scale=scale, q_offset=q_offset,
                                kind=kind, window=window, chunk=chunk,
                                probs_dtype=probs_dtype, pad_lens=pad_lens)
    mask = _mask_array(kind, q.shape[0], q.shape[1], sk, q_offset, window,
                       pad_lens)
    out = _noisy_staged_attention(q, k, v, mask, scale, plan)
    return _inject_row_faults(out, nz, "prefill_fault")


@register("attention_decode", "raceit_noisy_staged",
          supported=_noise_supported,
          notes="float scores + noisy ACAM softmax; fully row-independent, "
                "so injected faults stay bitwise-confined to their row")
def _decode_noisy_staged(plan, q, k, v, *, kv_len, scale, pad_valid=None):
    nz = plan.exec_cfg.noise
    s = _decode_scores(q, k, k.shape[2], scale)
    valid = _decode_valid(k, kv_len, pad_valid)
    s = _decode_mask_scores(s, valid, LOGIT_FMT.min_value)
    pr = noisy_acam_softmax(s, axis=-1, mode=plan.exec_cfg.softmax_mode,
                            noise=nz, key=site_key(nz, "decode_softmax",
                                                   s.shape))
    out = _decode_combine(pr, v)
    return _inject_row_faults(out, nz, "decode_fault")
