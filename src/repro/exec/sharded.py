"""The ``raceit_*_tp`` backend family: tensor-parallel fused attention.

Multi-device serving resolves through the same `ExecPlan` machinery as
everything else: these backends register against the attention slots with
purely *structural* capability predicates (they read the declarative
`repro.dist.MeshSpec` on ``ExecConfig.mesh``, never device availability),
so plans resolve — and `plan_audit` exercises the catalog x mesh matrix —
on a one-device process, while actually *running* a resolved TP plan
materializes the concrete mesh via ``MeshSpec.build()``.

Sharding layout (the mesh ``"model"`` axis, ``ms`` shards):

  q heads     H  -> H/ms  contiguous chunks (q is kv-major: heads
                          ``[kvh*rep, (kvh+1)*rep)`` share KV head ``kvh``,
                          so an H-chunk boundary lands on a KV-group
                          boundary whenever ``KV % ms == 0`` — the
                          predicate's divisibility requirement)
  KV cache    KV -> KV/ms on the head axis of the contiguous buffer
                          (B, Smax, KV, hd) *and* of the paged pool
                          (n_pages, page_size, KV, hd); block tables,
                          kv_len vectors, and pad masks stay replicated
  output      H  -> H/ms  (the head axis again; the mixer's output
                          projection consumes it replicated)

Bitwise parity with the single-device chain is a two-collective protocol,
not an afterthought (tests/test_sharded_parity.py asserts it bit-for-bit):

1. quantizer scales are *globalized* — each shard computes its local
   ``max|x|`` and `jax.lax.pmax`-es it over the mesh axis before the
   shared scale formula (`repro.kernels.ops.tp_quantize_tensor` and
   friends); f32 max is order-free, so scales and codes match the
   unsharded quantizers bit-for-bit;
2. the kernel's grid-global PROB re-quantization max is globalized via
   the probe -> pmax -> exact flow (`repro.kernels.ops.tp_exact_call`):
   a probe call yields the shard-local cmax, pmax makes it global, and
   the exact call re-runs with ``cmax_floor`` seeded to the global so
   every shard re-quantizes with the same table the unsharded kernel
   would have used.

The probe call doubles the kernel work per shard; each shard holds
``1/ms`` of the heads, so the *total* work is ``2/ms`` of the
single-device call — a win for every real mesh (ms >= 2), and the
predicate refuses ms == 1 anyway (a 1-device mesh resolves to the same
single-device chain as ``mesh=None``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import shard_map
from repro.kernels import ops as kops

from .backends import (RACEIT_ATTENTION_MAX_KEYS, _fused_supported,
                       _mask_array, _prefill_digital)
from .registry import register

AXIS = "model"  # the mesh axis every TP backend shards over


def _tp_supported(model_cfg, exec_cfg):
    ms = getattr(exec_cfg.mesh, "model_size", 1)
    if ms <= 1:
        return ("no tensor-parallel mesh (ExecConfig.mesh has no 'model' "
                "axis of size > 1)")
    why = _fused_supported(model_cfg, exec_cfg)
    if why is not None:
        return why
    if model_cfg.n_kv_heads % ms:
        return (f"n_kv_heads={model_cfg.n_kv_heads} not divisible by the "
                f"mesh 'model' axis ({ms} shards) — KV-head chunks would "
                f"straddle shards")
    return None


def _gqa_tp_supported(model_cfg, exec_cfg):
    why = _tp_supported(model_cfg, exec_cfg)
    if why is not None:
        return why
    if model_cfg.n_kv_heads >= model_cfg.n_heads:
        return (f"n_kv_heads={model_cfg.n_kv_heads} == "
                f"n_heads={model_cfg.n_heads} (no KV-head sharing to "
                f"exploit; raceit_fused_tp is the same dataflow)")
    return None


def _shard(body, plan, operands, in_axes, out_axis):
    """Run ``body`` over the plan's mesh, operand i sharded on dim
    ``in_axes[i]`` of the "model" axis (None = fully replicated)."""
    mesh = plan.exec_cfg.mesh.build()
    specs = tuple(P() if ax is None else P(*([None] * ax + [AXIS]))
                  for ax in in_axes)
    out_spec = P(*([None] * out_axis + [AXIS]))
    return shard_map(body, mesh=mesh, in_specs=specs,
                     out_specs=out_spec)(*operands)


# ---------------------------------------------------------------------------
# attention_prefill
# ---------------------------------------------------------------------------

def _tp_fused_attention(q, k, v, mask, scale, plan, causal_offset=None):
    """`models.layers._raceit_fused_attention` sharded over heads.

    q (B, Sq, H, hd), k/v (B, Sk, KV, hd); ``mask`` (B, Sq, Sk) replicated
    (None with ``causal_offset`` takes the kernel's in-kernel causal mask,
    mirroring the single-device fast path).
    """
    mode = plan.exec_cfg.softmax_mode
    qs = q.astype(jnp.float32) * scale  # pre-fold outside the shard body

    def body(q, k, v, *rest):
        b, sq, h, hd = q.shape
        sk, kv = k.shape[1], k.shape[2]
        rep = h // kv
        qq = kops.tp_quantize_tensor(q, AXIS)
        kq = kops.tp_quantize_tensor(
            jnp.repeat(k.astype(jnp.float32), rep, axis=2), AXIS)
        vq = kops.tp_quantize_tensor(
            jnp.repeat(v.astype(jnp.float32), rep, axis=2), AXIS)
        mb = None
        if rest:
            mb = jnp.broadcast_to(rest[0][:, None],
                                  (b, h, sq, sk)).reshape(b * h, sq, sk)
        call = lambda floor: kops.acam_attention_codes(
            qq.codes.transpose(0, 2, 1, 3).reshape(b * h, sq, hd),
            kq.codes.transpose(0, 2, 1, 3).reshape(b * h, sk, hd),
            vq.codes.transpose(0, 2, 1, 3).reshape(b * h, sk, hd),
            qq.scale * kq.scale, mb,
            q_offset=causal_offset if causal_offset is not None else 0,
            causal=causal_offset is not None, mode=mode, cmax_floor=floor)
        out32, cmax = kops.tp_exact_call(call, AXIS)
        out = (out32.astype(jnp.float32)
               * (kops.prob_requant_scale(cmax) * vq.scale))
        return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)

    operands = [qs, k, v] + ([] if mask is None else [mask])
    in_axes = [2, 2, 2] + ([] if mask is None else [None])
    return _shard(body, plan, operands, in_axes, out_axis=2)


@register("attention_prefill", "raceit_fused_tp", supported=_tp_supported,
          notes="tensor-parallel fused prefill: heads sharded over the mesh "
                "'model' axis, quantizer scales and the PROB requant max "
                "globalized (pmax) — bit-identical to raceit_fused; falls "
                f"back to the digital path beyond "
                f"Sk={RACEIT_ATTENTION_MAX_KEYS}")
def _prefill_raceit_fused_tp(plan, q, k, v, *, scale, q_offset, kind, window,
                             chunk, probs_dtype=None, pad_lens=None):
    sk = k.shape[1]
    if sk > RACEIT_ATTENTION_MAX_KEYS:
        return _prefill_digital(plan, q, k, v, scale=scale, q_offset=q_offset,
                                kind=kind, window=window, chunk=chunk,
                                probs_dtype=probs_dtype, pad_lens=pad_lens)
    if kind == "causal" and pad_lens is None:
        return _tp_fused_attention(q, k, v, None, scale, plan,
                                   causal_offset=q_offset)
    mask = _mask_array(kind, q.shape[0], q.shape[1], sk, q_offset, window,
                       pad_lens)
    return _tp_fused_attention(q, k, v, mask, scale, plan)


# ---------------------------------------------------------------------------
# attention_decode (contiguous and paged caches, flat and GQA-native grids)
# ---------------------------------------------------------------------------

def _tp_fused_decode(q, k, v, kv_len, scale, plan, pad_valid=None):
    """`models.layers._raceit_fused_decode` sharded over heads.

    q (B, Sq, H, hd), k/v (B, Smax, KV, hd); kv_len and pad_valid stay
    replicated — lengths are per *request*, and every shard serves every
    request (for a slice of its heads).
    """
    mode = plan.exec_cfg.softmax_mode
    qs = q.astype(jnp.float32) * scale
    kvl = jnp.asarray(kv_len, jnp.int32)

    def body(q, k, v, kvl, *rest):
        b, sq, h, hd = q.shape
        smax, kv = k.shape[1], k.shape[2]
        rep = h // kv
        qq = kops.tp_quantize_tensor(q, AXIS)
        k_codes, k_scale = kops.tp_masked_prefix_quantize(
            k.astype(jnp.float32), kvl, AXIS, axis=1)
        v_codes, v_scale = kops.tp_masked_prefix_quantize(
            v.astype(jnp.float32), kvl, AXIS, axis=1)
        fold = lambda c: jnp.repeat(c, rep, axis=2).transpose(
            0, 2, 1, 3).reshape(b * h, smax, hd)
        mask = None
        if rest:
            pv = rest[0][:, None, :] if rest[0].ndim == 2 else rest[0]
            mask = jnp.broadcast_to(pv[:, None],
                                    (b, h, sq, smax)).reshape(b * h, sq, smax)
        kvl_g = kops.expand_row_lens(kvl, h)
        qc = qq.codes.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
        if sq == 1:
            call = lambda floor: kops.acam_attention_decode_codes(
                qc, fold(k_codes), fold(v_codes), qq.scale * k_scale, kvl_g,
                mask=mask, mode=mode, cmax_floor=floor)
        else:  # the chunked-prefill step, same delegate as the flat backend
            call = lambda floor: kops.acam_attention_codes(
                qc, fold(k_codes), fold(v_codes), qq.scale * k_scale, mask,
                kv_len=kvl_g, mode=mode, cmax_floor=floor)
        out32, cmax = kops.tp_exact_call(call, AXIS)
        out = (out32.astype(jnp.float32)
               * (kops.prob_requant_scale(cmax) * v_scale))
        return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)

    operands = [qs, k, v, kvl] + ([] if pad_valid is None else [pad_valid])
    in_axes = [2, 2, 2, None] + ([] if pad_valid is None else [None])
    return _shard(body, plan, operands, in_axes, out_axis=2)


def _tp_gqa_decode(q, k, v, kv_len, scale, plan, pad_valid=None):
    """`models.layers._raceit_gqa_decode` sharded over KV-head groups."""
    b, sq, h, hd = q.shape
    if sq > 1:  # chunk steps ride the flat grid, as on one device
        return _tp_fused_decode(q, k, v, kv_len, scale, plan,
                                pad_valid=pad_valid)
    mode = plan.exec_cfg.softmax_mode
    qs = q.astype(jnp.float32) * scale
    kvl = jnp.asarray(kv_len, jnp.int32)

    def body(q, k, v, kvl, *rest):
        b, sq, h, hd = q.shape
        smax, kv = k.shape[1], k.shape[2]
        rep = h // kv
        qq = kops.tp_quantize_tensor(q, AXIS)
        k_codes, k_scale = kops.tp_masked_prefix_quantize(
            k.astype(jnp.float32), kvl, AXIS, axis=1)
        v_codes, v_scale = kops.tp_masked_prefix_quantize(
            v.astype(jnp.float32), kvl, AXIS, axis=1)
        to_groups = lambda c: c.transpose(0, 2, 1, 3).reshape(b * kv, smax, hd)
        mask = None
        if rest:
            mask = jnp.broadcast_to(rest[0][:, None, None, :],
                                    (b, kv, rep, smax)).reshape(b * kv, rep,
                                                                smax)
        call = lambda floor: kops.acam_attention_decode_gqa_codes(
            qq.codes.reshape(b, h, hd).reshape(b, kv, rep, hd
                                               ).reshape(b * kv, rep, hd),
            to_groups(k_codes), to_groups(v_codes), qq.scale * k_scale,
            kops.expand_row_lens(kvl, kv), mask=mask, mode=mode,
            cmax_floor=floor)
        out32, cmax = kops.tp_exact_call(call, AXIS)
        return (out32.astype(jnp.float32)
                * (kops.prob_requant_scale(cmax) * v_scale)
                ).reshape(b, sq, h, hd)

    operands = [qs, k, v, kvl] + ([] if pad_valid is None else [pad_valid])
    in_axes = [2, 2, 2, None] + ([] if pad_valid is None else [None])
    return _shard(body, plan, operands, in_axes, out_axis=2)


def _tp_paged_decode(q, k_pool, v_pool, kv_len, scale, plan, pad_valid=None,
                     block_table=None, gqa=False):
    """`models.layers._raceit_paged_decode` sharded over the pool's KV axis.

    The page pool (n_pages, page_size, KV, hd) shards on its head axis;
    block tables and fill levels are replicated, so page routing — and the
    trash-page fence — is identical on every shard.
    """
    mode = plan.exec_cfg.softmax_mode
    b, sq, h, hd = q.shape
    qs = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,hd)
    kvl = jnp.asarray(kv_len, jnp.int32)
    bt = jnp.asarray(block_table, jnp.int32)
    mask0 = pad_valid
    if mask0 is not None and mask0.ndim == 2:  # (B, Smax) -> (B, Sq, Smax)
        mask0 = mask0[:, None, :]

    def body(q, k_pool, v_pool, kvl, bt, *rest):
        b, h, sq, hd = q.shape
        n_pages, ps, kv, _ = k_pool.shape
        rep = h // kv
        pv = kops.page_valid_lengths(bt, kvl, n_pages, ps)
        qq = kops.tp_quantize_tensor(q, AXIS)
        k_codes, k_scale = kops.tp_masked_page_quantize(
            k_pool.astype(jnp.float32), pv, AXIS)
        v_codes, v_scale = kops.tp_masked_page_quantize(
            v_pool.astype(jnp.float32), pv, AXIS)
        sk = bt.shape[1] * ps
        if gqa:
            to_rows = lambda c: c.transpose(0, 2, 1, 3).reshape(
                n_pages * kv, ps, hd)
            mask = None
            if rest:
                mask = jnp.broadcast_to(rest[0][:, None],
                                        (b, kv, rep, sk)).reshape(b * kv,
                                                                  rep, sk)
            call = lambda floor: kops.acam_attention_decode_gqa_codes(
                qq.codes.reshape(b, kv, rep, hd).reshape(b * kv, rep, hd),
                to_rows(k_codes), to_rows(v_codes), qq.scale * k_scale,
                kops.expand_row_lens(kvl, kv), mask=mask, mode=mode,
                block_table=bt, page_size=ps, groups_per_slot=kv,
                cmax_floor=floor)
        else:
            to_rows = lambda c: jnp.repeat(c, rep, axis=2).transpose(
                0, 2, 1, 3).reshape(n_pages * h, ps, hd)
            mask = None
            if rest:
                mask = jnp.broadcast_to(rest[0][:, None],
                                        (b, h, sq, sk)).reshape(b * h, sq, sk)
            call = lambda floor: kops.acam_attention_codes(
                qq.codes.reshape(b * h, sq, hd), to_rows(k_codes),
                to_rows(v_codes), qq.scale * k_scale, mask,
                kv_len=kops.expand_row_lens(kvl, h), mode=mode,
                block_table=bt, page_size=ps, groups_per_slot=h,
                cmax_floor=floor)
        out32, cmax = kops.tp_exact_call(call, AXIS)
        return (out32.astype(jnp.float32)
                * (kops.prob_requant_scale(cmax) * v_scale)
                ).reshape(b, h, sq, hd)

    operands = [qs, k_pool, v_pool, kvl, bt] \
        + ([] if mask0 is None else [mask0])
    in_axes = [1, 2, 2, None, None] + ([] if mask0 is None else [None])
    out = _shard(body, plan, operands, in_axes, out_axis=1)
    return out.transpose(0, 2, 1, 3)  # (B, Sq, H, hd)


@register("attention_decode", "raceit_fused_tp", supported=_tp_supported,
          paged=True,
          notes="tensor-parallel fused decode: KV cache (contiguous or "
                "paged pool) sharded over heads on the mesh 'model' axis; "
                "probe->pmax->exact requant keeps it bit-identical to the "
                "single-device chain")
def _decode_raceit_fused_tp(plan, q, k, v, *, kv_len, scale, pad_valid=None,
                            block_table=None, page_size=None):
    if block_table is not None:
        return _tp_paged_decode(q, k, v, kv_len, scale, plan,
                                pad_valid=pad_valid, block_table=block_table,
                                gqa=False)
    return _tp_fused_decode(q, k, v, kv_len, scale, plan, pad_valid=pad_valid)


@register("attention_decode", "raceit_gqa_tp", supported=_gqa_tp_supported,
          paged=True,
          notes="tensor-parallel GQA-native decode: each shard's KV-head "
                "groups stream their own pool stripe — the multi-device "
                "serving default for grouped-query configs")
def _decode_raceit_gqa_tp(plan, q, k, v, *, kv_len, scale, pad_valid=None,
                          block_table=None, page_size=None):
    if block_table is not None:
        return _tp_paged_decode(q, k, v, kv_len, scale, plan,
                                pad_valid=pad_valid, block_table=block_table,
                                gqa=q.shape[1] == 1)
    return _tp_gqa_decode(q, k, v, kv_len, scale, plan, pad_valid=pad_valid)
