"""Built-in backend registrations for every RaceOp slot.

Each backend is a thin adapter: the math lives with its owner
(`repro.models.layers` for the attention formulations and quantized
matmuls, `repro.core` for the staged numerics, `repro.kernels` for the
fused Pallas paths); this module binds those implementations to named
registry entries with capability predicates, so `resolve_plan` can pick
between them and `plan.explain()` can name what is running and why.

Naming convention: ``digital`` is the bf16/f32 baseline; ``raceit_*``
backends are the paper's analog-faithful paths (``raceit_staged`` = the
stage-by-stage XLA pipeline, ``raceit_fused`` = the streaming Pallas
kernel, ``raceit_int`` = exact-ADC int8 crossbar matmul, ``raceit_lut`` =
Compute-ACAM LUT activations, ``raceit_acam`` = the Fig. 8 softmax
dataflow). The resident-`QuantizedWeight` form is handled inside the
matmul/lm_head backends (it is a property of the *weight*, not the
config), always with the plan's ``act_bits`` — never a reconstructed
default ExecConfig.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops as acam_ops
from repro.core.attention import dd_matmul_codes, fused_attention_supported
from repro.core.ops import LOGIT_FMT
from repro.core.quant import quantize_tensor
from repro.core.softmax import acam_softmax
from repro.models import layers
from repro.models.layers import NEG_INF, QuantizedWeight

from .registry import register

# the staged/fused raceit attention formulations materialize (or stream)
# O(Sq*Sk) work per head; past this key length the model stack has always
# degraded to the chunked float path (a runtime shape rule, so it lives in
# the backend impls, not the config-level capability predicate)
RACEIT_ATTENTION_MAX_KEYS = 4096
_SEQ_NOTE = (f"falls back to the digital path beyond "
             f"Sk={RACEIT_ATTENTION_MAX_KEYS}")


def _fused_supported(model_cfg, exec_cfg):
    if exec_cfg.noise is not None:
        # the streaming Pallas kernels model ideal devices; device-noise
        # injection rides the staged raceit_noisy_* path, so a fused
        # request under an active NoiseConfig degrades with this reason
        # recorded on the plan (and the one-time warning)
        return ("device-noise injection active (ExecConfig.noise); fused "
                "kernels model ideal devices — noise rides the staged "
                "raceit_noisy_* path")
    return fused_attention_supported(fidelity=exec_cfg.matmul_fidelity,
                                     softmax_mode=exec_cfg.softmax_mode)


# ---------------------------------------------------------------------------
# matmul (weight matmuls: QKV / FFN / SSM projections — the crossbar DPE lane)
# ---------------------------------------------------------------------------

def _resident_matmul(plan, x, w: QuantizedWeight, bias):
    """Resident int8 crossbar weight: codes + per-column scale.

    Activation quantization uses the *plan's* ``act_bits`` — this is the
    path that previously rebuilt a bare ``ExecConfig(mode="raceit")`` in
    the lm head and silently dropped the caller's bit-width knobs.
    """
    k = w.codes.shape[0]
    xq = quantize_tensor(x.astype(jnp.float32), bits=plan.exec_cfg.act_bits)
    y32 = jax.lax.dot(xq.codes.reshape(-1, k).astype(jnp.int32),
                      w.codes.astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    y = y32.astype(jnp.float32) * (xq.scale * w.scale)
    y = y.reshape(*x.shape[:-1], *w.shape).astype(x.dtype)
    if bias is not None:
        y = y + bias.reshape(w.shape).astype(y.dtype)
    return y


@register("matmul", "digital")
def _matmul_digital(plan, x, w, bias):
    if isinstance(w, QuantizedWeight):
        return _resident_matmul(plan, x, w, bias)
    k = w.shape[0]
    w2 = w.reshape(k, -1)
    # preferred f32 materializes f32 outputs (and f32 TP collectives); the
    # MXU accumulates in f32 internally either way, so the default keeps
    # the boundary in compute dtype and halves collective bytes.
    pref = (jnp.float32 if plan.model_cfg.matmul_out_dtype == "f32"
            else x.dtype)
    y = jax.lax.dot_general(
        x, w2.astype(x.dtype), (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=pref).astype(x.dtype)
    y = y.reshape(*x.shape[:-1], *w.shape[1:])
    if bias is not None:
        y = y + bias.reshape(w.shape[1:]).astype(y.dtype)
    return y


@register("matmul", "raceit_int")
def _matmul_raceit_int(plan, x, w, bias):
    """Exact-ADC int8 crossbar matmul (equivalence proven vs core.crossbar)."""
    if isinstance(w, QuantizedWeight):
        return _resident_matmul(plan, x, w, bias)
    ec = plan.exec_cfg
    k = w.shape[0]
    w2 = w.reshape(k, -1)
    xq = quantize_tensor(x.astype(jnp.float32), bits=ec.act_bits)
    wq = quantize_tensor(w2.astype(jnp.float32), bits=ec.weight_bits, axis=1)
    y32 = jax.lax.dot(xq.codes.reshape(-1, k).astype(jnp.int32),
                      wq.codes.astype(jnp.int32),
                      preferred_element_type=jnp.int32)
    y = y32.astype(jnp.float32) * (xq.scale * wq.scale)
    y = y.reshape(*x.shape[:-1], *w.shape[1:]).astype(x.dtype)
    if bias is not None:
        y = y + bias.reshape(w.shape[1:]).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# activation (FFN nonlinearity)
# ---------------------------------------------------------------------------

@register("activation", "digital")
def _activation_digital(plan, x, name=None):
    name = name or plan.model_cfg.activation
    if name == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


@register("activation", "raceit_lut")
def _activation_raceit_lut(plan, x, name=None):
    """Compute-ACAM LUT activation (unlisted activations map to gelu)."""
    name = name or plan.model_cfg.activation
    op = acam_ops.get_op(name if name in ("gelu", "silu") else "gelu")
    return op(x.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# softmax (standalone rows: the MoE router, the staged decode scores)
# ---------------------------------------------------------------------------

@register("softmax", "digital")
def _softmax_digital(plan, logits, axis):
    return jax.nn.softmax(logits, axis=axis)


@register("softmax", "raceit_acam")
def _softmax_raceit_acam(plan, logits, axis):
    return acam_softmax(logits, axis=axis, mode=plan.exec_cfg.softmax_mode)


# ---------------------------------------------------------------------------
# dd_matmul (data-dependent matmuls on int8 codes: q.K^T, probs.V)
# ---------------------------------------------------------------------------

@register("dd_matmul", "int")
def _dd_matmul_int(plan, a_codes, b_codes):
    return dd_matmul_codes(a_codes, b_codes, fidelity="int")


@register("dd_matmul", "acam",
          notes="4-bit nibble-table multiplies; bit-identical to 'int', slow")
def _dd_matmul_acam(plan, a_codes, b_codes):
    return dd_matmul_codes(a_codes, b_codes, fidelity="acam")


# ---------------------------------------------------------------------------
# attention_prefill (full / prefill attention)
# ---------------------------------------------------------------------------
# Interface: impl(plan, q, k, v, *, scale, q_offset, kind, window, chunk,
#                 probs_dtype, pad_lens)
#   q (B, Sq, H, hd) flat heads; k/v (B, Sk, KV, hd); kind in
#   ("cross", "bidir", "local", "causal"); pad_lens (B,) int32 marks each
#   row's left-pad key prefix (batched-serving buckets) — those keys are
#   masked on top of the structural mask.
#
# The rule for ModelConfig-derived knobs: anything a sub-stack may *replace*
# (mask kind, window, probs dtype, activation name) is computed by the call
# site from ITS cfg and passed in — encoder sub-stacks run with a replaced
# ModelConfig the plan was not resolved against. ``plan.model_cfg`` is only
# read for knobs that are constant across sub-stacks by construction
# (matmul_out_dtype) and as a fallback when the call site passes None.

def _mask_fn(kind: str, sk: int, q_offset, window: int):
    if kind == "cross":
        return lambda qi, ki: jnp.ones((), bool)  # full cross attention
    if kind == "bidir":
        return lambda qi, ki: ki < sk + 0 * qi    # bidirectional
    if kind == "local":
        return lambda qi, ki: ((ki <= qi + q_offset)
                               & (ki > qi + q_offset - window))
    return lambda qi, ki: ki <= qi + q_offset     # causal


def _mask_array(kind, b, sq, sk, q_offset, window, pad_lens=None):
    msk = _mask_fn(kind, sk, q_offset, window)(
        jnp.arange(sq)[:, None], jnp.arange(sk)[None, :])
    msk = jnp.broadcast_to(msk, (b, sq, sk))
    if pad_lens is not None:  # left-pad keys do not exist for their row
        msk = msk & (jnp.arange(sk)[None, None, :] >= pad_lens[:, None, None])
    return msk


@register("attention_prefill", "digital")
def _prefill_digital(plan, q, k, v, *, scale, q_offset, kind, window, chunk,
                     probs_dtype=None, pad_lens=None):
    if probs_dtype is None:
        probs_dtype = layers._probs_dtype(plan.model_cfg)
    sq, sk = q.shape[1], k.shape[1]
    if (kind == "local" and sq == sk and sq % window == 0 and sq > window
            and pad_lens is None):
        # sliding-window layers, train & single-shot prefill: q-blocked
        # 2W-key attention instead of the masked-full path (the blocked
        # form has no per-row mask slot, so padded buckets take the
        # chunked path below)
        return layers._local_block_attention(q, k, v, window, scale,
                                             probs_dtype)
    mask_fn = _mask_fn(kind, sk, q_offset, window)
    return layers._chunked_attention(q, k, v, mask_fn, min(chunk, sk), scale,
                                     probs_dtype, pad_lens=pad_lens)


@register("attention_prefill", "raceit_staged", notes=_SEQ_NOTE)
def _prefill_raceit_staged(plan, q, k, v, *, scale, q_offset, kind, window,
                           chunk, probs_dtype=None, pad_lens=None):
    sk = k.shape[1]
    if sk > RACEIT_ATTENTION_MAX_KEYS:
        return _prefill_digital(plan, q, k, v, scale=scale, q_offset=q_offset,
                                kind=kind, window=window, chunk=chunk,
                                probs_dtype=probs_dtype, pad_lens=pad_lens)
    mask = _mask_array(kind, q.shape[0], q.shape[1], sk, q_offset, window,
                       pad_lens)
    return layers._raceit_staged_attention(q, k, v, mask, scale, plan)


@register("attention_prefill", "raceit_fused", supported=_fused_supported,
          notes=_SEQ_NOTE)
def _prefill_raceit_fused(plan, q, k, v, *, scale, q_offset, kind, window,
                          chunk, probs_dtype=None, pad_lens=None):
    sk = k.shape[1]
    if sk > RACEIT_ATTENTION_MAX_KEYS:
        return _prefill_digital(plan, q, k, v, scale=scale, q_offset=q_offset,
                                kind=kind, window=window, chunk=chunk,
                                probs_dtype=probs_dtype, pad_lens=pad_lens)
    if kind == "causal" and pad_lens is None:
        # plain causal: the kernel masks from block indices, so not even a
        # mask of score shape is ever built (padded buckets need the
        # per-row mask array)
        return layers._raceit_fused_attention(q, k, v, None, scale, plan,
                                              causal_offset=q_offset)
    mask = _mask_array(kind, q.shape[0], q.shape[1], sk, q_offset, window,
                       pad_lens)
    return layers._raceit_fused_attention(q, k, v, mask, scale, plan)


# ---------------------------------------------------------------------------
# attention_decode (Sq=1 against the KV cache's valid prefix)
# ---------------------------------------------------------------------------
# Interface: impl(plan, q, k, v, *, kv_len, scale, pad_valid) -> (B, 1, H, hd)
#   q (B, 1, H, hd) flat heads; k/v (B, Smax, KV, hd) fixed-shape buffers;
#   kv_len is a () scalar (one shared fill level) or a (B,) vector of
#   per-request fill levels (slot-level continuous batching; 0 = empty
#   slot, a dead row); pad_valid (B, Smax) bool restricts each row's
#   attendable slots inside the valid prefix (left-padded batch buckets),
#   None = all attendable.

def _decode_scores(q, k, kv_heads, scale):
    """Float decode scores in grouped-query layout: (B, KV, G, 1, Smax)."""
    qg = layers._split_gqa(q, kv_heads)  # (B, 1, KV, G, hd)
    return jnp.einsum("bqkgd,bckd->bkgqc", qg.astype(jnp.float32) * scale,
                      k.astype(jnp.float32))


def _decode_combine(pr, v):
    o = jnp.einsum("bkgqc,bckd->bkgqd", pr, v.astype(jnp.float32))
    b, kv, g, sq, hd = o.shape
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, kv * g, hd)


def _decode_valid(k, kv_len, pad_valid):
    """Key-validity mask for the float decode paths: (B, Smax), or
    (B, Sq, Smax) when ``pad_valid`` carries a per-query mask (the chunked
    prefill step's intra-chunk causality).

    ``kv_len`` may be a scalar or a (B,) per-row vector — the float paths
    are per-row-native either way (the mask is already per row).
    """
    valid = (jnp.arange(k.shape[1])[None, :]
             < jnp.reshape(jnp.asarray(kv_len), (-1, 1)))
    if pad_valid is not None:
        valid = (valid[:, None, :] & pad_valid if pad_valid.ndim == 3
                 else valid & pad_valid)
    return valid


def _decode_mask_scores(s, valid, sentinel):
    """Apply a `_decode_valid` mask to grouped scores (B, KV, G, Sq, Smax)."""
    vm = (valid[:, None, None, None] if valid.ndim == 2
          else valid[:, None, None])  # (B, Sq, Smax) -> (B, 1, 1, Sq, Smax)
    return jnp.where(vm, s, sentinel)


def _flatten_row_lens(k, kv_len, pad_valid):
    """Degrade a per-row kv_len vector to the shared-max-fill contract.

    The flat fused kernels take one scalar fill level; a per-row vector is
    served by decoding every row to the batch max and masking each row's
    tail via the pad mask — correct attention, but every row streams to
    the shared frontier (the pre-rows occupancy behavior) and, unlike the
    per-row kernels, stale cache entries inside [row_len, max_len) still
    sit inside the quantizer-scale reduction window (they are *masked*,
    not nonexistent). The ``*_rows`` backends exist to remove both; this
    path keeps scalar-kv_len callers and explicit flat-backend pins
    working when a per-row vector shows up.
    """
    if jnp.ndim(kv_len) == 0:
        return kv_len, pad_valid
    valid = jnp.arange(k.shape[1])[None, :] < kv_len[:, None]
    if pad_valid is not None and pad_valid.ndim == 3:  # per-query chunk mask
        return jnp.max(kv_len), valid[:, None, :] & pad_valid
    return jnp.max(kv_len), (valid if pad_valid is None
                             else valid & pad_valid)


@register("attention_decode", "digital")
def _decode_digital(plan, q, k, v, *, kv_len, scale, pad_valid=None):
    s = _decode_scores(q, k, k.shape[2], scale)
    valid = _decode_valid(k, kv_len, pad_valid)
    s = _decode_mask_scores(s, valid, NEG_INF)
    return _decode_combine(jax.nn.softmax(s, axis=-1), v)


@register("attention_decode", "raceit_staged",
          notes="float scores + ACAM softmax (the pre-PR2 serving decode)")
def _decode_raceit_staged(plan, q, k, v, *, kv_len, scale, pad_valid=None):
    s = _decode_scores(q, k, k.shape[2], scale)
    valid = _decode_valid(k, kv_len, pad_valid)
    s = _decode_mask_scores(s, valid, LOGIT_FMT.min_value)
    pr = acam_softmax(s, axis=-1, mode=plan.exec_cfg.softmax_mode)
    return _decode_combine(pr, v)


@register("attention_decode", "raceit_fused", supported=_fused_supported,
          notes="per-row kv_len vectors degrade to the shared max fill")
def _decode_raceit_fused(plan, q, k, v, *, kv_len, scale, pad_valid=None):
    # full quantized Fig.-12 numerics over the cache's valid prefix — same
    # contract as the fused prefill path
    kv_len, pad_valid = _flatten_row_lens(k, kv_len, pad_valid)
    return layers._raceit_fused_decode(q, k, v, kv_len, scale, plan,
                                       pad_valid=pad_valid)


def _gqa_native_supported(model_cfg, exec_cfg):
    why = _fused_supported(model_cfg, exec_cfg)
    if why is not None:
        return why
    if model_cfg.n_kv_heads >= model_cfg.n_heads:
        return (f"n_kv_heads={model_cfg.n_kv_heads} == "
                f"n_heads={model_cfg.n_heads} (no KV-head sharing to "
                f"exploit; the flat fused kernel is the same dataflow)")
    return None


@register("attention_decode", "raceit_gqa_native",
          supported=_gqa_native_supported,
          notes="native (B*KV) cache layout; the rep queries sharing a KV "
                "head ride one tile — no cache-code repeat in the hot loop")
def _decode_raceit_gqa(plan, q, k, v, *, kv_len, scale, pad_valid=None):
    # bit-identical to raceit_fused, at 1/rep of the KV-cache reads: the
    # cache codes are never repeated to H (see layers._raceit_gqa_decode)
    kv_len, pad_valid = _flatten_row_lens(k, kv_len, pad_valid)
    return layers._raceit_gqa_decode(q, k, v, kv_len, scale, plan,
                                     pad_valid=pad_valid)


@register("attention_decode", "raceit_fused_rows", supported=_fused_supported,
          notes="per-row kv_len: every batch row decodes at its own cache "
                "fill level (continuous batching); scalar kv_len callers "
                "are served unchanged")
def _decode_raceit_fused_rows(plan, q, k, v, *, kv_len, scale,
                              pad_valid=None):
    # the per-row serving decode: a (B,) kv_len vector reaches the kernel
    # as per-group valid prefixes — per-row masks, per-row dead-block
    # skipping, stale tails outside every quantizer-scale window, empty
    # rows (kv_len 0) defined as zeros. A scalar kv_len is the flat path.
    return layers._raceit_fused_decode(q, k, v, kv_len, scale, plan,
                                       pad_valid=pad_valid)


@register("attention_decode", "raceit_gqa_rows",
          supported=_gqa_native_supported,
          notes="per-row kv_len on the GQA-native cache layout — the "
                "serving default for grouped-query configs")
def _decode_raceit_gqa_rows(plan, q, k, v, *, kv_len, scale, pad_valid=None):
    # per-row lengths + the GQA-native dataflow: each KV-head group's tile
    # streams to its own request's fill frontier and is fetched once for
    # the rep sharing queries (see layers._raceit_gqa_decode)
    return layers._raceit_gqa_decode(q, k, v, kv_len, scale, plan,
                                     pad_valid=pad_valid)


@register("attention_decode", "raceit_fused_paged",
          supported=_fused_supported, paged=True,
          notes="block-paged KV pool (block_table/page_size); contiguous "
                "callers are served on the per-row flat kernel unchanged")
def _decode_raceit_fused_paged(plan, q, k, v, *, kv_len, scale,
                               pad_valid=None, block_table=None,
                               page_size=None):
    # the paged serving decode: k/v are the (n_pages, page_size, KV, hd)
    # page pool, block_table (B, max_pages) names each row's pages (0 = the
    # trash page), and the per-page quantizer reduces each page's scale over
    # the union of its live entries — bit-identical to raceit_fused_rows on
    # the gathered contiguous layout (tests/test_attention_paged.py)
    if block_table is None:
        return layers._raceit_fused_decode(q, k, v, kv_len, scale, plan,
                                           pad_valid=pad_valid)
    return layers._raceit_paged_decode(q, k, v, kv_len, scale, plan,
                                       pad_valid=pad_valid,
                                       block_table=block_table, gqa=False)


@register("attention_decode", "raceit_gqa_paged",
          supported=_gqa_native_supported, paged=True,
          notes="block-paged KV pool on the GQA-native layout — the paged "
                "serving default for grouped-query configs")
def _decode_raceit_gqa_paged(plan, q, k, v, *, kv_len, scale,
                             pad_valid=None, block_table=None,
                             page_size=None):
    if block_table is None:
        return layers._raceit_gqa_decode(q, k, v, kv_len, scale, plan,
                                         pad_valid=pad_valid)
    # chunked-prefill steps (Sq > 1) ride the flat paged entry — same
    # rationale as _raceit_gqa_decode's Sq>1 delegate: the GQA grid's row
    # dimension carries the rep sharing queries, which a chunk needs for
    # its Sq positions; bit-identical either way
    return layers._raceit_paged_decode(q, k, v, kv_len, scale, plan,
                                       pad_valid=pad_valid,
                                       block_table=block_table,
                                       gqa=q.shape[1] == 1)


# ---------------------------------------------------------------------------
# lm_head (the unembedding projection)
# ---------------------------------------------------------------------------

@register("lm_head", "digital",
          notes="resident int8 weights take the quantized path with the "
                "plan's act_bits")
def _lm_head_digital(plan, x, w):
    if isinstance(w, QuantizedWeight):  # resident int8 unembedding
        return _resident_matmul(plan, x, w, None).astype(jnp.float32)
    return jnp.einsum("bsd,dv->bsv", x.astype(jnp.float32),
                      w.astype(jnp.float32))


@register("lm_head", "raceit_q8",
          notes="fully-quantized lm head (beyond-paper; default stays "
                "full-precision)")
def _lm_head_raceit_q8(plan, x, w):
    if isinstance(w, QuantizedWeight):
        return _resident_matmul(plan, x, w, None).astype(jnp.float32)
    return _matmul_raceit_int(plan, x, w, None).astype(jnp.float32)


# the raceit_noisy_* family registers itself against the same slots; it
# lives in its own module but is part of the built-in registry surface,
# and its impls reuse the staged helpers above — importing it here (after
# every helper is defined) keeps `_ensure_backends_loaded` the single
# load point.
from . import noisy  # noqa: E402,F401

# likewise the tensor-parallel raceit_*_tp family (mesh-sharded attention):
# same slots, same registry surface, own module.
from . import sharded  # noqa: E402,F401
