"""repro.exec — RaceOp registry + resolved ExecPlan.

The single dispatch API for every RACE-IT operator: backends register
against op slots (`repro.exec.registry`), `resolve_plan` picks one
implementation per slot for a (ModelConfig, ExecConfig) pair, and the
model/serving stack calls ``plan.<slot>(...)`` instead of branching on
``exec_cfg.mode``. See `docs/architecture.md` §Dispatch.
"""
from .plan import (ExecPlan, ResolvedOp, Degrade, as_plan, resolve_plan,
                   reset_plan_cache)
from .registry import OP_SLOTS, BackendSpec, get_backend, list_backends, register

__all__ = ["ExecPlan", "ResolvedOp", "Degrade", "as_plan", "resolve_plan",
           "reset_plan_cache", "OP_SLOTS", "BackendSpec", "get_backend",
           "list_backends", "register"]
