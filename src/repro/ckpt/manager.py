"""Fault-tolerant checkpointing: atomic, asynchronous, elastic-restorable.

* atomic      — writes go to `<dir>/tmp-<step>` and are renamed to
                `<dir>/step-<step>` only after fsync, so a preempted save
                never corrupts the latest checkpoint;
* async       — `save(..., block=False)` snapshots to host RAM and writes on
                a background thread (training continues);
* elastic     — `restore(shardings=...)` re-places every leaf under a NEW
                mesh/sharding, so a job restarted on a different topology
                (e.g. 512 -> 256 chips after a pod loss) resumes seamlessly;
* retention   — keeps the last `keep` checkpoints;
* state scope — params, optimizer state, data-iterator state, and step are
                all captured (exact-resume is tested).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

__all__ = ["CheckpointManager"]

# numpy can't serialize ml_dtypes natively: store as bit-identical views
_VIEW_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _flatten(tree) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out, dtypes = {}, {}
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in keypath) or "_root"
        arr = np.asarray(jax.device_get(leaf))
        dtypes[path] = arr.dtype.name
        if arr.dtype.name in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[arr.dtype.name])
        out[path] = arr
    return out, dtypes


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None,
             block: bool = True):
        self.wait()  # one in-flight save at a time
        leaves, dtypes = _flatten(tree)  # host snapshot
        treedef = jax.tree_util.tree_structure(tree)
        meta = {"step": int(step), "treedef": str(treedef),
                "paths": list(leaves), "dtypes": dtypes,
                "extra": extra or {}}

        def _write():
            try:
                tmp = self.dir / f"tmp-{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                np.savez(tmp / "leaves.npz", **leaves)
                (tmp / "meta.json").write_text(json.dumps(meta))
                final = self.dir / f"step-{step}"
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)  # atomic publish
                self._gc()
            except BaseException as e:  # noqa: BLE001
                self._error = e

        if block:
            _write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(f"async checkpoint failed: {e}") from e

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step-{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def steps(self) -> list[int]:
        return sorted(int(p.name.split("-")[1])
                      for p in self.dir.glob("step-*"))

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of `template`; optionally re-place
        leaves under new shardings (elastic restart)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step-{step}"
        meta = json.loads((d / "meta.json").read_text())
        with np.load(d / "leaves.npz") as z:
            leaves = {k: z[k] for k in z.files}

        flat_t = jax.tree_util.tree_flatten_with_path(template)[0]
        treedef = jax.tree_util.tree_structure(template)
        shard_flat = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "addressable_devices"))
            if shardings is not None else [None] * len(flat_t))
        out = []
        for (keypath, tmpl), shard in zip(flat_t, shard_flat):
            path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                            for k in keypath) or "_root"
            arr = leaves[path]
            saved = meta.get("dtypes", {}).get(path)
            if saved in _VIEW_DTYPES:
                arr = arr.view(getattr(ml_dtypes, saved))
            if hasattr(tmpl, "dtype") and arr.dtype != tmpl.dtype:
                arr = arr.astype(tmpl.dtype)
            out.append(jax.device_put(arr, shard) if shard is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]
