"""Gradient compression with error feedback (EF-SGD style).

`ef_compress_update` compresses a gradient pytree after folding in the
residual from the previous step, and returns the new residual so the
time-averaged compressed gradient is unbiased — the standard error-feedback
guarantee behind int8/sign gradient compressors.

Call-path status: this module is NOT wired into the training step — the
serving-side distribution work (`ExecConfig.mesh`, `exec/sharded.py`,
FSDP-at-load in `serve/engine.py`) consumes `dist/sharding.py` only.
`ef_compress_update`'s contract (unbiasedness of the error-fed compressed
stream) is covered by `tests/test_substrate.py`; wiring it into a
data-parallel `train/trainer.py` gradient exchange is future work, and any
claim stronger than that would be aspirational.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_compress_update"]


def _compress_leaf(g: jax.Array, method: str):
    """Returns (compressed payload, restored float array)."""
    if method == "none":
        return g, g
    if method == "int8":
        amax = jnp.max(jnp.abs(g))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        codes = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        return (codes, scale), codes.astype(jnp.float32) * scale
    if method == "sign":
        scale = jnp.mean(jnp.abs(g))
        codes = jnp.sign(g).astype(jnp.int8)
        return (codes, scale), codes.astype(jnp.float32) * scale
    raise ValueError(f"unknown compression method: {method}")


def ef_compress_update(grads, residual, method: str = "int8"):
    """(grads + residual) -> (compressed, restored, new_residual) pytrees."""
    if residual is None:
        residual = jax.tree.map(jnp.zeros_like, grads)
    g_eff = jax.tree.map(lambda g, r: g + r, grads, residual)
    flat, treedef = jax.tree_util.tree_flatten(g_eff)
    comp_leaves, rest_leaves = zip(*(_compress_leaf(g, method) for g in flat)) \
        if flat else ((), ())
    compressed = jax.tree_util.tree_unflatten(treedef, list(comp_leaves))
    restored = jax.tree_util.tree_unflatten(treedef, list(rest_leaves))
    new_residual = jax.tree.map(lambda g, r: g - r, g_eff, restored)
    return compressed, restored, new_residual
