from .sharding import (  # noqa: F401
    MeshContext, MeshSpec, ShardingPolicy, compat_make_mesh, constraint,
    current_policy, named_sharding_tree, param_specs, shard_map, use_policy,
)
