from .sharding import (  # noqa: F401
    MeshContext, ShardingPolicy, constraint, current_policy,
    named_sharding_tree, param_specs, use_policy,
)
