"""Logical-axis sharding policy + mesh context (GSPMD distribution layer).

Model code annotates tensors with *logical* axis names ("batch", "heads",
"mlp", ...) via `constraint`; a `ShardingPolicy` maps those names onto the
physical mesh axes ("pod", "data", "model"), dropping any assignment that
does not divide the dimension or would reuse a mesh axis twice. With no
active policy every annotation is a no-op, so single-host tests and the
serving stack run unchanged.

Also hosts the small jax-version compatibility shims (`shard_map`,
`compat_make_mesh`) so model code and tests run on both the 0.4.x toolchain
baked into this container and newer jax releases.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "MeshContext", "ShardingPolicy", "constraint", "current_policy",
    "named_sharding_tree", "param_specs", "use_policy", "shard_map",
    "compat_make_mesh",
]

_DP_AXES = ("pod", "data")


# --------------------------------------------------------------------------
# jax version compatibility
# --------------------------------------------------------------------------

def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` on new jax; experimental shard_map (check_rep) on old."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:  # pre-check_vma signature
            pass
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def compat_make_mesh(shape, axis_names):
    """jax.make_mesh with Auto axis types where the installed jax has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axis_names,
                                 axis_types=(axis_type.Auto,) * len(shape))
        except TypeError:
            pass
    return jax.make_mesh(shape, axis_names)


# --------------------------------------------------------------------------
# mesh context
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MeshContext:
    """Physical mesh + the conventional axis roles used by the model stack."""

    mesh: object = None

    @property
    def axis_names(self) -> tuple:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    def _size(self, name: str) -> int:
        return int(self.mesh.shape[name]) if name in self.axis_names else 1

    @property
    def model_axis(self) -> Optional[str]:
        return "model" if "model" in self.axis_names else None

    @property
    def model_size(self) -> int:
        return self._size("model")

    @property
    def present_dp_axes(self) -> tuple:
        return tuple(a for a in _DP_AXES if a in self.axis_names)

    @property
    def dp_size(self) -> int:
        return int(np.prod([self._size(a) for a in self.present_dp_axes],
                           dtype=np.int64))


# --------------------------------------------------------------------------
# policy
# --------------------------------------------------------------------------

def _default_axis_map(mesh) -> dict:
    names = tuple(mesh.axis_names) if mesh is not None else ()
    dp = tuple(a for a in _DP_AXES if a in names)
    model = ("model",) if "model" in names else ()
    return {
        "batch": dp,
        "seq": (),            # caches replicate over seq unless make_policy remaps
        "sp_seq": model,      # Megatron-SP residual stream
        "heads": model,
        "mlp": model,
        "vocab": model,
        "model": model,
        "chunks": model,      # SSD chunk dim fallback when heads don't divide
        "headdim": (),
    }


class ShardingPolicy:
    """Maps logical axis names onto mesh axes with divisibility checks."""

    def __init__(self, mesh, axis_map: Optional[dict] = None):
        self.mesh = mesh
        self.axis_map = dict(axis_map) if axis_map is not None \
            else _default_axis_map(mesh)

    def mesh_axes(self, name: Optional[str]) -> tuple:
        if name is None:
            return ()
        return tuple(self.axis_map.get(name, ()))

    def axes_size(self, axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([int(self.mesh.shape[a]) for a in axes],
                           dtype=np.int64)) if axes else 1

    def spec_for(self, shape: tuple, names: tuple) -> P:
        """PartitionSpec for `shape`, one logical name (or None) per dim.

        A mesh axis is used at most once; an assignment that does not divide
        the dimension is dropped (replicated) rather than erroring.
        """
        used: set = set()
        entries = []
        for dim, name in zip(shape, names):
            picked = []
            for ax in self.mesh_axes(name):
                size = int(self.mesh.shape[ax])
                if ax in used or size <= 0:
                    continue
                if dim % (self.axes_size(tuple(picked)) * size) != 0:
                    continue
                picked.append(ax)
            used.update(picked)
            if not picked:
                entries.append(None)
            elif len(picked) == 1:
                entries.append(picked[0])
            else:
                entries.append(tuple(picked))
        return P(*entries)


# --------------------------------------------------------------------------
# active-policy context (thread of execution, not thread-safe by design)
# --------------------------------------------------------------------------

_ACTIVE: list = []


@contextlib.contextmanager
def use_policy(policy: ShardingPolicy, mesh_ctx: Optional[MeshContext] = None):
    _ACTIVE.append((policy, mesh_ctx))
    try:
        yield policy
    finally:
        _ACTIVE.pop()


def current_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE[-1][0] if _ACTIVE else None


def constraint(x, *names):
    """Annotate `x` with logical axis names; no-op without an active policy."""
    pol = current_policy()
    if pol is None or pol.mesh is None:
        return x
    spec = pol.spec_for(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(pol.mesh, spec))


# --------------------------------------------------------------------------
# parameter sharding rules
# --------------------------------------------------------------------------

# logical axes per weight leaf, aligned to the *trailing* dims of the leaf
# (leading scan/expert dims replicate). See DESIGN notes in models/layers.py.
_PARAM_RULES = {
    "wq": (None, "heads", None),
    "wk": (None, "heads", None),
    "wv": (None, "heads", None),
    "bq": ("heads", None),
    "bk": ("heads", None),
    "bv": ("heads", None),
    "wo": ("heads", None, None),
    "w1": (None, "mlp"),
    "w3": (None, "mlp"),
    "w2": ("mlp", None),
    "tok_emb": ("vocab", None),
    "unembed": (None, "vocab"),
    "w_z": (None, "heads"),
    "w_x": (None, "heads"),
    "w_B": (None, "heads"),
    "w_C": (None, "heads"),
    "w_dt": (None, "heads"),
    "out_proj": ("heads", None),
}


def _leaf_axes(path: str, shape: tuple) -> tuple:
    name = path.split("/")[-1]
    rule = _PARAM_RULES.get(name)
    if rule is None or len(rule) > len(shape):
        return tuple(None for _ in shape)
    return tuple(None for _ in range(len(shape) - len(rule))) + tuple(rule)


def _path_str(keypath) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in keypath)


def param_specs(params_shapes, cfg, policy: ShardingPolicy):
    """PartitionSpec pytree for a parameter (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for keypath, leaf in flat:
        shape = tuple(getattr(leaf, "shape", ()))
        axes = _leaf_axes(_path_str(keypath), shape)
        specs.append(policy.spec_for(shape, axes))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding_tree(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
