"""Logical-axis sharding policy + mesh context (GSPMD distribution layer).

Model code annotates tensors with *logical* axis names ("batch", "heads",
"mlp", ...) via `constraint`; a `ShardingPolicy` maps those names onto the
physical mesh axes ("pod", "data", "model"), dropping any assignment that
does not divide the dimension or would reuse a mesh axis twice. With no
active policy every `constraint` annotation is a no-op, so single-device
tests run unchanged.

Who consumes what (these are live call paths, not future plans):

- `constraint` lands in the layer stack at the mixer/FFN seams —
  `models/blocks.py::apply_layer` pins the residual stream to
  ("batch", "sp_seq", None) after every block, and the attention/FFN
  bodies in `models/layers.py` annotate activations at their head/mlp
  splits. Active only under `use_policy` (the dry-run launcher and
  mesh-sharded serving both enter it).
- `param_specs` is called by `launch/inputs.py::input_specs` (dry-run
  lowering: eval-shaped params get NamedShardings attached),
  `launch/train.py` (real params `device_put` onto the mesh), and
  `serve/engine.py` (FSDP-at-load for `ModelConfig.fsdp` configs served
  with `ExecConfig.mesh` — command-r-35B / mixtral-8x22B-class trees
  resolve without fitting one device).
- `MeshSpec` is the *declarative, hashable* mesh shape that rides on
  `ExecConfig.mesh` and therefore in the `resolve_plan` lru-cache key;
  the tensor-parallel attention backends (`exec/sharded.py`) call
  `MeshSpec.build()` to materialize the concrete `jax.sharding.Mesh`
  and `repro.dist.shard_map` to run per-shard kernel bodies over it.

Also hosts the small jax-version compatibility shims (`shard_map`,
`compat_make_mesh`) so model code and tests run on both the 0.4.x toolchain
baked into this container and newer jax releases.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "MeshContext", "MeshSpec", "ShardingPolicy", "constraint",
    "current_policy", "named_sharding_tree", "param_specs", "use_policy",
    "shard_map", "compat_make_mesh",
]

_DP_AXES = ("pod", "data")


# --------------------------------------------------------------------------
# jax version compatibility
# --------------------------------------------------------------------------

def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` on new jax; experimental shard_map (check_rep) on old."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=check_vma)
        except TypeError:  # pre-check_vma signature
            pass
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def compat_make_mesh(shape, axis_names):
    """jax.make_mesh with Auto axis types where the installed jax has them."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(shape, axis_names,
                                 axis_types=(axis_type.Auto,) * len(shape))
        except TypeError:
            pass
    return jax.make_mesh(shape, axis_names)


# --------------------------------------------------------------------------
# declarative mesh shape (plan-cache safe)
# --------------------------------------------------------------------------

_BUILT_MESHES: dict = {}


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Declarative mesh shape: what `ExecConfig.mesh` carries.

    A frozen, hashable value object — `resolve_plan` is lru-cached over
    `(ModelConfig, ExecConfig)`, so the config must carry the mesh *shape*
    (which determines backend capability: divisibility, model_size), never
    the live `jax.sharding.Mesh` (device handles don't belong in a cache
    key). Backends materialize the concrete mesh via `build()` at trace
    time; capability predicates stay purely structural so plans resolve —
    and `plan_audit` exercises the catalog x mesh matrix — on a one-device
    process with no `XLA_FLAGS` set.

    ``axes`` is an ordered tuple of ``(name, size)`` pairs, e.g.
    ``(("data", 2), ("model", 4))``. `parse` accepts the launcher
    ``--mesh`` forms: ``"4"`` / ``"model=4"`` / ``"data=2,model=4"``.
    """

    axes: tuple = ()

    def __post_init__(self):
        seen = set()
        for entry in self.axes:
            name, size = entry
            if name in seen:
                raise ValueError(f"duplicate mesh axis {name!r} in {self.axes}")
            seen.add(name)
            if not isinstance(size, int) or size < 1:
                raise ValueError(f"mesh axis {name!r} needs a positive int "
                                 f"size, got {size!r}")

    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        """``"4"`` (model=4) / ``"model=4"`` / ``"data=2,model=4"``."""
        axes = []
        for part in str(text).split(","):
            part = part.strip()
            if not part:
                continue
            name, eq, size = part.partition("=")
            if not eq:
                name, size = "model", part
            try:
                axes.append((name.strip(), int(size)))
            except ValueError:
                raise ValueError(f"--mesh entries are axis=size, got {part!r}")
        return cls(axes=tuple(axes))

    @property
    def axis_names(self) -> tuple:
        return tuple(name for name, _ in self.axes)

    @property
    def n_devices(self) -> int:
        return int(np.prod([size for _, size in self.axes], dtype=np.int64)) \
            if self.axes else 1

    @property
    def model_size(self) -> int:
        return dict(self.axes).get("model", 1)

    def describe(self) -> str:
        return ",".join(f"{n}={s}" for n, s in self.axes) or "1"

    def build(self):
        """The concrete `jax.sharding.Mesh` (cached per spec).

        Raises with a run-it hint when the process has fewer devices than
        the spec asks for — structural predicates never call this, so a
        plan naming a TP backend resolves anywhere; only actually *running*
        it needs the devices (simulated ones count:
        ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
        """
        cached = _BUILT_MESHES.get(self)
        if cached is None:
            have = len(jax.devices())
            if self.n_devices > have:
                raise RuntimeError(
                    f"mesh {self.describe()} needs {self.n_devices} devices "
                    f"but the process has {have}; run under XLA_FLAGS="
                    f"--xla_force_host_platform_device_count="
                    f"{self.n_devices} (or on that many real devices)")
            cached = _BUILT_MESHES[self] = compat_make_mesh(
                tuple(size for _, size in self.axes), self.axis_names)
        return cached

    def context(self) -> "MeshContext":
        return MeshContext(self.build())


# --------------------------------------------------------------------------
# mesh context
# --------------------------------------------------------------------------

@dataclasses.dataclass
class MeshContext:
    """Physical mesh + the conventional axis roles used by the model stack."""

    mesh: object = None

    @property
    def axis_names(self) -> tuple:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    def _size(self, name: str) -> int:
        return int(self.mesh.shape[name]) if name in self.axis_names else 1

    @property
    def model_axis(self) -> Optional[str]:
        return "model" if "model" in self.axis_names else None

    @property
    def model_size(self) -> int:
        return self._size("model")

    @property
    def present_dp_axes(self) -> tuple:
        return tuple(a for a in _DP_AXES if a in self.axis_names)

    @property
    def dp_size(self) -> int:
        return int(np.prod([self._size(a) for a in self.present_dp_axes],
                           dtype=np.int64))


# --------------------------------------------------------------------------
# policy
# --------------------------------------------------------------------------

def _default_axis_map(mesh) -> dict:
    names = tuple(mesh.axis_names) if mesh is not None else ()
    dp = tuple(a for a in _DP_AXES if a in names)
    model = ("model",) if "model" in names else ()
    return {
        "batch": dp,
        "seq": (),            # caches replicate over seq unless make_policy remaps
        "sp_seq": model,      # Megatron-SP residual stream
        "heads": model,
        "mlp": model,
        "vocab": model,
        "model": model,
        "chunks": model,      # SSD chunk dim fallback when heads don't divide
        "headdim": (),
    }


class ShardingPolicy:
    """Maps logical axis names onto mesh axes with divisibility checks."""

    def __init__(self, mesh, axis_map: Optional[dict] = None):
        self.mesh = mesh
        self.axis_map = dict(axis_map) if axis_map is not None \
            else _default_axis_map(mesh)

    def mesh_axes(self, name: Optional[str]) -> tuple:
        if name is None:
            return ()
        return tuple(self.axis_map.get(name, ()))

    def axes_size(self, axes) -> int:
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([int(self.mesh.shape[a]) for a in axes],
                           dtype=np.int64)) if axes else 1

    def spec_for(self, shape: tuple, names: tuple) -> P:
        """PartitionSpec for `shape`, one logical name (or None) per dim.

        A mesh axis is used at most once; an assignment that does not divide
        the dimension is dropped (replicated) rather than erroring.
        """
        used: set = set()
        entries = []
        for dim, name in zip(shape, names):
            picked = []
            for ax in self.mesh_axes(name):
                size = int(self.mesh.shape[ax])
                if ax in used or size <= 0:
                    continue
                if dim % (self.axes_size(tuple(picked)) * size) != 0:
                    continue
                picked.append(ax)
            used.update(picked)
            if not picked:
                entries.append(None)
            elif len(picked) == 1:
                entries.append(picked[0])
            else:
                entries.append(tuple(picked))
        return P(*entries)


# --------------------------------------------------------------------------
# active-policy context (thread of execution, not thread-safe by design)
# --------------------------------------------------------------------------

_ACTIVE: list = []


@contextlib.contextmanager
def use_policy(policy: ShardingPolicy, mesh_ctx: Optional[MeshContext] = None):
    _ACTIVE.append((policy, mesh_ctx))
    try:
        yield policy
    finally:
        _ACTIVE.pop()


def current_policy() -> Optional[ShardingPolicy]:
    return _ACTIVE[-1][0] if _ACTIVE else None


def constraint(x, *names):
    """Annotate `x` with logical axis names; no-op without an active policy."""
    pol = current_policy()
    if pol is None or pol.mesh is None:
        return x
    spec = pol.spec_for(x.shape, names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(pol.mesh, spec))


# --------------------------------------------------------------------------
# parameter sharding rules
# --------------------------------------------------------------------------

# logical axes per weight leaf, keyed by leaf name and aligned to the
# *trailing* dims of the leaf — leading scan/expert dims replicate, so one
# rule covers both a plain layer's (d_model, H, hd) wq and the scanned
# stack's (n_layers, d_model, H, hd). Megatron split: qkv/up projections
# shard their output (heads/mlp), wo/down their input, embeddings the vocab.
_PARAM_RULES = {
    "wq": (None, "heads", None),
    "wk": (None, "heads", None),
    "wv": (None, "heads", None),
    "bq": ("heads", None),
    "bk": ("heads", None),
    "bv": ("heads", None),
    "wo": ("heads", None, None),
    "w1": (None, "mlp"),
    "w3": (None, "mlp"),
    "w2": ("mlp", None),
    "tok_emb": ("vocab", None),
    "unembed": (None, "vocab"),
    "w_z": (None, "heads"),
    "w_x": (None, "heads"),
    "w_B": (None, "heads"),
    "w_C": (None, "heads"),
    "w_dt": (None, "heads"),
    "out_proj": ("heads", None),
}


def _leaf_axes(path: str, shape: tuple) -> tuple:
    name = path.split("/")[-1]
    rule = _PARAM_RULES.get(name)
    if rule is None or len(rule) > len(shape):
        return tuple(None for _ in shape)
    return tuple(None for _ in range(len(shape) - len(rule))) + tuple(rule)


def _path_str(keypath) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in keypath)


def param_specs(params_shapes, cfg, policy: ShardingPolicy):
    """PartitionSpec pytree for a parameter (shape) pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shapes)
    specs = []
    for keypath, leaf in flat:
        shape = tuple(getattr(leaf, "shape", ()))
        axes = _leaf_axes(_path_str(keypath), shape)
        specs.append(policy.spec_for(shape, axes))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named_sharding_tree(specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))
