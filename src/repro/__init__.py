"""RACE-IT in JAX: analog-IMC-faithful multi-pod transformer framework.

Layers:
  core/     — the paper's Compute-ACAM contribution (compiler, numerics)
  kernels/  — Pallas TPU kernels (interpret-validated)
  models/   — block-pattern transformer stack, digital + raceit exec modes
  configs/  — 10 assigned architectures + the paper's own models
  dist/     — sharding rules (DP/FSDP/TP/EP/SP), gradient compression
  train/    — AdamW, fault-tolerant loop
  serve/    — generation engine, request batching
  ckpt/     — atomic/async/elastic checkpointing
  data/     — checkpointable synthetic LM data
  hw/       — RACE-IT/PUMA/ReTransformer cycle+energy simulator
  launch/   — production meshes, multi-pod dry-run, HLO cost analyzer
"""
