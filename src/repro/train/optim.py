"""Optimizers and LR schedules (pure-pytree AdamW, no external deps).

Moments are kept in f32 regardless of param dtype; optimizer state inherits
the parameter sharding (ZeRO-style when fsdp shards params over "data").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "apply_updates",
           "global_norm", "clip_by_global_norm", "warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: Optional[Callable] = None  # step -> lr multiplier


def warmup_cosine(warmup: int, total: int, min_ratio: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    lr = cfg.lr * (cfg.schedule(step) if cfg.schedule is not None else 1.0)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (-lr * u).astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    updates = treedef.unflatten([o[0] for o in out])
    new_state = {
        "mu": treedef.unflatten([o[1] for o in out]),
        "nu": treedef.unflatten([o[2] for o in out]),
        "step": step,
    }
    return updates, new_state, {"grad_norm": gnorm, "lr": lr}


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
