"""Fault-tolerant outer training loop.

Production behaviors implemented (and exercised by tests/examples):

* auto-resume from the latest checkpoint (params + optimizer + data state);
* periodic async checkpointing with atomic publish + retention;
* preemption handling: SIGTERM/SIGINT triggers a final blocking checkpoint;
* straggler/hang monitoring: a watchdog flags steps slower than
  `straggler_factor` x the running median (on a real cluster this feeds the
  controller that evicts the slow host; here it is surfaced in metrics);
* metrics CSV (loss, grad-norm, lr, step time, straggler flags).
"""
from __future__ import annotations

import csv
import dataclasses
import signal
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from repro.ckpt.manager import CheckpointManager

__all__ = ["TrainLoopConfig", "run_training"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    metrics_path: Optional[str] = None


def run_training(train_step: Callable, params, opt_state, data_iter,
                 cfg: TrainLoopConfig, make_batch=None, log=print):
    """Run `train_step(params, opt_state, batch) -> (params, opt_state, m)`.

    Returns (params, opt_state, history). `data_iter` must expose
    next_batch()/state()/set_state() (see data.synthetic.SyntheticLM).
    """
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep)
    start_step = 0
    if mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore((params, opt_state))
        start_step = int(extra.get("step", 0))
        if "data_state" in extra and hasattr(data_iter, "set_state"):
            data_iter.set_state(extra["data_state"])
        log(f"[loop] resumed from step {start_step}")

    stop = {"flag": False}

    def _handler(signum, frame):  # preemption: checkpoint and exit cleanly
        stop["flag"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:  # non-main thread (tests)
            pass

    history = []
    step_times: list[float] = []
    stragglers = 0
    metrics_file = None
    writer = None
    if cfg.metrics_path:
        Path(cfg.metrics_path).parent.mkdir(parents=True, exist_ok=True)
        metrics_file = open(cfg.metrics_path, "a", newline="")
        writer = csv.writer(metrics_file)

    step = start_step
    try:
        while step < cfg.steps and not stop["flag"]:
            batch = data_iter.next_batch()
            if make_batch is not None:
                batch = make_batch(batch)
            t0 = time.perf_counter()
            params, opt_state, m = train_step(params, opt_state, batch)
            loss = float(m["loss"])  # blocks: realistic step timing
            dt = time.perf_counter() - t0
            step += 1

            is_straggler = (len(step_times) >= 8 and
                            dt > cfg.straggler_factor * float(np.median(step_times)))
            stragglers += int(is_straggler)
            step_times.append(dt)
            rec = {"step": step, "loss": loss,
                   "grad_norm": float(m.get("grad_norm", np.nan)),
                   "lr": float(m.get("lr", np.nan)),
                   "step_time_s": dt, "straggler": is_straggler}
            history.append(rec)
            if writer:
                writer.writerow(list(rec.values()))
            if step % cfg.log_every == 0:
                log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)"
                    + (" STRAGGLER" if is_straggler else ""))
            if step % cfg.ckpt_every == 0:
                mgr.save(step, (params, opt_state),
                         extra={"step": step,
                                "data_state": (data_iter.state()
                                               if hasattr(data_iter, "state")
                                               else {})},
                         block=False)
    finally:
        mgr.save(step, (params, opt_state),
                 extra={"step": step,
                        "data_state": (data_iter.state()
                                       if hasattr(data_iter, "state") else {})},
                 block=True)
        if metrics_file:
            metrics_file.close()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return params, opt_state, {"history": history, "stragglers": stragglers,
                               "final_step": step}
