"""Training step factory: grad, clip, AdamW, optional microbatch accumulation.

The returned step is a pure function suitable for pjit; gradient reduction
across ("pod","data") and FSDP all-gather/reduce-scatter are inserted by XLA
SPMD from the parameter shardings (DESIGN.md §4).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from . import optim


def make_train_step(model: Model, opt_cfg: optim.AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, use_remat=True)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            # gradient accumulation over the leading batch dim via scan
            def micro(b):
                return jax.tree.map(
                    lambda a: a.reshape((microbatches, -1) + a.shape[1:]), b)

            def acc_body(carry, mb):
                loss_sum, grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                grads = jax.tree.map(jnp.add, grads, g)
                return (loss_sum + l, grads), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zero_grads), micro(batch))
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        updates, opt_state, om = optim.adamw_update(grads, opt_state, params, opt_cfg)
        params = optim.apply_updates(params, updates)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss_fn(params, batch, use_remat=False)
    return eval_step
