from . import optim, trainer  # noqa: F401
from .loop import TrainLoopConfig, run_training  # noqa: F401
