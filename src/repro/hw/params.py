"""RACE-IT hardware constants (paper Table II, 16nm) and baselines.

Every number here is transcribed from the paper; derived per-unit values
(e.g. per-ACAM-array power/area) are computed, not re-measured — Table IV's
4-bit ADC row (70.9 um^2 / 0.012 mW == exactly one 4x8 array) confirms the
derivation.
"""
from __future__ import annotations

import dataclasses

MW = 1e-3   # W
UM2 = 1e-12  # m^2 (areas are kept in the paper's units below)


@dataclasses.dataclass(frozen=True)
class CoreParams:
    # crossbar DPE lane
    n_xbars: int = 8
    xbar_rows: int = 128
    xbar_cols: int = 128
    cell_bits: int = 2
    dac_bits: int = 1
    weight_bits: int = 8
    input_bits: int = 8
    xbar_read_ns: float = 100.0       # one analog pulse (ISAAC-style)
    xbar_power_mw: float = 2.4
    dac_power_mw: float = 0.95532
    sa_power_mw: float = 0.95         # shift & add units (128)
    # digital lanes
    n_adders: int = 1024
    adder_power_mw: float = 12.2281
    adder_ghz: float = 1.0
    xor_count: int = 6144             # Gray decode
    xor_power_mw: float = 0.1536
    # GCE (Compute-ACAM) lane
    n_acam_arrays: int = 1536
    acam_rows: int = 4
    acam_cols: int = 8
    acam_power_mw: float = 19.16928
    acam_search_ns: float = 1.0       # one 4-bit search; 8-bit ops take 2
    n_adc_arrays: int = 256           # reserved as crossbar ADCs (32/xbar)
    reg_file_power_mw: float = 0.01573
    control_power_mw: float = 0.0597
    core_power_mw: float = 35.93175
    core_area_mm2: float = 0.14378

    @property
    def acam_array_power_mw(self) -> float:
        return self.acam_power_mw / self.n_acam_arrays  # 0.01248 mW

    @property
    def acam_array_area_um2(self) -> float:
        return 0.10899e6 / self.n_acam_arrays  # 70.95 um^2

    @property
    def n_gce_arrays(self) -> int:
        return self.n_acam_arrays - self.n_adc_arrays  # 1280

    @property
    def xbar_mvm_ns(self) -> float:
        """Full 8-bit-input MVM on one crossbar: input_bits/dac_bits pulses."""
        return self.xbar_read_ns * (self.input_bits // self.dac_bits)


@dataclasses.dataclass(frozen=True)
class ChipParams:
    cores_per_tile: int = 12
    tiles_per_chip: int = 121
    tile_power_mw: float = 435.68
    tile_area_mm2: float = 1.86087
    edram_kb: int = 256
    router_power_mw: float = 10.03087
    chip_power_w: float = 53.602
    chip_area_mm2: float = 225.16573
    interchip_gbps: float = 1.6       # §VII inter-chip bandwidth
    core: CoreParams = CoreParams()

    @property
    def n_cores(self) -> int:
        return self.cores_per_tile * self.tiles_per_chip  # 1452

    @property
    def n_xbars(self) -> int:
        return self.n_cores * self.core.n_xbars


# GCE configuration chosen in §VIII-D: k = multipliers / exp units = 28.3
GCE_DEFAULT = {"multipliers": 454, "exp_units": 16, "log_units": 1,
               "act_units": 1}

# CMOS operator baselines (Table IV, right columns; 16nm-scaled)
CMOS_OPERATORS = {
    "adc4": {"power_mw": 0.113, "area_um2": 116.0},
    "mult4": {"power_mw": 0.00225, "area_um2": 1104.0},
    "gelu8": {"power_mw": 0.334, "area_um2": 1054.0},
    "softmax8": {"power_mw": 0.077, "area_um2": 1131.0},
}

# Paper-measured reference points (used for reporting ratios, not derived)
PAPER_CLAIMS = {
    "speedup_vs_p100": 38.0,
    "speedup_vs_h100": 10.7,
    "speedup_vs_puma": 5.9,
    "speedup_vs_retransformer": 4.0,
    "puma_speedup_vs_p100": 6.4,
    "retransformer_speedup_vs_p100": 9.3,
    "energy_saving_vs_p100": 1193.0,
    "energy_saving_vs_puma": 3.9,
    "energy_saving_vs_retransformer": 5.8,
    "table_v_tops": {  # (TOPS, TOPS/W)
        "bert-base": {"PUMA": (19.27, 27.48), "ReTransformer": (64.63, 28.0),
                      "RACE-IT": (110.11, 109.0)},
        "bert-large": {"PUMA": (33.59, 34.87), "ReTransformer": (89.04, 36.14),
                       "RACE-IT": (191.90, 129.1)},
        "gpt2-large": {"PUMA": (42.16, 18.59), "ReTransformer": (182.76, 69.03),
                       "RACE-IT": (268.2, 80.0)},
    },
}

# Baseline accelerator knobs
PUMA_VFU_MULTS_PER_CORE = 64      # §VIII-B: 64 multiplications at a time
RERAM_WRITE_NS_PER_ROW = 50_000.0  # ReTransformer crossbar write (~50us/row
                                   # for multi-level programming, cf. §I/§VIII)
