"""Analytical cycle/energy simulator for RACE-IT and the §VII baselines.

Models the five-stage MHA pipeline of Fig. 12 at *computing-sequence* (one
row of Q) granularity. A row's data-dependent work executes on the lanes of
the core that owns that row:

  RACE-IT   stages run on separate lanes (DPE / adders / GCE) and overlap
            across computing sequences -> row time = max(stage time)
  PUMA      all non-MVM work serializes through one VFU (64 mults/cycle,
            §VIII-B) -> row time = sum of VFU stage times
  ReTransformer  data-dependent matmuls run in-crossbar but pay operand
            writes (decomposed, amortized over the row) + VFU softmax

Crossbar MVM: 8x 1-bit input pulses x 100 ns = 800 ns per row (§II-A).
4-bit ACAM search = 1 ns; 8-bit op = 2 searches; 8-bit multiply = 4 nibble
searches spread over the 4-bit multiplier units (§IV-B).

Calibration: one effective row-parallelism factor per architecture is fitted
on **bert-base only** against Table V TOPS; bert-large and gpt2-large numbers
and every Fig. 13 ratio are then predictions (benchmarks/ compares them).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig

from .params import (GCE_DEFAULT, PAPER_CLAIMS, PUMA_VFU_MULTS_PER_CORE,
                     ChipParams)

CHIP = ChipParams()
CORE = CHIP.core

VFU_EXP_CYCLES = 10          # exp on a VFU (piecewise approx)
EXP_UNIT_NS = 40.0           # pipelined 8-bit exp element latency on a GCE
                             # exp unit (calibrated to the Fig. 15 upper knee)
RET_WRITE_NS_PER_ROW = 1000  # ReRAM row write incl. verify (decomposed)
RET_WRITE_REUSE = 1.0        # §VIII-B: decomposition reduces data reuse

# effective row-parallelism, calibrated on bert-base Table V (see docstring)
PARALLELISM = {"raceit": 1.55, "puma": 0.98, "retransformer": 2.71}
# per-op active energy (J/op), calibrated on bert-base Table V TOPS/W;
# the PUMA/ReT premium is the conventional-ADC power the paper eliminates
ENERGY_PER_OP = {"raceit": 1 / 109e12, "puma": 1 / 27.48e12,
                 "retransformer": 1 / 28.0e12}


@dataclasses.dataclass
class Workload:
    name: str
    n_layers: int
    d_model: int
    d_ff: int
    seq_len: int

    @classmethod
    def from_config(cls, cfg: ModelConfig, seq_len: int = 384) -> "Workload":
        return cls(cfg.name, cfg.n_layers, cfg.d_model,
                   cfg.d_ff or 4 * cfg.d_model, seq_len)

    @property
    def params_per_layer(self) -> int:
        return 4 * self.d_model ** 2 + 2 * self.d_model * self.d_ff

    @property
    def macs_per_token(self) -> float:
        return (self.n_layers *
                (self.params_per_layer + 2 * self.seq_len * self.d_model))


def _chips_needed(w: Workload) -> int:
    cells_per_param = CORE.weight_bits // CORE.cell_bits
    cells = w.n_layers * w.params_per_layer * cells_per_param
    cap = CHIP.n_xbars * CORE.xbar_rows * CORE.xbar_cols
    return max(1, -(-cells // cap))


def raceit_stage_times(w: Workload, gce=GCE_DEFAULT) -> dict:
    """ns per computing sequence on one core's lanes (Fig. 12)."""
    L, d = w.seq_len, w.d_model
    search = CORE.acam_search_ns
    mult_rate = gce["multipliers"] / (4.0 * search)   # mult8 per ns
    exp_rate = gce["exp_units"] / EXP_UNIT_NS         # exp8 per ns
    add_rate = CORE.n_adders * CORE.adder_ghz
    return {
        "mvm": CORE.xbar_mvm_ns,
        "matmul1": L * d / mult_rate,
        "div_add": L / add_rate,
        "softmax": 2 * L / exp_rate + 2 * L / add_rate,
        "matmul2": L * d / mult_rate,
    }


def _row_ns(w: Workload, arch: str) -> tuple[float, dict]:
    L, d = w.seq_len, w.d_model
    if arch == "raceit":
        st = raceit_stage_times(w)
        return max(st.values()), st
    if arch == "puma":
        vfu = PUMA_VFU_MULTS_PER_CORE * CORE.adder_ghz  # ops/ns
        st = {
            "mvm": CORE.xbar_mvm_ns,
            "vfu_matmul1": L * d / vfu,
            "vfu_div_add": L / vfu,
            "vfu_softmax": (2 * L * VFU_EXP_CYCLES + L) / vfu,
            "vfu_matmul2": L * d / vfu,
        }
        serial = sum(v for k, v in st.items() if k.startswith("vfu"))
        return max(CORE.xbar_mvm_ns, serial), st
    if arch == "retransformer":
        vfu = PUMA_VFU_MULTS_PER_CORE * CORE.adder_ghz
        st = {
            "mvm": 2 * CORE.xbar_mvm_ns,  # two in-crossbar dd matmuls
            "write": (d / CORE.xbar_cols) * RET_WRITE_NS_PER_ROW
                     / RET_WRITE_REUSE,
            "vfu_softmax": (2 * L * VFU_EXP_CYCLES + L) / vfu,
        }
        return st["write"] + st["mvm"] + st["vfu_softmax"], st
    raise KeyError(arch)


def _validate_workload(w: Workload) -> None:
    """Reject degenerate workloads with a named error instead of letting
    them surface as a ZeroDivisionError at the tops_per_w division (or a
    TypeError inside macs_per_token when d_ff is None)."""
    if w.n_layers is None or w.n_layers <= 0:
        raise ValueError(f"workload {w.name!r}: n_layers={w.n_layers} — the "
                         f"simulator models >= 1 transformer layer")
    if not w.d_model or w.d_model <= 0:
        raise ValueError(f"workload {w.name!r}: d_model={w.d_model} must be "
                         f"positive")
    if not w.d_ff or w.d_ff <= 0:
        raise ValueError(f"workload {w.name!r}: d_ff={w.d_ff} must be "
                         f"positive (Workload.from_config defaults it to "
                         f"4*d_model)")
    if w.seq_len is None or w.seq_len <= 0:
        raise ValueError(f"workload {w.name!r}: seq_len={w.seq_len} — the "
                         f"row-granularity pipeline model needs >= 1 "
                         f"computing sequence")


def simulate(w: Workload, arch: str = "raceit") -> dict:
    _validate_workload(w)
    chips = _chips_needed(w)
    base_ns, st = _row_ns(w, arch)
    row_ns = base_ns / PARALLELISM[arch]
    tokens_per_s = 1e9 / row_ns
    tops = 2 * w.macs_per_token * tokens_per_s / 1e12
    energy_per_token_j = 2 * w.macs_per_token * ENERGY_PER_OP[arch]
    power_w = energy_per_token_j * tokens_per_s  # active power at throughput
    return {
        "arch": arch, "model": w.name, "chips": chips,
        "stage_ns": {k: round(v, 1) for k, v in st.items()},
        "row_ns": round(row_ns, 1),
        "tokens_per_s": tokens_per_s,
        "latency_per_seq_s": w.seq_len * row_ns * 1e-9,
        "tops": round(tops, 2),
        "power_w": round(power_w, 1),
        "tops_per_w": round(tops / power_w, 2),
        "energy_per_token_uj": round(energy_per_token_j * 1e6, 3),
    }


def gpu_reference(raceit_result: dict) -> dict:
    """P100/H100 reference points anchored on the paper's measured ratios
    (no CUDA in this container; anchoring documented in EXPERIMENTS.md)."""
    tps = raceit_result.get("tokens_per_s")
    if not tps or tps <= 0:
        raise ValueError(
            f"gpu_reference needs a simulate() result with a positive "
            f"tokens_per_s, got {tps!r} — the GPU points are ratios off the "
            f"RACE-IT throughput, so a zero/missing anchor is meaningless")
    if "energy_per_token_uj" not in raceit_result:
        raise ValueError("gpu_reference needs 'energy_per_token_uj' in the "
                         "simulate() result (P100 energy is anchored on it)")
    return {
        "p100_tokens_per_s":
            raceit_result["tokens_per_s"] / PAPER_CLAIMS["speedup_vs_p100"],
        "h100_tokens_per_s":
            raceit_result["tokens_per_s"] / PAPER_CLAIMS["speedup_vs_h100"],
        "p100_energy_per_token_uj":
            raceit_result["energy_per_token_uj"]
            * PAPER_CLAIMS["energy_saving_vs_p100"],
    }
