"""Table IV reproduction: Compute-ACAM operator area/power from OUR compiler.

The paper's per-array constants (one 4x8 array = 70.95 um^2, 12.48 uW, from
Table II) convert the compiler's row counts into operator area/power. The
CMOS columns come from the paper's cited implementations (params.py).
"""
from __future__ import annotations

import numpy as np

from repro.core import compiler, ops as acam_ops
from repro.core.acam import Acam2VarFunction, AcamFunction
from repro.core.quant import FixedPointFormat

from .params import CMOS_OPERATORS, CoreParams

CORE = CoreParams()


def _cost_from_rows(rows: int) -> dict:
    arrays = rows / CORE.acam_rows
    return {
        "rows": rows,
        "arrays": arrays,
        "area_um2": arrays * CORE.acam_array_area_um2,
        "power_mw": arrays * CORE.acam_array_power_mw,
    }


def operator_cost(name: str, encode: bool) -> dict:
    """Area/power of one Compute-ACAM operator unit (paper Table IV rows)."""
    if name == "adc4":
        op = acam_ops.get_op("identity4", encode=encode)
        rows = op.program.rows_needed()
    elif name == "mult4":
        f_in = FixedPointFormat(int_bits=1, frac_bits=2)   # Fig. 7 config
        f_out = FixedPointFormat(int_bits=2, frac_bits=1)
        op = Acam2VarFunction.compile("m", lambda x, y: x * y, f_in, f_in,
                                      f_out, encode=encode)
        rows = op.program.rows_needed()
    elif name == "gelu8":
        op = AcamFunction.compile(
            "g", acam_ops._np_gelu,
            FixedPointFormat(int_bits=2, frac_bits=5),
            FixedPointFormat(int_bits=2, frac_bits=5), encode=encode)
        rows = op.program.rows_needed()
    elif name == "softmax8":
        # one softmax unit = exp (PoT out) + log tables (Fig. 8 dataflow)
        e = AcamFunction.compile("e", np.exp, acam_ops.LOGIT_FMT,
                                 acam_ops.EXP_POT, encode=encode)
        l = acam_ops.get_op("log", encode=encode)
        p = acam_ops.get_op("exp_prob", encode=encode)
        rows = (e.program.rows_needed() + l.program.rows_needed()
                + p.program.rows_needed())
    else:
        raise KeyError(name)
    out = _cost_from_rows(rows)
    out["cmos"] = CMOS_OPERATORS[name]
    return out


def table_iv() -> dict:
    """All Table IV rows, ours (w/ and w/o encoding) vs paper vs CMOS."""
    paper = {  # paper's Compute-ACAM columns (area um^2, power mW)
        "adc4": {False: (70.9, 0.012), True: (70.9, 0.012)},
        "mult4": {False: (301.0, 0.053), True: (195.0, 0.034)},
        "gelu8": {False: (443.0, 0.078), True: (337.0, 0.059)},
        "softmax8": {False: (648.0, 0.124), True: (506.0, 0.099)},
    }
    rows = {}
    for name in ("adc4", "mult4", "gelu8", "softmax8"):
        rows[name] = {}
        for enc in (False, True):
            c = operator_cost(name, enc)
            rows[name]["encoded" if enc else "plain"] = {
                "ours_area_um2": round(c["area_um2"], 1),
                "ours_power_mw": round(c["power_mw"], 4),
                "paper_area_um2": paper[name][enc][0],
                "paper_power_mw": paper[name][enc][1],
                "cmos_area_um2": c["cmos"]["area_um2"],
                "cmos_power_mw": c["cmos"]["power_mw"],
                "acam_rows": c["rows"],
            }
    return rows


def gce_unit_arrays() -> dict:
    """Arrays consumed per configured GCE unit type (encoded)."""
    mult = operator_cost("mult4", True)       # one 4-bit 2-var table set
    # an 8-bit multiplier = 4 nibble tables (ss, su x2 shared, uu) (§IV-B)
    ss, su, uu = acam_ops.mult4_programs(True)
    mult8_rows = (ss.program.rows_needed() + 2 * su.program.rows_needed()
                  + uu.program.rows_needed())
    exp = AcamFunction.compile("e", np.exp, acam_ops.LOGIT_FMT,
                               acam_ops.EXP_POT, encode=True)
    log = acam_ops.get_op("log", encode=True)
    gelu = operator_cost("gelu8", True)
    return {
        "mult8": int(np.ceil(mult8_rows / CORE.acam_rows)),
        # one GCE "multiplier" is a 4-bit 2-var unit (454 of them fit the
        # 1280-array budget at k=28.3, matching §VI/§VIII-D)
        "mult4_arrays_frac": mult["rows"] / CORE.acam_rows,
        "exp8": int(np.ceil(exp.program.rows_needed() / CORE.acam_rows)),
        "log8": int(np.ceil(log.program.rows_needed() / CORE.acam_rows)),
        "act8": int(np.ceil(gelu["rows"] / CORE.acam_rows)),
    }
