"""GCE configuration exploration (paper §VIII-D, Fig. 15).

The 1280 GCE arrays per core are split between 4-bit multipliers and 8-bit
exponent units at ratio k = multipliers / exp-units (log and activation units
fixed at 1). The pipeline model (simulator.py) turns each (M, E) split into a
bottleneck stage time; the sweep reproduces the Fig. 15 shape: a broad
plateau (matmul-bound) that collapses when E starves the softmax stage or M
starves the matmul stages.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ModelConfig

from . import area, simulator
from .params import CoreParams

CORE = CoreParams()


def split_for_k(k: float) -> dict:
    """Largest (multipliers, exp_units) with M = k*E fitting the GCE budget."""
    u = area.gce_unit_arrays()
    a_mult = u["mult4_arrays_frac"]
    a_exp = u["exp8"]
    budget = CORE.n_gce_arrays - u["log8"] - u["act8"]
    e = budget / (k * a_mult + a_exp)
    m = k * e
    return {"multipliers": max(1, int(m)), "exp_units": max(1, int(e)),
            "log_units": 1, "act_units": 1}


def k_sweep(cfg: ModelConfig, seq_len: int = 256,
            ks=None) -> list[dict]:
    ks = ks if ks is not None else np.geomspace(0.5, 300, 25)
    w = simulator.Workload.from_config(cfg, seq_len)
    rows = []
    for k in ks:
        gce = split_for_k(float(k))
        st = simulator.raceit_stage_times(w, gce)
        row_ns = max(st.values())
        rows.append({"k": round(float(k), 2), **gce,
                     "row_ns": round(row_ns, 3),
                     "tokens_per_s": 1e9 / row_ns,
                     "bottleneck": max(st, key=st.get)})
    return rows


def optimal_k_range(rows: list[dict], tolerance: float = 0.05) -> tuple:
    best = max(r["tokens_per_s"] for r in rows)
    good = [r["k"] for r in rows if r["tokens_per_s"] >= (1 - tolerance) * best]
    return (min(good), max(good))
