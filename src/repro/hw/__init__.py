from . import area, gce, params, simulator  # noqa: F401
