from . import area, gce, noise, params, simulator  # noqa: F401
from .noise import NoiseConfig  # noqa: F401
