"""Device-variation noise models for the RACE-IT analog substrate.

Every ``raceit_*`` backend so far models *ideal* devices; this module is
the fidelity layer behind the ``raceit_noisy_*`` backend family
(`repro.exec.noisy`): a frozen `NoiseConfig` carried on
``ExecConfig.noise`` names how far the simulated devices deviate from the
compiled programs, per physical mechanism:

  acam_sigma          ACAM threshold-voltage variation, in input-code LSBs.
                      A stored match window's edges drift, which is
                      equivalent (input-referred) to jittering the searched
                      code — `repro.core.acam.jitter_codes` /
                      ``AcamFunction.apply_codes_noisy`` apply it; the
                      per-cell form is ``RangeArrays.jittered``.
  conductance_sigma   Crossbar cell-conductance variation for the MVM path,
                      as a fraction of the full conductance range. Applied
                      to stored weight codes in the ISAAC unsigned offset
                      domain (`perturb_weight_codes`).
  stuck_rate          Fraction of crossbar cells stuck at G_min/G_max
                      (half each), same unsigned domain.
  readout_sigma       ACAM output/readout noise, in output-code LSBs (the
                      match-line sense path), applied to produced codes.
  fault_rate          Per-row catastrophic-fault probability on the noisy
                      attention backends — rows go non-finite. Zero in all
                      presets; it exists to drive the fail-safe serving
                      path (`repro.serve.continuous`) and its tests.

Determinism contract: injection sites never draw from an ambient RNG.
Each derives its key as ``site_key(noise, tag, shape)`` — a pure function
of (``NoiseConfig.seed``, a site tag string, the operand shape) — so the
same seed + config reproduces bit-identical noisy outputs across runs,
and under jit the draws constant-fold into the executable: a given
device's fault map is *static* across calls, which is the physics (a
chip's variation does not re-roll between inferences). Two same-shape
call sites with the same tag share a fault map — a documented
simplification (the simulated arrays are reused across layers, as the
paper's pipelined cores are).

Every helper is a Python-level no-op when its knobs are zero, so a
zero-sigma ``NoiseConfig`` is bit-identical to the clean backends
(tests/test_exec_noise.py asserts this for every registered noisy
backend).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.acam import jitter_codes  # noqa: F401  (re-export: the
#   input-referred ACAM jitter primitive lives with the ACAM semantics)

__all__ = ["NoiseConfig", "site_key", "jitter_codes",
           "perturb_weight_codes", "fault_rows", "PRESETS"]

# the "nominal" device-variation profile; worst_case = 4x nominal. The
# magnitudes are plausible for ReRAM ACAM/crossbar arrays (sub-LSB
# threshold jitter, ~1% conductance spread, ~0.1% stuck cells) — they are
# sweep anchors for Fig.-14-style accuracy-vs-noise curves, not measured
# silicon data.
_NOMINAL = dict(acam_sigma=0.5, conductance_sigma=0.01,
                stuck_rate=0.001, readout_sigma=0.5)
PRESETS = {
    "clean": {k: 0.0 for k in _NOMINAL},
    "nominal": dict(_NOMINAL),
    "worst_case": {k: 4.0 * v for k, v in _NOMINAL.items()},
}


@dataclasses.dataclass(frozen=True)
class NoiseConfig:
    """Frozen (hashable) device-noise knobs; rides on ``ExecConfig.noise``.

    Being frozen matters: `repro.exec.plan.resolve_plan` is lru-cached
    over the full ExecConfig, so two configs differing only in noise
    resolve to distinct plans (and distinct jit closures).
    """

    acam_sigma: float = 0.0         # input-code LSBs
    conductance_sigma: float = 0.0  # fraction of the full code range
    stuck_rate: float = 0.0         # fraction of cells (half off, half on)
    readout_sigma: float = 0.0      # output-code LSBs
    fault_rate: float = 0.0         # per-row catastrophic decode faults
    seed: int = 0

    @property
    def is_clean(self) -> bool:
        return (self.acam_sigma <= 0.0 and self.conductance_sigma <= 0.0
                and self.stuck_rate <= 0.0 and self.readout_sigma <= 0.0
                and self.fault_rate <= 0.0)

    @classmethod
    def preset(cls, name: str, seed: int = 0) -> "NoiseConfig":
        return cls(seed=seed, **PRESETS[name])

    @classmethod
    def scaled(cls, lam: float, seed: int = 0) -> "NoiseConfig":
        """``lam`` x the nominal profile — the sweep axis of the
        accuracy-vs-noise benchmarks (0 = clean, 1 = nominal, 4 =
        worst_case)."""
        return cls(seed=seed, **{k: lam * v for k, v in _NOMINAL.items()})

    @classmethod
    def parse(cls, spec, seed: int = 0) -> "NoiseConfig":
        """``--noise`` surface: a preset name or a float sigma scale."""
        if isinstance(spec, (int, float)) and not isinstance(spec, bool):
            return cls.scaled(float(spec), seed=seed)
        s = str(spec).strip().lower()
        if s in PRESETS:
            return cls.preset(s, seed=seed)
        try:
            lam = float(s)
        except ValueError:
            raise ValueError(
                f"unknown noise spec {spec!r}: expected a preset "
                f"({sorted(PRESETS)}) or a float scale of the nominal "
                f"profile") from None
        return cls.scaled(lam, seed=seed)


def site_key(noise: NoiseConfig, tag: str, shape: tuple = ()) -> jax.Array:
    """Deterministic per-injection-site PRNG key.

    Pure in (seed, tag, shape): the tag names the physical site ("matmul_w",
    "decode_softmax", ...), the static shape dims distinguish differently
    sized arrays at the same site. No global state, no key threading — a
    noisy run is exactly reproducible from its NoiseConfig alone.
    """
    key = jax.random.PRNGKey(noise.seed)
    key = jax.random.fold_in(key, zlib.crc32(tag.encode()) & 0x7FFFFFFF)
    for dim in shape:
        key = jax.random.fold_in(key, int(dim))
    return key


def perturb_weight_codes(codes: jax.Array, noise: NoiseConfig,
                         key: jax.Array, bits: int = 8) -> jax.Array:
    """Crossbar conductance variation + stuck-at cells on stored weights.

    Works in the ISAAC unsigned offset domain the crossbar actually
    programs (`repro.core.crossbar` stores ``code + 2^(bits-1)`` as a
    conductance): Gaussian conductance spread of
    ``conductance_sigma * full_range`` codes, then ``stuck_rate`` of the
    cells pinned to G_min (stuck-off) or G_max (stuck-on), half each.
    Returns the perturbed signed codes; a no-op when both knobs are zero.
    """
    if noise.conductance_sigma <= 0.0 and noise.stuck_rate <= 0.0:
        return codes
    off = 1 << (bits - 1)
    top = (1 << bits) - 1
    u = codes.astype(jnp.int32) + off  # unsigned conductance domain
    kg, ks = jax.random.split(key)
    if noise.conductance_sigma > 0.0:
        g = jnp.round(noise.conductance_sigma * top
                      * jax.random.normal(kg, u.shape)).astype(jnp.int32)
        u = jnp.clip(u + g, 0, top)
    if noise.stuck_rate > 0.0:
        r = jax.random.uniform(ks, u.shape)
        u = jnp.where(r < noise.stuck_rate / 2, 0, u)  # stuck-off (G_min)
        u = jnp.where((r >= noise.stuck_rate / 2)
                      & (r < noise.stuck_rate), top, u)  # stuck-on (G_max)
    return (u - off).astype(codes.dtype)


def fault_rows(noise: NoiseConfig, key: jax.Array,
               n_rows: int) -> Optional[jax.Array]:
    """(n_rows,) bool mask of catastrophically faulted batch rows.

    Deterministic Bernoulli(``fault_rate``) per row; None when the rate is
    zero (the common case — presets never set it). The noisy attention
    decode backend NaNs out faulted rows, which is what the fail-safe
    serving path detects and retires.
    """
    if noise.fault_rate <= 0.0:
        return None
    return jax.random.uniform(key, (n_rows,)) < noise.fault_rate
