"""Deterministic synthetic LM data with learnable structure.

The stream mixes Markov bigram structure with induction-head patterns
(`A B ... A -> B`), so a small transformer trained on it shows a clear
accuracy signal — used by the Fig. 14 quantization study and the e2e
training example (no datasets ship in this container).

The iterator is stateful and *checkpointable*: `state()`/`set_state()` give
exact restore, and `skip(n)` fast-forwards after a restart. Sharding: each
data-parallel host takes its slice of the global batch.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLM"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int = 256
    seq_len: int = 128
    global_batch: int = 32
    seed: int = 0            # stream seed (varies train/eval)
    table_seed: int = 1234   # bigram-structure seed (fixed across splits)
    shard_index: int = 0
    shard_count: int = 1
    _step: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.table_seed)
        # sparse bigram transition table
        k = max(2, self.vocab_size // 64)  # small branching => clear top-1 signal
        self._succ = rng.integers(0, self.vocab_size,
                                  (self.vocab_size, k)).astype(np.int32)

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.shard_count == 0
        return self.global_batch // self.shard_count

    def state(self) -> dict:
        return {"step": self._step, "seed": self.seed}

    def set_state(self, st: dict):
        self._step = int(st["step"])

    def skip(self, n: int):
        self._step += int(n)

    def next_batch(self) -> dict:
        rng = np.random.default_rng(
            (self.seed, self._step, self.shard_index))
        self._step += 1
        B, S, V = self.local_batch, self.seq_len, self.vocab_size
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, B)
        choose = rng.integers(0, self._succ.shape[1], (B, S))
        for t in range(1, S):
            toks[:, t] = self._succ[toks[:, t - 1], choose[:, t]]
        # plant induction patterns: copy a random earlier bigram forward
        n_pat = max(1, S // 16)
        for b in range(B):
            starts = rng.integers(1, S - 2, n_pat)
            for s in starts:
                src = rng.integers(0, max(1, s - 1))
                toks[b, s] = toks[b, src]
                toks[b, min(s + 1, S - 1)] = toks[b, src + 1]
        return {"tokens": toks}

    def __iter__(self):
        while True:
            yield self.next_batch()
