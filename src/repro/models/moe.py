"""Mixture-of-Experts layer with explicit shard_map distribution.

Two distribution modes (DESIGN.md §4):

* **TP-in-expert** (default, works for any expert count): expert weights are
  sharded on d_ff over the "model" axis; every shard routes/dispatches its
  local tokens, computes partial expert outputs, combines locally, and a
  single psum over "model" finishes the row-parallel matmul.
* **EP** (`expert_parallel=True`, experts % model_size == 0): experts are
  sharded over "model"; capacity-dispatched token blocks are exchanged with
  two all_to_alls (dispatch + return) and no psum is needed.

Routing is token-choice top-k with a static capacity
C = ceil(k * T_local * capacity_factor / E); overflow tokens drop (their
residual path passes through), underflow slots compute on zeros.
The router softmax goes through the Compute-ACAM softmax dataflow in raceit
mode — the paper's reconfigurability claim applied to a post-paper layer type.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ExecConfig, ModelConfig
from repro.dist.sharding import MeshContext, shard_map
from repro.exec.plan import ExecPlan, as_plan
from jax.sharding import PartitionSpec as P

from . import layers

Params = dict


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": layers._dense_init(ks[0], (D, E), jnp.float32),
        "w1": layers._dense_init(ks[1], (E, D, F), dtype),
        "w2": layers._dense_init(ks[2], (E, F, D), dtype, fan_in=F),
    }
    if cfg.glu:
        p["w3"] = layers._dense_init(ks[3], (E, D, F), dtype)
    return p


def _moe_local(p, x, cfg: ModelConfig, plan: ExecPlan, axis: Optional[str],
               tp_size: int):
    """Per-shard MoE body. x: (B_l, S, D). axis: model axis name (or None)."""
    Bl, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = Bl * S
    xf = x.reshape(T, D)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    # the router softmax goes through the plan's softmax slot — the ACAM
    # dataflow in raceit mode, the paper's reconfigurability claim applied
    # to a post-paper layer type
    probs = plan.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, K)  # (T, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # --- capacity-based dispatch (static C) ---
    C = max(1, int(-(-K * T * cfg.capacity_factor // E)))
    e_flat = expert.reshape(-1)  # (T*K,) token-major
    # rank of each (token, k) within its expert, via stable sort
    order = jnp.argsort(e_flat, stable=True)
    sorted_e = e_flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank_sorted = jnp.arange(T * K, dtype=jnp.int32) - starts[sorted_e]
    rank = jnp.zeros((T * K,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < C
    slot = jnp.where(keep, e_flat * C + rank, E * C)  # E*C = drop bin

    token_id = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    disp = jnp.zeros((E * C + 1, D), x.dtype).at[slot].set(xf[token_id])
    disp = disp[:-1].reshape(E, C, D)

    if cfg.expert_parallel and axis is not None and tp_size > 1:
        # EP: exchange expert-blocks so each shard holds its own experts' tokens.
        disp = jax.lax.all_to_all(disp, axis, split_axis=0, concat_axis=1, tiled=True)
    w1, w2 = p["w1"], p["w2"]
    h = jnp.einsum("ecd,edf->ecf", disp, w1.astype(disp.dtype),
                   preferred_element_type=jnp.float32).astype(disp.dtype)
    h = plan.activation(h, cfg.activation)
    if "w3" in p:
        h = h * jnp.einsum("ecd,edf->ecf", disp, p["w3"].astype(disp.dtype),
                           preferred_element_type=jnp.float32).astype(disp.dtype)
    y_e = jnp.einsum("ecf,efd->ecd", h, w2.astype(h.dtype),
                     preferred_element_type=jnp.float32).astype(disp.dtype)
    if cfg.expert_parallel and axis is not None and tp_size > 1:
        y_e = jax.lax.all_to_all(y_e, axis, split_axis=1, concat_axis=0, tiled=True)

    # --- combine: gather each (token, k) slot's output, weight, and sum ---
    y_pad = jnp.concatenate([y_e.reshape(E * C, D),
                             jnp.zeros((1, D), y_e.dtype)], 0)
    per_choice = y_pad[slot] * (gate.reshape(-1) * keep)[:, None].astype(y_e.dtype)
    y = per_choice.reshape(T, K, D).sum(axis=1)

    if (not cfg.expert_parallel) and axis is not None and tp_size > 1:
        y = jax.lax.psum(y, axis)  # finish the row-parallel (d_ff-sharded) matmul
    return y.reshape(Bl, S, D)


def moe(p: Params, x: jax.Array, cfg: ModelConfig,
        plan: "ExecPlan | ExecConfig",
        mesh_ctx: Optional[MeshContext]) -> jax.Array:
    """Dispatching wrapper: shard_map over the mesh, or plain local call."""
    plan = as_plan(cfg, plan)
    if mesh_ctx is None or mesh_ctx.mesh is None:
        return _moe_local(p, x, cfg, plan, axis=None, tp_size=1)

    mesh = mesh_ctx.mesh
    model = mesh_ctx.model_axis if mesh_ctx.model_size > 1 else None
    dp = mesh_ctx.present_dp_axes
    batch_spec = dp if (dp and x.shape[0] % mesh_ctx.dp_size == 0) else None

    if cfg.expert_parallel and model is not None:
        # EP: also shard the sequence over "model" so each shard dispatches a
        # distinct token slice (otherwise the exchanged blocks are replicas and
        # expert FFNs run model_size-times redundantly — decode S=1 accepts it).
        seq_spec = model if x.shape[1] % mesh_ctx.model_size == 0 else None
        x_spec = P(batch_spec, seq_spec, None)
        w_specs = {"router": P(None, None), "w1": P(model, None, None),
                   "w2": P(model, None, None)}
        if "w3" in p:
            w_specs["w3"] = P(model, None, None)
    else:
        x_spec = P(batch_spec, None, None)
        w_specs = {"router": P(None, None), "w1": P(None, None, model),
                   "w2": P(None, model, None)}
        if "w3" in p:
            w_specs["w3"] = P(None, None, model)

    fn = partial(_moe_local, cfg=cfg, plan=plan, axis=model,
                 tp_size=mesh_ctx.model_size)
    return shard_map(
        fn, mesh=mesh, in_specs=(w_specs, x_spec), out_specs=x_spec,
        check_vma=False,
    )(p, x)
