"""Top-level model assembly: decoder LMs, encoder-only (BERT), enc-dec (Whisper).

Public API:

    model = Model(cfg, exec_cfg, mesh_ctx)
    params = model.init(rng)
    logits = model.forward(params, batch)                  # train / scoring
    cache = model.init_cache(batch_size, max_len)
    logits, cache = model.prefill(params, tokens, cache)
    logits, cache = model.decode_step(params, token, cache)

`batch` for forward is a dict: {"tokens": (B,S) int32, optional "positions",
optional "enc_feats": (B, enc_len, d_model) for stub-frontend models}.
All functions are pure and jit/pjit-friendly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ExecConfig, ModelConfig
from repro.dist.sharding import MeshContext, constraint
from repro.exec.plan import ExecPlan, as_plan

from . import blocks, layers

Params = dict

# weight leaves that live on crossbars as int8 conductance codes when serving
_QUANTIZABLE = {"wq", "wk", "wv", "wo", "w1", "w2", "w3", "unembed",
                "w_z", "w_x", "w_B", "w_C", "w_dt", "out_proj"}


def quantize_model_params(params: Params) -> Params:
    """Convert weight matrices to resident int8 codes + per-column scales
    (the paper's deployment form: weights ARE the crossbar conductances).

    Stacked scan leaves (R, K, ...) quantize per layer: codes (R, K, N),
    scale (R, 1, N); the scan slices them to exactly what _linear consumes.
    """
    def q2d(leaf, stacked: bool, name: str):
        arr = jnp.asarray(leaf, jnp.float32)
        if name == "wo":  # contraction spans (heads, head_dim)
            if stacked:
                arr = arr.reshape(arr.shape[0], -1, arr.shape[-1])
            else:
                arr = arr.reshape(-1, arr.shape[-1])
        if stacked:
            flat = arr.reshape(arr.shape[0], arr.shape[1], -1)  # (R, K, N)
            amax = jnp.max(jnp.abs(flat), axis=1, keepdims=True)
            shape = tuple(arr.shape[2:])
        else:
            flat = arr.reshape(arr.shape[0], -1)                # (K, N)
            amax = jnp.max(jnp.abs(flat), axis=0, keepdims=True)
            shape = tuple(arr.shape[1:])
        scale = jnp.maximum(amax, 1e-12) / 127.0
        codes = jnp.clip(jnp.round(flat / scale), -128, 127).astype(jnp.int8)
        return layers.QuantizedWeight(codes, scale.astype(jnp.float32), shape)

    def walk(tree, stacked=False):
        if isinstance(tree, dict):
            out = {}
            for name, leaf in tree.items():
                if (name in _QUANTIZABLE and hasattr(leaf, "ndim")
                        and leaf.ndim >= (3 if stacked else 2)):
                    out[name] = q2d(leaf, stacked, name)
                elif name == "moe":
                    out[name] = leaf  # expert einsums keep bf16 (DESIGN §7)
                elif name == "scan":
                    out[name] = [walk(x, stacked=True) for x in leaf]
                else:
                    out[name] = walk(leaf, stacked)
            return out
        if isinstance(tree, list):
            return [walk(x, stacked) for x in tree]
        if isinstance(tree, tuple):
            return tuple(walk(x, stacked) for x in tree)
        return tree

    return walk(params)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def map_cache_idx(cache, fn):
    """Apply ``fn`` to every ``idx`` leaf of a cache pytree.

    ``idx`` leaves are the per-layer write indices (`blocks.init_layer_cache`
    puts one in every attention cache dict); scan-stacked layers carry a
    leading repetition dim on theirs. Used to turn a fresh cache into a
    slot pool (scalar idx -> (n_slots,) vectors) and by the continuous
    batcher's row scatter.
    """
    def walk(t):
        if isinstance(t, dict):
            return {k: (fn(v) if k == "idx" else walk(v))
                    for k, v in t.items()}
        if isinstance(t, list):
            return [walk(x) for x in t]
        if isinstance(t, tuple):
            return tuple(walk(x) for x in t)
        return t
    return walk(cache)


class Model:
    def __init__(self, cfg: ModelConfig,
                 exec_cfg: "ExecConfig | ExecPlan" = ExecConfig(),
                 mesh_ctx: Optional[MeshContext] = None):
        self.cfg = cfg
        # resolve the operator dispatch table once: every layer call below
        # goes through self.plan's slot methods, never through mode branches
        self.plan = as_plan(cfg, exec_cfg)
        self.exec_cfg = self.plan.exec_cfg
        self.mesh_ctx = mesh_ctx

    # ------------------------------------------------------------------ init
    def init(self, rng) -> Params:
        cfg = self.cfg
        dtype = _dtype(cfg.param_dtype)
        ks = jax.random.split(rng, 6)
        p: Params = {"embed": layers.init_embeddings(ks[0], cfg, dtype),
                     "final_norm": layers.init_norm(cfg, dtype)}
        if cfg.is_encoder_decoder:
            enc_cfg = cfg.replace(causal=False, mixer_pattern=("attn",),
                                  ffn_pattern=("dense",))
            p["encoder"] = blocks.init_stack(ks[1], enc_cfg, dtype,
                                             n_layers=cfg.n_encoder_layers)
            p["enc_norm"] = layers.init_norm(cfg, dtype)
            p["decoder"] = blocks.init_stack(ks[2], cfg, dtype, cross=True)
        else:
            p["blocks"] = blocks.init_stack(ks[1], cfg, dtype)
        return p

    # ------------------------------------------------------------- internals
    def _positions(self, tokens: jax.Array, offset=0) -> jax.Array:
        b, s = tokens.shape[:2]
        return jnp.broadcast_to(jnp.arange(s) + offset, (b, s))

    def _encode(self, params: Params, enc_feats: jax.Array) -> jax.Array:
        """Whisper encoder over stub-frontend frame embeddings."""
        enc_cfg = self.cfg.replace(causal=False, mixer_pattern=("attn",),
                                   ffn_pattern=("dense",))
        pos = self._positions(enc_feats[..., 0])
        x = enc_feats.astype(_dtype(self.cfg.compute_dtype))
        x, _ = blocks.apply_stack(params["encoder"], x, cfg=enc_cfg,
                                  plan=self.plan, positions=pos,
                                  caches=None, mesh_ctx=self.mesh_ctx,
                                  n_layers=self.cfg.n_encoder_layers)
        return layers.apply_norm(params["enc_norm"], x, self.cfg)

    def _enc_kv(self, params: Params, enc_out: jax.Array) -> list:
        """Per-decoder-layer cross K/V from the encoder output."""
        kvs = []
        for t in range(self.cfg.n_layers):
            lp = self._decoder_layer_params(params, t)["cross"]
            k = layers._linear(enc_out, lp["wk"], self.plan, lp.get("bk"))
            v = layers._linear(enc_out, lp["wv"], self.plan, lp.get("bv"))
            kvs.append((k, v))
        return kvs

    def _decoder_layer_params(self, params: Params, t: int) -> Params:
        P, n_full, _ = blocks.layer_plan(self.cfg)
        if t < n_full * P:
            r, j = divmod(t, P)
            return jax.tree.map(lambda a: a[r], params["decoder"]["scan"][j])
        return params["decoder"]["tail"][t - n_full * P]

    def _trunk(self, params: Params, tokens, positions, caches, enc_feats,
               use_remat: bool, pad_lens=None, pad_prompt_len=None,
               slot_lens=None, block_table=None, page_size=None,
               chunk_offs=None):
        cfg = self.cfg
        x = layers.embed(params["embed"], tokens,
                         positions if positions.ndim == 2 else positions[0], cfg)
        x = x.astype(_dtype(cfg.compute_dtype))

        if cfg.is_encoder_decoder:
            if caches is not None and "enc_kv" in caches:
                enc_kv = caches["enc_kv"]  # cached cross K/V (prefill/decode)
            else:
                enc_out = self._encode(params, enc_feats)
                enc_kv = self._enc_kv(params, enc_out)
            # decoder: unrolled (whisper is 4L), cross-attn per layer
            new_tail = []
            dec_caches = caches["dec"] if caches is not None else None
            for t in range(cfg.n_layers):
                lp = self._decoder_layer_params(params, t)
                mixer, ffn_kind = cfg.layer_spec(t)
                cache_t = dec_caches[t] if dec_caches is not None else None
                x, nc = blocks.apply_layer(
                    lp, x, cfg=cfg, plan=self.plan, mixer=mixer,
                    ffn_kind=ffn_kind, positions=positions,
                    cache=cache_t if cache_t else None, mesh_ctx=self.mesh_ctx,
                    enc_kv=enc_kv[t], pad_lens=pad_lens,
                    pad_prompt_len=pad_prompt_len, slot_lens=slot_lens)
                new_tail.append(nc if nc is not None else {})
            new_caches = ({"dec": new_tail, "enc_kv": enc_kv}
                          if caches is not None else None)
        else:
            x, new_caches = blocks.apply_stack(
                params["blocks"], x, cfg=cfg, plan=self.plan,
                positions=positions, caches=caches, mesh_ctx=self.mesh_ctx,
                use_remat=use_remat, pad_lens=pad_lens,
                pad_prompt_len=pad_prompt_len, slot_lens=slot_lens,
                block_table=block_table, page_size=page_size,
                chunk_offs=chunk_offs)

        x = layers.apply_norm(params["final_norm"], x, cfg)
        return x, new_caches

    # ---------------------------------------------------------------- public
    def forward(self, params: Params, batch: dict, use_remat: bool = True):
        tokens = batch["tokens"]
        positions = batch.get("positions")
        if positions is None:
            positions = self._positions(tokens)
        x, _ = self._trunk(params, tokens, positions, None,
                           batch.get("enc_feats"), use_remat)
        return layers.unembed(params["embed"], x, self.cfg, self.plan)

    def init_slot_cache(self, n_slots: int, max_len: int, dtype=None,
                        page_size: Optional[int] = None,
                        n_pages: Optional[int] = None) -> Params:
        """A fixed-shape *slot-pool* cache for continuous batching.

        Identical buffers to `init_cache`, but every per-layer ``idx``
        leaf is a (n_slots,) vector — one independent write index per
        slot — so `decode_step` writes each row's new k/v at its own
        column and slots fill/retire independently
        (`repro.serve.continuous.ContinuousBatcher` owns the lifecycle).

        ``page_size``/``n_pages`` switch every attention layer's buffers
        to the block-paged pool form (`blocks.init_layer_cache`): k/v
        become an (n_pages, page_size, KV, hd) page pool shared by all
        slots, addressed through the (n_slots, max_pages) block table the
        serving layer owns and threads into `decode_step` /
        `prefill_chunk`. ``max_len`` then only documents intent — capacity
        is ``(n_pages - 1) * page_size`` pooled across slots (page 0 is
        the trash page), which is the point: memory follows actual fill,
        not n_slots x max_len. Raises for stacks with non-attention or
        local mixers (their state has no paged form).
        """
        if self.cfg.is_encoder_decoder:
            raise NotImplementedError(
                "slot-pool caches cover decoder-only stacks; encoder-"
                "decoder serving stays on bucketed batching")
        if page_size is not None:
            # paged caches are born per-slot: idx is already (n_slots,)
            return blocks.init_stack_cache(
                self.cfg, n_slots, max_len,
                dtype or _dtype(self.cfg.compute_dtype),
                page_size=page_size, n_pages=n_pages)
        cache = self.init_cache(n_slots, max_len, dtype)
        vec = lambda a: jnp.broadcast_to(a[..., None],
                                         a.shape + (n_slots,)).copy()
        return map_cache_idx(cache, vec)

    def init_cache(self, batch: int, max_len: int, dtype=None) -> Params:
        cfg = self.cfg
        dtype = dtype or _dtype(cfg.compute_dtype)
        if cfg.is_encoder_decoder:
            hd = cfg.resolved_head_dim
            dec = [blocks.init_layer_cache(cfg, cfg.layer_spec(t)[0], batch,
                                           max_len, dtype) or {}
                   for t in range(cfg.n_layers)]
            enc_kv = [(jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads, hd), dtype),
                       jnp.zeros((batch, cfg.encoder_len, cfg.n_kv_heads, hd), dtype))
                      for _ in range(cfg.n_layers)]
            return {"dec": dec, "enc_kv": enc_kv}
        return blocks.init_stack_cache(cfg, batch, max_len, dtype)

    def prefill(self, params: Params, tokens: jax.Array, cache: Params,
                enc_feats=None, positions=None, pad_lens=None):
        """Process the prompt; returns last-position logits + filled cache.

        ``pad_lens`` (B,) int32: per-row left-pad prefix lengths (batched
        serving buckets). Real tokens then sit at positions shifted down by
        their row's pad count (pad rows are clipped to position 0 — their
        outputs are masked out of every real row's attention anyway), and
        attention masks the pad columns per row, so a request's prefill is
        independent of its bucket-mates. The last column is a real token
        for every row by construction (left-padding), so the returned
        last-position logits are per-request first-token logits.
        """
        if positions is None:
            positions = self._positions(tokens)
            if pad_lens is not None:
                positions = jnp.maximum(
                    positions - pad_lens[:, None].astype(jnp.int32), 0)
        if self.cfg.is_encoder_decoder and enc_feats is not None:
            enc_out = self._encode(params, enc_feats)
            cache = dict(cache, enc_kv=[
                (k.astype(c[0].dtype), v.astype(c[1].dtype))
                for (k, v), c in zip(self._enc_kv(params, enc_out), cache["enc_kv"])])
        x, new_cache = self._trunk(params, tokens, positions, cache, None,
                                   False, pad_lens=pad_lens)
        logits = layers.unembed(params["embed"], x[:, -1:], self.cfg, self.plan)
        return logits, new_cache

    def decode_step(self, params: Params, token: jax.Array, cache: Params,
                    pad_lens=None, pad_prompt_len=None, slot_lens=None,
                    block_table=None, page_size=None):
        """token: (B, 1). Returns (logits (B,1,V), cache).

        Each attention layer's decode step runs whatever backend the plan
        resolved for the ``attention_decode`` slot — the serving default
        (`ExecConfig.serving()`) is ``raceit_gqa_rows`` when the config
        shares KV heads (``n_kv_heads < n_heads``), else
        ``raceit_fused_rows``; both stream each row's valid cache prefix
        in one kernel pass (`layers._raceit_gqa_decode` /
        `layers._raceit_fused_decode`), and ``plan.explain()`` names the
        backend and any degrade reason. ``pad_lens`` (B,) keeps
        left-padded rows at their true positions and masks their pad cache
        slots; ``pad_prompt_len`` (the padded prompt length — scalar for a
        bucket, (B,) for slot pools) lets layers whose ring buffer the
        prompt overflowed drop the slot-space pad mask (the last-L prefill
        broke the slot == column mapping it relies on).

        ``slot_lens`` (B,) int32 drives slot-level continuous batching
        (`repro.serve.continuous`): entry b is the number of valid cache
        columns for row b *including the token decoded this step* (0 = an
        empty slot whose row is dead), so each slot decodes at its own
        fill level against a per-slot-``idx`` cache
        (`Model.init_slot_cache`) and the pool's shapes — hence the
        compiled executable — never change as requests come and go.

        ``block_table`` (B, max_pages) int32 + static ``page_size`` address
        a block-paged slot cache (`init_slot_cache(page_size=..., ...)`):
        row b's logical cache column c lives at pool page
        ``block_table[b, c // page_size]`` — one table for the whole stack.
        Requires ``slot_lens``; the paged decode backends
        (``raceit_*_paged``) follow the indirection in-kernel, anything
        else is served by gathering pages to contiguous rows.
        """
        if slot_lens is not None:
            # per-slot positions: the new token's index among the row's
            # real tokens (pads excluded below); empty slots clamp to 0
            idx = jnp.maximum(jnp.asarray(slot_lens, jnp.int32)[:, None] - 1,
                              0)
            positions = jnp.broadcast_to(idx, token.shape)
        else:
            idx = self._cache_index(cache)
            positions = jnp.broadcast_to(idx, token.shape).astype(jnp.int32)
        if pad_lens is not None:
            positions = jnp.maximum(
                positions - pad_lens[:, None].astype(jnp.int32), 0)
        x, new_cache = self._trunk(params, token, positions, cache, None,
                                   False, pad_lens=pad_lens,
                                   pad_prompt_len=pad_prompt_len,
                                   slot_lens=slot_lens,
                                   block_table=block_table,
                                   page_size=page_size)
        logits = layers.unembed(params["embed"], x, self.cfg, self.plan)
        return logits, new_cache

    def prefill_chunk(self, params: Params, tokens: jax.Array, cache: Params,
                      chunk_offs, chunk_lens, block_table, page_size):
        """Stream one prompt chunk per slot into a block-paged cache.

        tokens: (B, C) — row b carries ``chunk_lens[b]`` prompt tokens
        destined for logical cache columns [chunk_offs[b], chunk_offs[b] +
        chunk_lens[b]); columns past the feed are garbage padding whose
        cache writes route to the trash page. C is the *pinned* chunk
        width: every admission streams through the same (B, C) call, so
        chunked prefill adds exactly one compiled executable regardless of
        prompt length (Sarathi-style prefill/decode interleave without
        shape churn). A row with ``chunk_lens[b] == 0`` does not
        participate (its block-table row should be all trash, its output
        row is garbage).

        Returns (logits (B, 1, V), cache): row b's logits are taken at its
        last fed position — meaningful only for rows whose chunk completes
        their prompt (they are that request's first-token logits, the
        chunked analog of `prefill`'s last-column logits).
        """
        offs = jnp.asarray(chunk_offs, jnp.int32)
        feed = jnp.asarray(chunk_lens, jnp.int32)
        positions = offs[:, None] + jnp.broadcast_to(
            jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
        x, new_cache = self._trunk(params, tokens, positions, cache, None,
                                   False, slot_lens=offs + feed,
                                   block_table=block_table,
                                   page_size=page_size, chunk_offs=offs)
        last = jnp.maximum(feed - 1, 0)[:, None, None]
        x_last = jnp.take_along_axis(x, jnp.broadcast_to(
            last, (x.shape[0], 1, x.shape[2])), axis=1)
        logits = layers.unembed(params["embed"], x_last, self.cfg, self.plan)
        return logits, new_cache

    def _cache_index(self, cache: Params):
        leaves = jax.tree_util.tree_leaves(
            jax.tree.map(lambda d: d.get("idx", None) if isinstance(d, dict) else None,
                         cache, is_leaf=lambda d: isinstance(d, dict) and "idx" in d))
        for leaf in leaves:
            if leaf is not None:
                return jnp.max(leaf) if getattr(leaf, "ndim", 0) else leaf
        return jnp.zeros((), jnp.int32)

    # --------------------------------------------------------------- loss
    def loss_fn(self, params: Params, batch: dict, use_remat: bool = True):
        """Next-token cross entropy (mean over non-masked tokens)."""
        logits = self.forward(params, batch, use_remat=use_remat)
        tokens = batch["tokens"]
        if self.cfg.causal:
            targets = tokens[:, 1:]
            logits = logits[:, :-1]
        else:  # encoder-only: masked-token style (predict identity here)
            targets = tokens
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones_like(targets, jnp.float32)
        else:
            mask = mask[:, -targets.shape[1]:].astype(jnp.float32)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
