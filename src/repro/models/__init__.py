from .model import Model  # noqa: F401
from . import layers, blocks, moe, ssm  # noqa: F401
