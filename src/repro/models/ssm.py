"""Mamba-2 (SSD, state-space duality) mixer — chunked matmul form + O(1) decode.

The SSD recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t, y_t = C_t h_t is
evaluated in the chunkwise-parallel matmul form of arXiv:2405.21060 (intra-
chunk "attention-like" term + inter-chunk state recurrence), which maps onto
the MXU. Decode uses the constant-memory recurrent update.

Projection weights are stored per-component (w_z/w_x/w_B/w_C/w_dt) so the
head-major d_inner dimensions shard cleanly over the "model" axis (TP).

RACE-IT applicability (DESIGN.md §5): the in/out projections are crossbar
MVMs; softplus/exp gating and the data-dependent chunk matmuls are exactly
Compute-ACAM 1-var / 2-var ops, so `raceit` mode quantizes them the same way.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ExecConfig, ModelConfig
from repro.dist.sharding import constraint, current_policy
from repro.exec.plan import ExecPlan, as_plan

from . import layers

Params = dict


def init_mamba(key, cfg: ModelConfig, dtype) -> Params:
    D = cfg.d_model
    d_in, H, N, G = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    ks = jax.random.split(key, 8)
    dt = jnp.exp(jax.random.uniform(ks[5], (H,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    return {
        "w_z": layers._dense_init(ks[0], (D, d_in), dtype),
        "w_x": layers._dense_init(ks[1], (D, d_in), dtype),
        "w_B": layers._dense_init(ks[2], (D, G * N), dtype),
        "w_C": layers._dense_init(ks[3], (D, G * N), dtype),
        "w_dt": layers._dense_init(ks[4], (D, H), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),  # inv softplus
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32) % 15 + 1.0),
        "ssm_D": jnp.ones((H,), jnp.float32),
        # identity-at-current-tap init so signal passes at step 0
        "conv_x": jnp.zeros((cfg.conv_width, d_in), dtype).at[-1].set(1.0),
        "conv_B": jnp.zeros((cfg.conv_width, G * N), dtype).at[-1].set(1.0),
        "conv_C": jnp.zeros((cfg.conv_width, G * N), dtype).at[-1].set(1.0),
        "norm_scale": jnp.ones((d_in,), dtype),
    }


def _causal_conv_simple(x, w, state):
    """Depthwise causal conv via explicit shifted sums (W is tiny)."""
    W = w.shape[0]
    if state is None:
        ctx = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    new_state = ctx[:, -(W - 1):, :] if W > 1 else None
    S = x.shape[1]
    y = sum(ctx[:, i : i + S, :] * w[i].astype(x.dtype) for i in range(W))
    return y, new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, init_state=None):
    """Chunkwise SSD. xh (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N).

    Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    Bsz, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:  # zero-pad: dt=0 makes padded steps identity (no state update)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_pad = S + pad
    nc = S_pad // L
    rep = H // G

    # TP inside SSD: shard heads over "model" when divisible, otherwise the
    # independent chunks dim (intra-chunk L^2 tensors are the memory hot spot).
    pol = current_policy()
    msz = pol.axes_size(pol.mesh_axes("heads")) if (pol and pol.mesh) else 1
    use_heads = msz > 1 and H % msz == 0
    hax = "heads" if use_heads else None
    cax = None if use_heads else "chunks"

    xc = constraint(xh.reshape(Bsz, nc, L, H, Pd), "batch", cax, None, hax, None)
    dtc = constraint(dt.reshape(Bsz, nc, L, H), "batch", cax, None, hax
                     ).astype(jnp.float32)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, L, G, N), rep, axis=3)  # (B,nc,L,H,N)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, L, G, N), rep, axis=3)
    Bc = constraint(Bc, "batch", cax, None, hax, None)
    Cc = constraint(Cc, "batch", cax, None, hax, None)

    dA = dtc * A  # (B,nc,L,H), negative
    cum = jnp.cumsum(dA, axis=2)

    # --- intra-chunk (attention-like, masked by causal decay) ---
    CB = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc,
                    preferred_element_type=jnp.float32)
    # decay[l,s] = exp(cum_l - cum_s), lower-triangular
    cl = cum.transpose(0, 1, 3, 2)  # (B,nc,H,L)
    dmat = jnp.exp(jnp.clip(cl[..., :, None] - cl[..., None, :], -60.0, 0.0))
    mask = jnp.tril(jnp.ones((L, L), bool))
    att = CB * jnp.where(mask, dmat, 0.0) * dtc.transpose(0, 1, 3, 2)[..., None, :]
    y_intra = jnp.einsum("bchls,bcshp->bclhp", att.astype(xh.dtype), xc,
                         preferred_element_type=jnp.float32)

    # --- per-chunk states and inter-chunk recurrence ---
    decay_end = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, -60.0, 0.0))  # (B,nc,L,H)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bc.astype(jnp.float32),
                        (dtc * decay_end), xc.astype(jnp.float32))
    chunk_decay = jnp.exp(jnp.clip(cum[:, :, -1, :], -60.0, 0.0))  # (B,nc,H)

    s0 = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def scan_fn(s_prev, xs):
        st_c, cd_c = xs
        s_new = s_prev * cd_c[..., None, None] + st_c
        return s_new, s_prev

    (s_final, states_in) = jax.lax.scan(
        scan_fn, s0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    states_in = states_in.swapaxes(0, 1)  # (B,nc,H,P,N) state entering chunk

    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc.astype(jnp.float32),
                         states_in, jnp.exp(jnp.clip(cum, -60.0, 0.0)))
    y = (y_intra + y_inter).reshape(Bsz, S_pad, H, Pd)[:, :S]
    return y.astype(xh.dtype), s_final


def mamba(p: Params, x: jax.Array, *, cfg: ModelConfig,
          plan: "ExecPlan | ExecConfig",
          cache: Optional[Params] = None) -> tuple[jax.Array, Optional[Params]]:
    """Mamba-2 mixer. cache = {"state","conv_x","conv_B","conv_C"} for decode.

    Projections dispatch through the plan's matmul slot (int8 crossbar
    matmuls in raceit mode); the SSD scan itself stays float.
    """
    plan = as_plan(cfg, plan)
    Bsz, S, _ = x.shape
    H, Pd, N, G = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_groups

    z = layers._linear(x, p["w_z"], plan)
    xs = layers._linear(x, p["w_x"], plan)
    Bv = layers._linear(x, p["w_B"], plan)
    Cv = layers._linear(x, p["w_C"], plan)
    dt_raw = layers._linear(x, p["w_dt"], plan).astype(jnp.float32)

    xs, cs_x = _causal_conv_simple(xs, p["conv_x"], cache["conv_x"] if cache else None)
    Bv, cs_B = _causal_conv_simple(Bv, p["conv_B"], cache["conv_B"] if cache else None)
    Cv, cs_C = _causal_conv_simple(Cv, p["conv_C"], cache["conv_C"] if cache else None)
    xs, Bv, Cv = (jax.nn.silu(xs), jax.nn.silu(Bv), jax.nn.silu(Cv))

    xh = constraint(xs.reshape(Bsz, S, H, Pd), "batch", None, "heads", "headdim")
    Bm = Bv.reshape(Bsz, S, G, N)
    Cm = Cv.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)

    if S == 1 and cache is not None:
        # recurrent decode step
        s_prev = cache["state"].astype(jnp.float32)  # (B,H,P,N)
        dt1 = dt[:, 0]  # (B,H)
        dA1 = jnp.exp(dt1 * A)  # (B,H)
        B1 = jnp.repeat(Bm[:, 0], H // G, axis=1)  # (B,H,N)
        C1 = jnp.repeat(Cm[:, 0], H // G, axis=1)
        x1 = xh[:, 0].astype(jnp.float32)  # (B,H,P)
        s_new = (s_prev * dA1[..., None, None]
                 + jnp.einsum("bh,bhn,bhp->bhpn", dt1, B1.astype(jnp.float32), x1))
        y = jnp.einsum("bhn,bhpn->bhp", C1.astype(jnp.float32), s_new)
        y = y[:, None].astype(x.dtype)  # (B,1,H,P)
        state = s_new
    else:
        init_state = cache["state"] if cache is not None else None
        y, state = _ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)

    y = y + xh * p["ssm_D"][:, None].astype(x.dtype)
    y = y.reshape(Bsz, S, cfg.d_inner)

    # gated RMSNorm (mamba2's norm-before-out-proj)
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = g * jax.lax.rsqrt(jnp.mean(g * g, -1, keepdims=True) + 1e-6)
    y = (g * p["norm_scale"].astype(jnp.float32)).astype(x.dtype)

    out = layers._linear(y, p["out_proj"], plan)
    new_cache = None
    if cache is not None:
        new_cache = {"state": state.astype(cache["state"].dtype),
                     "conv_x": cs_x, "conv_B": cs_B, "conv_C": cs_C}
    return out, new_cache


def init_mamba_with_out(key, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = init_mamba(k1, cfg, dtype)
    p["out_proj"] = layers._dense_init(k2, (cfg.d_inner, cfg.d_model), dtype,
                                       fan_in=cfg.d_inner)
    return p
