"""BlockPattern machinery: heterogeneous layer stacks under jax.lax.scan.

A config's (mixer_pattern, ffn_pattern) defines a repeating *period* of P
layers (jamba: P=8 with one attention + 7 mamba and MoE every 2nd; gemma3:
P=6 with 5 local + 1 global). The stack is executed as

    scan over n_full = n_layers // P repetitions of the period
      (each period position has its params stacked along the scan dim)
    + an unrolled tail of n_layers % P layers

which keeps HLO size O(P) instead of O(n_layers) — essential when lowering
at 512 devices — while preserving the exact layer ordering.
Caches thread through the scan as per-position stacked pytrees.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ExecConfig, ModelConfig
from repro.dist.sharding import MeshContext
from repro.exec.plan import ExecPlan, as_plan
from repro.exec.plan import layer_plan as _mixer_plan

from repro.dist.sharding import constraint

from . import layers, moe as moe_mod, ssm

Params = dict


# --------------------------------------------------------------------------
# single layer
# --------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, mixer: str, ffn_kind: str, dtype,
               cross: bool = False) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": layers.init_norm(cfg, dtype)}
    if mixer in ("attn", "attn_local"):
        p["attn"] = layers.init_attention(ks[0], cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = ssm.init_mamba_with_out(ks[0], cfg, dtype)
    if cross:
        p["norm_x"] = layers.init_norm(cfg, dtype)
        p["cross"] = layers.init_attention(ks[2], cfg, dtype)
    if ffn_kind == "dense":
        p["norm2"] = layers.init_norm(cfg, dtype)
        p["ffn"] = layers.init_ffn(ks[1], cfg, dtype)
    elif ffn_kind == "moe":
        p["norm2"] = layers.init_norm(cfg, dtype)
        p["moe"] = moe_mod.init_moe(ks[1], cfg, dtype)
    return p


def apply_layer(p: Params, x: jax.Array, *, cfg: ModelConfig,
                plan: ExecPlan | ExecConfig, mixer: str, ffn_kind: str,
                positions: jax.Array, cache: Optional[Params],
                mesh_ctx: Optional[MeshContext],
                enc_kv: Optional[tuple] = None,
                pad_lens: Optional[jax.Array] = None,
                pad_prompt_len: Optional[jax.Array] = None,
                slot_lens: Optional[jax.Array] = None,
                block_table: Optional[jax.Array] = None,
                page_size: Optional[int] = None,
                chunk_offs: Optional[jax.Array] = None,
                ) -> tuple[jax.Array, Any]:
    plan = as_plan(cfg, plan)
    # per-mixer-kind plan overrides (ExecConfig.layer_overrides): e.g. pin
    # sliding-window "attn_local" layers to the staged path while global
    # "attn" layers stay fused — resolved through the same lru-cached
    # resolve_plan, so this is a dict lookup per trace, not per step
    plan = _mixer_plan(plan, mixer)
    h = layers.apply_norm(p["norm1"], x, cfg)
    if mixer in ("attn", "attn_local"):
        m, new_cache = layers.attention(
            p["attn"], h, cfg=cfg, plan=plan, positions=positions,
            local=(mixer == "attn_local"),
            cache=cache.get("attn") if cache else None, pad_lens=pad_lens,
            pad_prompt_len=pad_prompt_len, slot_lens=slot_lens,
            block_table=block_table, page_size=page_size,
            chunk_offs=chunk_offs)
        if cache is not None:
            new_cache = {"attn": new_cache}
    elif mixer == "mamba":
        m, new_cache = ssm.mamba(p["mamba"], h, cfg=cfg, plan=plan,
                                 cache=cache.get("mamba") if cache else None)
        if cache is not None:
            new_cache = {"mamba": new_cache}
    else:
        raise ValueError(mixer)
    x = x + m

    if "cross" in p and enc_kv is not None:
        hx = layers.apply_norm(p["norm_x"], x, cfg)
        cx, _ = layers.attention(p["cross"], hx, cfg=cfg, plan=plan,
                                 positions=positions, cross_kv=enc_kv)
        x = x + cx

    if ffn_kind == "dense":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        x = x + layers.ffn(p["ffn"], h2, cfg, plan)
    elif ffn_kind == "moe":
        h2 = layers.apply_norm(p["norm2"], x, cfg)
        x = x + moe_mod.moe(p["moe"], h2, cfg, plan, mesh_ctx)
    # sequence-parallel residual stream: the carried activation (and thus the
    # remat stash) lives sharded over "model"; XLA inserts AG/RS at the
    # boundaries that need full sequence (Megatron-SP pattern).
    x = constraint(x, "batch", "sp_seq", None)
    return x, (new_cache if cache is not None else None)


# --------------------------------------------------------------------------
# cache construction
# --------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int,
                     dtype, page_size: Optional[int] = None,
                     n_pages: Optional[int] = None) -> Optional[Params]:
    """One layer's decode cache; ``page_size``/``n_pages`` switch attention
    layers to the block-paged pool form.

    Paged caches store a page *pool* shared by every slot — k/v are
    (n_pages, page_size, KV, hd) and a slot's logical columns are resolved
    through the block table the caller threads alongside (one table for the
    whole stack: every layer's pool uses the same page assignments, so the
    table is serving state, not cache state). ``idx`` becomes a (batch,)
    per-slot fill vector mirroring the serving layer's slot_lens. Physical
    page 0 is the trash page and is never handed to a slot.
    """
    if page_size is not None:
        if mixer == "attn":
            hd = cfg.resolved_head_dim
            if n_pages is None:
                raise ValueError("paged caches need n_pages")
            return {"attn": {
                "k": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd),
                               dtype),
                "v": jnp.zeros((n_pages, page_size, cfg.n_kv_heads, hd),
                               dtype),
                "idx": jnp.zeros((batch,), jnp.int32),
            }}
        raise NotImplementedError(
            f"block-paged caches cover global attention layers only; "
            f"mixer {mixer!r} keeps its own state layout (serve contiguous "
            f"for this config)")
    if mixer in ("attn", "attn_local"):
        hd = cfg.resolved_head_dim
        # local layers keep a ring buffer of window size (DESIGN.md §4)
        length = min(max_len, cfg.window) if mixer == "attn_local" else max_len
        return {"attn": {
            "k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
            "idx": jnp.zeros((), jnp.int32),
        }}
    if mixer == "mamba":
        W = cfg.conv_width
        return {"mamba": {
            "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim,
                                cfg.ssm_state), jnp.float32),
            "conv_x": jnp.zeros((batch, W - 1, cfg.d_inner), dtype),
            "conv_B": jnp.zeros((batch, W - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
            "conv_C": jnp.zeros((batch, W - 1, cfg.ssm_groups * cfg.ssm_state), dtype),
        }}
    return None


# --------------------------------------------------------------------------
# the stack
# --------------------------------------------------------------------------

def layer_plan(cfg: ModelConfig, n_layers: Optional[int] = None):
    n = n_layers if n_layers is not None else cfg.n_layers
    P = cfg.block_period
    n_full = n // P
    specs = [cfg.layer_spec(i) for i in range(n)]
    return P, n_full, specs


def init_stack(key, cfg: ModelConfig, dtype, n_layers: Optional[int] = None,
               cross: bool = False) -> Params:
    P, n_full, specs = layer_plan(cfg, n_layers)
    keys = jax.random.split(key, len(specs))
    scan_params = []
    for j in range(P):
        if n_full == 0:
            break
        layer_keys = [keys[r * P + j] for r in range(n_full)]
        mixer, ffn_kind = specs[j]
        init_j = partial(init_layer, cfg=cfg, mixer=mixer, ffn_kind=ffn_kind,
                         dtype=dtype, cross=cross)
        scan_params.append(jax.vmap(init_j)(jnp.stack(layer_keys)))
    tail_params = []
    for i in range(n_full * P, len(specs)):
        mixer, ffn_kind = specs[i]
        tail_params.append(init_layer(keys[i], cfg, mixer, ffn_kind, dtype,
                                      cross=cross))
    return {"scan": scan_params, "tail": tail_params}


def init_stack_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                     n_layers: Optional[int] = None,
                     page_size: Optional[int] = None,
                     n_pages: Optional[int] = None) -> Params:
    P, n_full, specs = layer_plan(cfg, n_layers)

    def stack_tree(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), tree)

    scan_caches = []
    for j in range(P):
        if n_full == 0:
            break
        mixer, _ = specs[j]
        c = init_layer_cache(cfg, mixer, batch, max_len, dtype,
                             page_size=page_size, n_pages=n_pages)
        scan_caches.append(stack_tree(c, n_full) if c is not None else {})
    tail_caches = []
    for i in range(n_full * P, len(specs)):
        mixer, _ = specs[i]
        tail_caches.append(init_layer_cache(cfg, mixer, batch, max_len, dtype,
                                            page_size=page_size,
                                            n_pages=n_pages) or {})
    return {"scan": scan_caches, "tail": tail_caches}


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def apply_stack(params: Params, x: jax.Array, *, cfg: ModelConfig,
                plan: ExecPlan | ExecConfig, positions: jax.Array,
                caches: Optional[Params], mesh_ctx: Optional[MeshContext],
                enc_kv_stack: Optional[list] = None,
                n_layers: Optional[int] = None,
                use_remat: bool = False,
                pad_lens: Optional[jax.Array] = None,
                pad_prompt_len: Optional[jax.Array] = None,
                slot_lens: Optional[jax.Array] = None,
                block_table: Optional[jax.Array] = None,
                page_size: Optional[int] = None,
                chunk_offs: Optional[jax.Array] = None,
                ) -> tuple[jax.Array, Optional[Params]]:
    """Run the stack. caches is the pytree from init_stack_cache (or None).

    ``pad_lens`` (B,) marks per-row left-pad prefixes (batched serving);
    attention layers mask those key slots, SSM mixers currently scan
    through them (see `repro.serve.batching` for the exactness contract).
    ``slot_lens`` (B,) is the per-slot decode length authority for
    slot-pool caches (`repro.serve.continuous`): attention layers decode
    each row at its own fill level; SSM mixers ignore it (their state is
    overwritten whenever a slot is re-admitted).

    ``block_table`` (B, max_pages) + static ``page_size`` mark the caches
    as block-paged pools (see `init_layer_cache`); ONE table serves every
    layer — each layer's pool uses the same page assignments, so the table
    threads here as an argument, like slot_lens, not inside the cache
    pytree. ``chunk_offs`` (B,) turns the step into a chunked-prefill call
    (see `repro.models.layers.attention`).
    """
    plan = as_plan(cfg, plan)
    P, n_full, specs = layer_plan(cfg, n_layers)
    has_cache = caches is not None

    if n_full > 0:
        def body(carry, xs):
            x = carry
            p_list, c_list = xs
            new_cs = []
            for j in range(P):
                mixer, ffn_kind = specs[j]
                cache_j = c_list[j] if has_cache else None
                x, nc = apply_layer(
                    p_list[j], x, cfg=cfg, plan=plan, mixer=mixer,
                    ffn_kind=ffn_kind, positions=positions,
                    cache=(cache_j if cache_j else None), mesh_ctx=mesh_ctx,
                    enc_kv=None, pad_lens=pad_lens,
                    pad_prompt_len=pad_prompt_len, slot_lens=slot_lens,
                    block_table=block_table, page_size=page_size,
                    chunk_offs=chunk_offs)
                new_cs.append(nc if nc is not None else {})
            return x, tuple(new_cs)

        body_fn = _remat_wrap(body, cfg) if use_remat else body
        scan_caches = tuple(caches["scan"]) if has_cache else tuple(
            {} for _ in range(P))
        x, new_scan = jax.lax.scan(
            body_fn, x, (tuple(params["scan"]), scan_caches),
            unroll=cfg.scan_unroll)
    else:
        new_scan = ()

    new_tail = []
    for t, i in enumerate(range(n_full * P, len(specs))):
        mixer, ffn_kind = specs[i]
        cache_t = caches["tail"][t] if has_cache else None
        x, nc = apply_layer(
            params["tail"][t], x, cfg=cfg, plan=plan, mixer=mixer,
            ffn_kind=ffn_kind, positions=positions,
            cache=(cache_t if cache_t else None), mesh_ctx=mesh_ctx,
            enc_kv=None, pad_lens=pad_lens, pad_prompt_len=pad_prompt_len,
            slot_lens=slot_lens, block_table=block_table,
            page_size=page_size, chunk_offs=chunk_offs)
        new_tail.append(nc if nc is not None else {})

    new_caches = ({"scan": list(new_scan), "tail": new_tail} if has_cache else None)
    return x, new_caches
