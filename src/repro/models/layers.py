"""Transformer building blocks: norms, positions, attention, FFN.

Everything is a pure function over explicit parameter pytrees (dicts).
Operator dispatch goes through a resolved `repro.exec.ExecPlan`: each layer
calls ``plan.matmul`` / ``plan.activation`` / ``plan.attention_prefill`` /
``plan.attention_decode`` instead of branching on an execution mode — the
plan was resolved once per (ModelConfig, ExecConfig) pair and names exactly
one backend per operator slot (``plan.explain()`` shows the table).

The analog-faithful math that the raceit backends bind lives here as
private helpers (`_raceit_staged_attention`, `_raceit_fused_attention`,
`_raceit_fused_decode`, `_raceit_gqa_decode`) next to the float
formulations they are validated against (`_chunked_attention`,
`_local_block_attention`); the backend registrations that expose them as
named plan entries are in `repro.exec.backends`.

Attention uses a KV-chunked online-softmax (flash-style) formulation under
``jax.lax.scan`` so scores are never fully materialized — required to fit
prefill_32k in HBM and mirrored by the Pallas kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ExecConfig, ModelConfig
from repro.core.quant import quantize_tensor
from repro.dist.sharding import constraint
from repro.exec.plan import ExecPlan, as_plan

Params = dict
NEG_INF = -1e9


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedWeight:
    """Resident crossbar weight: int8 codes + per-column scale (static shape)."""

    codes: jax.Array   # (K, N) int8  (or stacked (R, K, N))
    scale: jax.Array   # (1, N) f32   (or (R, 1, N))
    shape: tuple       # static out-shape after the contraction dim

    def tree_flatten(self):
        return (self.codes, self.scale), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def _probs_dtype(cfg: ModelConfig):
    """dtype of the p matrix fed to the PV matmul (perf knob; f32 compute
    keeps both paths bit-consistent)."""
    if cfg.attn_probs_dtype == "float32" or cfg.compute_dtype == "float32":
        return jnp.float32
    return jnp.bfloat16


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def _dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, dtype) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype),
                "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {}  # np_layernorm: non-parametric (olmo)


def apply_norm(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, -1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    if cfg.norm == "layernorm":
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# rotary positions (standard + M-RoPE)
# --------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions (..., S) -> cos/sin (..., S, head_dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, H, hd). positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    if cfg.pos_emb == "mrope":
        # M-RoPE (qwen2-vl): the half-dim frequency bands are partitioned into
        # (t, h, w) sections; each section takes its positions from the
        # corresponding channel. Text-only inputs use identical channels.
        if positions.ndim == 2:
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        cos, sin = _rope_angles(positions, hd, cfg.rope_theta)  # (3, B, S, hd/2)
        secs = np.array(cfg.mrope_sections, np.int64)
        if secs.sum() != hd // 2:  # reduced smoke configs: rescale sections
            secs = np.maximum(1, secs * (hd // 2) // secs.sum())
            secs[-1] = hd // 2 - secs[:-1].sum()
        sections = np.cumsum(secs)[:-1]
        cos = jnp.concatenate(
            [c for c in (jnp.split(cos, sections, axis=-1)[i][i] for i in range(3))], -1)
        sin = jnp.concatenate(
            [s for s in (jnp.split(sin, sections, axis=-1)[i][i] for i in range(3))], -1)
    else:
        cos, sin = _rope_angles(positions, hd, cfg.rope_theta)  # (B, S, hd/2)
    cos = cos[:, :, None, :]  # (B, S, 1, hd/2)
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# linear projections (dispatched through the plan's matmul slot)
# --------------------------------------------------------------------------

def _linear(x: jax.Array, w: jax.Array, plan: ExecPlan,
            bias: Optional[jax.Array] = None) -> jax.Array:
    """x (..., K) @ w (K, ...) on the plan's matmul backend.

    `w` may be a pre-quantized resident weight (`QuantizedWeight`) — the
    crossbar-native serving form: weights stored as conductance codes,
    halving HBM weight traffic. The resident path always quantizes
    activations with the plan's ``act_bits``.
    """
    return plan.matmul(x, w, bias=bias)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    hd = cfg.resolved_head_dim
    heff = cfg.head_pad_to or cfg.n_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, heff, hd), dtype),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), dtype),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), dtype),
        "wo": _dense_init(ks[3], (heff, hd, cfg.d_model), dtype,
                          fan_in=cfg.n_heads * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((heff, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    return p


def _split_gqa(q, n_kv):
    """(B, S, H, hd) -> (B, S, KV, H//KV, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _chunked_attention(q, k, v, mask_fn, chunk: int, scale: float,
                       probs_dtype, pad_lens=None):
    """Online-softmax attention, scanning over KV chunks, flat-head layout.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd). KV heads are repeated to H inside
    each chunk step so scores shard cleanly over "heads" for any GQA ratio.
    mask_fn(q_idx, k_idx) -> bool; ``pad_lens`` (B,) int32 additionally
    masks each row's first ``pad_lens[b]`` keys (left-padded batch buckets).

    Masked-row semantics: a query row with *no* valid key outputs zeros.
    (With the finite ``NEG_INF`` sentinel, a fully-masked row's running max
    ``m`` never moves off its init, so ``p = exp(s - m_new) = exp(0) = 1``
    on every masked position and the row would silently emit the uniform
    average of V. ``m`` still at the sentinel after the scan is exactly the
    "no valid key" signature — those rows are zeroed. Rows with >= 1 valid
    key are unaffected: their masked positions get ``exp(NEG_INF - m) = 0``
    and any garbage accumulated before the first valid chunk is killed by
    the ``corr = exp(NEG_INF - m_new) = 0`` rescale.)
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    rep = h // kv
    sk_real = k.shape[1]
    pad = (-sk_real) % chunk  # e.g. whisper's 1500 encoder frames
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sk = k.shape[1]
    nchunks = sk // chunk
    q32 = constraint(q.astype(jnp.float32).transpose(0, 2, 1, 3) * scale,
                     "batch", "heads", None, None)  # (B,H,Sq,hd)
    qpos = jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, c0 = xs
        kr = jnp.repeat(kc.astype(jnp.float32), rep, axis=2)  # (B,C,H,hd)
        s = jnp.einsum("bhqd,bchd->bhqc", q32, kr)
        s = constraint(s, "batch", "heads", None, None)
        kpos = c0 + jnp.arange(chunk)
        msk = mask_fn(qpos[:, None], kpos[None, :]) & (kpos < sk_real)[None, :]
        if pad_lens is not None:  # per-row: left-pad keys do not exist
            msk = msk[None] & (kpos[None, :] >= pad_lens[:, None])[:, None, :]
            s = jnp.where(msk[:, None], s, NEG_INF)
        else:
            s = jnp.where(msk[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        # storing p in bf16 halves the dominant HBM tensor of the chunk loop;
        # the accumulator stays f32 (online-softmax stability)
        pv = p.astype(probs_dtype)
        vr = jnp.repeat(vc.astype(pv.dtype), rep, axis=2)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", pv, vr, preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, h, sq), NEG_INF, jnp.float32),
        jnp.zeros((b, h, sq), jnp.float32),
        constraint(jnp.zeros((b, h, sq, hd), jnp.float32),
                   "batch", "heads", None, None),
    )
    ks = k.reshape(b, nchunks, chunk, kv, hd).swapaxes(0, 1)
    vs = v.reshape(b, nchunks, chunk, kv, hd).swapaxes(0, 1)
    c0s = jnp.arange(nchunks) * chunk
    (m, l, acc), _ = jax.lax.scan(body, init, (ks, vs, c0s))
    out = jnp.where(m[..., None] > NEG_INF * 0.5,
                    acc / jnp.maximum(l, 1e-30)[..., None], 0.0)
    return out.transpose(0, 2, 1, 3)  # (B, Sq, H, hd)


def _local_block_attention(q, k, v, window: int, scale: float, probs_dtype):
    """Sliding-window attention in q-blocks: each W-token block attends only
    its own and the previous KV block (2W keys instead of S), cutting local
    layers' score FLOPs/bytes by S/(2W) vs the masked-full path.
    q: (B,S,H,hd); k/v: (B,S,KV,hd); requires S % window == 0.
    """
    B, S, H, hd = q.shape
    kv = k.shape[2]
    rep = H // kv
    W = window
    nb = S // W
    qb = (q.astype(jnp.float32) * scale).reshape(B, nb, W, H, hd)
    kb = k.reshape(B, nb, W, kv, hd)
    vb = v.reshape(B, nb, W, kv, hd)
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :nb]
    kcat = jnp.repeat(jnp.concatenate([kprev, kb], axis=2), rep, axis=3)
    vcat = jnp.repeat(jnp.concatenate([vprev, vb], axis=2), rep, axis=3)
    s = jnp.einsum("bnwhd,bnchd->bnhwc", qb, kcat.astype(jnp.float32))
    s = constraint(s, "batch", None, "heads", None, None)
    # mask: causal + window + block-0 has no previous block
    qpos = jnp.arange(W)[:, None]
    kpos = (jnp.arange(2 * W) - W)[None, :]
    base = (kpos <= qpos) & (kpos > qpos - W)  # (W, 2W)
    blk0 = base & (kpos >= 0)
    mask = jnp.where((jnp.arange(nb) == 0)[:, None, None], blk0[None], base[None])
    s = jnp.where(mask[None, :, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(probs_dtype)
    o = jnp.einsum("bnhwc,bnchd->bnwhd", p, vcat.astype(p.dtype),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, hd)


def _decode_quantize(q, k, v, kv_len, scale):
    """Shared fused-decode prolog: int8 codes + scales, native KV layout.

    q (B, 1, H, hd) is quantized with 1/sqrt(d) pre-folded; the k/v cache
    buffers (B, Smax, KV, hd) are quantized ONCE, unrepeated, with scales
    reduced over the valid prefix only. This (with `_decode_descale`) is
    the single point of truth both fused decode backends share — their
    bit-identical contract lives here, the backends differ only in how
    codes are grouped for their kernel entry.
    """
    from repro.kernels.ops import masked_prefix_quantize
    qq = quantize_tensor(q.astype(jnp.float32) * scale, bits=8)
    kq = masked_prefix_quantize(k.astype(jnp.float32), kv_len, axis=1)
    vq = masked_prefix_quantize(v.astype(jnp.float32), kv_len, axis=1)
    return qq, kq, vq


def _decode_descale(out32, cmax, v_scale, shape):
    """Shared fused-decode epilog: the oracle's PROB requant + V scales."""
    from repro.kernels.ops import prob_requant_scale
    return (out32.astype(jnp.float32)
            * (prob_requant_scale(cmax) * v_scale)).reshape(shape)


def _raceit_fused_decode(q, k, v, kv_len, scale, plan: ExecPlan,
                         pad_valid=None):
    """Decode-step (Sq=1) attention on the fused streaming kernel.

    q: (B, 1, H, hd) flat heads; k/v: (B, Smax, KV, hd) — the fixed-shape
    cache buffers, of which only the first ``kv_len`` rows are valid. The
    kernel masks the invalid tail out of the softmax, the global PROB max,
    and matmul-2 (fully-invalid key blocks are skipped outright via
    scalar-prefetched grid bounds), and the k/v quantizer scales are
    reduced over the valid prefix only, so the result is bit-exact vs the
    staged oracle on the cache slice. ``pad_valid`` (B, Smax) bool marks
    per-row attendable slots inside the prefix (left-padded batch buckets);
    masked slots sit at the LOGIT minimum, exactly like the oracle's
    additive mask. Returns (B, 1, H, hd).

    GQA heads are repeated to H *after* quantization, as int8 codes: the
    repeated tensor has the same max-abs as the original, so the scales are
    bit-identical to quantizing the repeated floats, at a quarter of the
    bytes and 1/rep of the quantizer scan. The ExecPlan prefers the
    `_raceit_gqa_decode` backend below for GQA configs, which skips the
    repeat entirely — this flat path stays registered as ``raceit_fused``
    (the MHA default and the GQA parity partner).
    """
    from repro.kernels.ops import (acam_attention_codes,
                                   acam_attention_decode_codes,
                                   expand_row_lens)
    b, sq, h, hd = q.shape
    smax, kv = k.shape[1], k.shape[2]
    rep = h // kv
    qq, (k_codes, k_scale), (v_codes, v_scale) = _decode_quantize(
        q, k, v, kv_len, scale)
    fold = lambda c: jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3
                                                          ).reshape(b * h,
                                                                    smax, hd)
    mask = None
    if pad_valid is not None:  # (B, Smax) or (B, Sq, Smax) -> (B*H, Sq, Smax)
        pv = pad_valid[:, None, :] if pad_valid.ndim == 2 else pad_valid
        mask = jnp.broadcast_to(pv[:, None], (b, h, sq, smax)
                                ).reshape(b * h, sq, smax)
    kvl = expand_row_lens(kv_len, h)
    qc = qq.codes.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    if sq == 1:
        out32, cmax = acam_attention_decode_codes(
            qc, fold(k_codes), fold(v_codes), qq.scale * k_scale,
            kvl, mask=mask, mode=plan.exec_cfg.softmax_mode)
    else:
        # Sq > 1 is the chunked-prefill step (a prompt chunk's queries vs
        # the same cache contract, causality carried by ``mask``); it takes
        # the general entry — the decode entry is its Sq=1 specialization
        out32, cmax = acam_attention_codes(
            qc, fold(k_codes), fold(v_codes), qq.scale * k_scale,
            mask, kv_len=kvl, mode=plan.exec_cfg.softmax_mode)
    return _decode_descale(out32, cmax, v_scale, (b, h, sq, hd)
                           ).transpose(0, 2, 1, 3)


def _raceit_gqa_decode(q, k, v, kv_len, scale, plan: ExecPlan,
                       pad_valid=None):
    """GQA-native decode-step attention: the KV cache is never repeated.

    Same contract as `_raceit_fused_decode` — bit-identical outputs, in
    fact (same quantizer scales and codes, same per-row sums in the same
    key-block order, same order-free integer cmax) — but k/v stay in their
    native (B, Smax, KV, hd) cache layout end to end: quantized once, and
    handed to `acam_attention_decode_gqa_codes` as (B*KV, Smax, hd) groups
    whose ``rep = H/KV`` sharing queries ride the tile's row dimension.
    The decode hot loop's ``jnp.repeat`` of cache codes disappears, and
    with it rep x of the KV-cache read traffic (see the ``decode_gqa_*``
    rows in BENCH_kernels.json).
    """
    from repro.kernels.ops import (acam_attention_decode_gqa_codes,
                                   expand_row_lens)
    b, sq, h, hd = q.shape
    smax, kv = k.shape[1], k.shape[2]
    rep = h // kv
    if sq > 1:
        # chunked-prefill steps ride the flat entry: the GQA grid's row dim
        # carries the rep sharing queries, which a chunk needs for its Sq
        # positions — bit-identical either way, this is a dataflow choice
        return _raceit_fused_decode(q, k, v, kv_len, scale, plan,
                                    pad_valid=pad_valid)
    qq, (k_codes, k_scale), (v_codes, v_scale) = _decode_quantize(
        q, k, v, kv_len, scale)
    to_groups = lambda c: c.transpose(0, 2, 1, 3).reshape(b * kv, smax, hd)
    mask = None
    if pad_valid is not None:  # (B, Smax) -> (B*KV, rep, Smax)
        mask = jnp.broadcast_to(pad_valid[:, None, None, :],
                                (b, kv, rep, smax)).reshape(b * kv, rep, smax)
    kvl = expand_row_lens(kv_len, kv)
    out32, cmax = acam_attention_decode_gqa_codes(
        qq.codes.reshape(b, h, hd).reshape(b, kv, rep, hd
                                           ).reshape(b * kv, rep, hd),
        to_groups(k_codes), to_groups(v_codes), qq.scale * k_scale,
        kvl, mask=mask,
        mode=plan.exec_cfg.softmax_mode)
    # (b*kv, rep, hd) rows land in head order
    return _decode_descale(out32, cmax, v_scale, (b, sq, h, hd))


def _raceit_paged_decode(q, k_pool, v_pool, kv_len, scale, plan: ExecPlan,
                         pad_valid=None, block_table=None, gqa=False):
    """Decode / chunk attention over a block-paged KV pool.

    q: (B, Sq, H, hd) layer layout — Sq=1 for the decode hot loop, Sq=C
    for chunked-prefill steps; k/v: the (n_pages, page_size, KV, hd) page
    pool shared by all slots, with ``block_table`` (B, max_pages) naming
    each slot's pages (0 = the trash page). Delegates to the jitted paged
    wrappers (`repro.kernels.ops.raceit_attention_decode_paged` /
    `_gqa_paged`), which quantize the pool per page with scales reduced
    over the union of live page entries — bit-identical to
    `_raceit_fused_decode` / `_raceit_gqa_decode` on the gathered
    contiguous layout of the same table. ``pad_valid`` (B, Smax) or
    (B, Sq, Smax) bool is the chunk path's intra-chunk causal mask.
    """
    from repro.kernels.ops import (raceit_attention_decode_gqa_paged,
                                   raceit_attention_decode_paged)
    b, sq, h, hd = q.shape
    qh = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # (B,H,Sq,hd)
    mask = pad_valid
    if mask is not None and mask.ndim == 2:  # (B, Smax) -> (B, Sq, Smax)
        mask = mask[:, None, :]
    fn = (raceit_attention_decode_gqa_paged if gqa and sq == 1
          else raceit_attention_decode_paged)
    out = fn(qh, k_pool.astype(jnp.float32), v_pool.astype(jnp.float32),
             kv_len, block_table, mask=mask,
             softmax_mode=plan.exec_cfg.softmax_mode, fold_scale=True)
    return out.transpose(0, 2, 1, 3)  # (B, Sq, H, hd)


def paged_write_targets_chunk(block_table, lens, chunk_offs, sq: int,
                              page_size: int):
    """Physical (pages, slots), each (B, sq), for a chunked-prefill write.

    Row b streams its chunk into logical columns [chunk_offs[b], lens[b]).
    The trash-page fence: any column that is not live — past the row's
    feed, a whole row with lens == chunk_offs, or *beyond the block
    table's capacity* — routes to physical page 0, which no live row ever
    reads (the read side caps kv_len at capacity and the allocator never
    issues page 0). Without the capacity clause an overflowing write
    would be clamped into the slot's last live page, silently corrupting
    a resident token; `repro.analysis` (KC107) checks this contract
    exhaustively.
    """
    ps = int(page_size)
    bt = jnp.asarray(block_table, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    offs = jnp.asarray(chunk_offs, jnp.int32)
    rows = jnp.arange(bt.shape[0])
    capacity = bt.shape[1] * ps
    cols = offs[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    live = (cols < lens[:, None]) & (cols < capacity)
    pages = jnp.where(live, bt[rows[:, None],
                               jnp.minimum(cols // ps, bt.shape[1] - 1)], 0)
    slots = jnp.where(live, cols % ps, 0)
    return pages, slots


def paged_write_targets_decode(block_table, lens, page_size: int):
    """Physical (pages, slots), each (B,), for a decode-step write.

    The new token is logical column lens[b] - 1. Empty slots (lens == 0)
    and slots filled past the block table's capacity write to the trash
    page 0 — same fence contract as the chunk path (KC107).
    """
    ps = int(page_size)
    bt = jnp.asarray(block_table, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    rows = jnp.arange(bt.shape[0])
    capacity = bt.shape[1] * ps
    pos = jnp.minimum(jnp.maximum(lens - 1, 0), capacity - 1)
    live = (lens > 0) & (lens <= capacity)
    pages = jnp.where(live, bt[rows, pos // ps], 0)
    return pages, pos % ps


def _attn_quantize(q, k, v, scale):
    """Shared Fig.-12 prolog: repeat KV heads to H, quantize to int8 codes."""
    rep = q.shape[2] // k.shape[2]
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    qq = quantize_tensor(q.astype(jnp.float32) * scale, bits=8)
    kq = quantize_tensor(kf.astype(jnp.float32), bits=8)
    vq = quantize_tensor(vf.astype(jnp.float32), bits=8)
    return qq, kq, vq


def _raceit_staged_attention(q, k, v, mask, scale, plan: ExecPlan):
    """Analog-faithful attention, stage by stage (the bit-accurate oracle
    formulation): quantized matmul-1, div-add mask, ACAM softmax, PROB
    re-quantization, matmul-2. The data-dependent matmuls go through the
    plan's ``dd_matmul`` slot, so ``matmul_fidelity="acam"`` routes them
    through the compiled 4-bit nibble tables (bit-identical to the integer
    matmul, per tests/test_core_acam.py).

    q: (B, Sq, H, hd) flat heads; k/v: (B, Sk, KV, hd); mask (B, Sq, Sk).
    """
    from repro.core.ops import LOGIT_FMT
    from repro.core.softmax import acam_softmax
    qq, kq, vq = _attn_quantize(q, k, v, scale)
    s32 = plan.dd_matmul(qq.codes.transpose(0, 2, 1, 3),      # (B,H,Sq,hd)
                         kq.codes.transpose(0, 2, 3, 1))      # (B,H,hd,Sk)
    logits = s32.astype(jnp.float32) * (qq.scale * kq.scale)
    logits = jnp.where(mask[:, None], logits, LOGIT_FMT.min_value)
    probs = acam_softmax(logits, axis=-1, mode=plan.exec_cfg.softmax_mode)
    pq = quantize_tensor(probs, bits=8)
    o32 = plan.dd_matmul(pq.codes,                            # (B,H,Sq,Sk)
                         vq.codes.transpose(0, 2, 1, 3))      # (B,H,Sk,hd)
    out = o32.astype(jnp.float32) * (pq.scale * vq.scale)
    return out.transpose(0, 2, 1, 3)  # (B, Sq, H, hd)


def _raceit_fused_attention(q, k, v, mask, scale, plan: ExecPlan,
                            causal_offset=None):
    """Analog-faithful attention on the streaming Pallas kernel: the whole
    Fig.-12 pipeline per VMEM tile, no (Sq, Sk) intermediates.

    ``causal_offset`` replaces the mask array with the kernel's in-kernel
    causal mask, so not even a mask of score shape is ever built; otherwise
    ``mask`` is (B, Sq, Sk) and broadcast over heads.
    """
    from repro.kernels.ops import acam_attention_codes, prob_requant_scale
    qq, kq, vq = _attn_quantize(q, k, v, scale)
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    if causal_offset is None:
        mb = jnp.broadcast_to(mask[:, None],
                              (b, h, sq, sk)).reshape(b * h, sq, sk)
    else:
        mb = None
    out32, cmax = acam_attention_codes(
        qq.codes.transpose(0, 2, 1, 3).reshape(b * h, sq, hd),
        kq.codes.transpose(0, 2, 1, 3).reshape(b * h, sk, hd),
        vq.codes.transpose(0, 2, 1, 3).reshape(b * h, sk, hd),
        qq.scale * kq.scale, mb,
        q_offset=causal_offset if causal_offset is not None else 0,
        causal=causal_offset is not None,
        mode=plan.exec_cfg.softmax_mode)
    out = out32.astype(jnp.float32) * (prob_requant_scale(cmax) * vq.scale)
    return out.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


def attention(
    p: Params,
    x: jax.Array,
    *,
    cfg: ModelConfig,
    plan: ExecPlan | ExecConfig,
    positions: jax.Array,
    local: bool = False,
    cache: Optional[Params] = None,
    cross_kv: Optional[tuple] = None,
    chunk: int = 1024,
    pad_lens: Optional[jax.Array] = None,
    pad_prompt_len: Optional[jax.Array] = None,
    slot_lens: Optional[jax.Array] = None,
    block_table: Optional[jax.Array] = None,
    page_size: Optional[int] = None,
    chunk_offs: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[Params]]:
    """Self- (or cross-) attention with optional KV cache.

    cache = {"k": (B, Smax, KV, hd), "v": ..., "idx": int32 scalar — or a
    (B,) vector of per-slot write indices for slot-pool caches}.
    prefill: x covers [0, S); decode: x is a single new token (Sq=1).

    ``block_table`` (B, max_pages) int32 + ``page_size`` (static int) switch
    the cache to its block-paged form: ``cache["k"]``/``"v"`` are a page
    *pool* (n_pages, page_size, KV, hd) shared by every slot, and row b's
    logical column c lives at pool position (block_table[b, c // page_size],
    c % page_size). Physical page 0 is the trash page — a block-table row
    full of zeros makes its slot's writes land harmlessly there, which is
    how the serving layer fences non-participating rows out of a batched
    call. Paged caches take ``slot_lens`` as their only length authority
    (``cache["idx"]`` mirrors it post-call) and come in two step shapes:

    * the Sq=1 decode step — the new k/v land at logical column
      ``slot_lens[b] - 1`` through the table;
    * the chunked-prefill step (``chunk_offs`` (B,) given) — row b streams
      prompt tokens into logical columns [chunk_offs[b], slot_lens[b]), so
      a long prompt enters its slot across several pinned-width calls
      (one compiled executable) interleaved with other slots' decode
      steps. Queries past a row's chunk (and all queries of rows with
      slot_lens == chunk_offs) are garbage rows: their writes route to the
      trash page and their outputs are the caller's to discard. Causality
      inside the chunk is a per-query mask (query j attends logical
      columns <= chunk_offs[b] + j), built here and carried through the
      backend as a (B, Sq, Smax) ``pad_valid``.

    Paged dispatch honors the resolved backend's `BackendSpec.paged` flag:
    paged-capable backends get the pool + table directly (the Pallas
    kernels follow the indirection per key block and skip dead pages);
    anything else — the digital/staged baselines, a pinned contiguous
    backend — is served by gathering the table's pages back to contiguous
    (B, max_pages*page_size, KV, hd) rows first, a degrade, never an
    error. Local/ring layers and left-padded buckets (``pad_lens``) are
    out of the paged contract and raise.

    ``slot_lens`` (B,) int32 is the per-row decode length authority for
    slot-level continuous batching (`repro.serve.continuous`): row b's
    query attends exactly the first ``slot_lens[b]`` cache columns
    (including the token written this step), so every slot decodes at its
    own fill level and a 0 entry marks an empty slot whose row is dead
    (no valid key; the raceit kernels define its output as zeros and its
    stale cache never touches a quantizer scale). When ``slot_lens`` is
    None the length comes from the cache's own ``idx``, scalar or
    per-slot vector alike. Per-slot caches also write each row's new k/v
    at its *own* column (a batched scatter instead of one shared
    `dynamic_update_slice` offset).

    ``pad_lens`` (B,) int32 marks each row's left-pad prefix (mixed-length
    batch buckets, see `repro.serve.batching`): those key slots do not
    exist for self-attention — prefill masks them per row, and the decode
    step masks the corresponding cache slots (including the ring-overwrite
    rule for local layers: a pad slot stays masked only until a later
    token's ring write reclaims it). Cross-attention ignores ``pad_lens``
    (its keys come from the encoder, not from ``x``); position offsets are
    the *caller's* job — `repro.models.model` computes per-row positions
    from the same pad lengths before RoPE ever sees them.

    ``pad_prompt_len`` (scalar) is the bucket's padded prompt length,
    needed only by the decode step: the slot-index == column-index mapping
    the pad mask relies on breaks when the *prefill* overflowed a ring
    buffer (the ``sq >= L`` branch below keeps the last L columns, putting
    column ``plen - L + s`` at slot ``s``), so for layers with
    ``pad_prompt_len > L`` the mask is dropped — every slot already holds
    one of the last L tokens, mostly real ones, and the remaining pads are
    the documented local-layer softening, not a mis-masked real token.

    Dispatch goes through the resolved plan: prefill (and full/cross
    attention) through ``plan.attention_prefill``, the Sq=1 cache step
    through ``plan.attention_decode`` — the backend (digital chunked,
    staged Fig.-12, or the streaming Pallas kernel) was chosen once at
    `repro.exec.resolve_plan` time, with unsupported combos degraded and
    the reasons recorded on the plan. ``plan`` also accepts a raw
    ExecConfig and resolves it against ``cfg`` (cached).

    The mask *kind* is computed here from the call-site ``cfg`` (encoder
    sub-stacks pass a replaced config), then the backend builds whatever
    mask representation it needs — a mask_fn for the chunked float path, a
    (B, Sq, Sk) array for the staged pipeline, or no mask at all for the
    fused kernel's in-kernel causal path.
    """
    plan = as_plan(cfg, plan)
    b, sq, _ = x.shape
    hd = cfg.resolved_head_dim
    q = _linear(x, p["wq"], plan, p.get("bq"))
    q = constraint(q, "batch", None, "heads", None)
    if cross_kv is None:
        k = _linear(x, p["wk"], plan, p.get("bk"))
        v = _linear(x, p["wv"], plan, p.get("bv"))
        if cfg.pos_emb in ("rope", "mrope"):
            q = apply_rope(q, positions, cfg)
            k = apply_rope(k, positions, cfg)
    else:
        k, v = cross_kv  # encoder keys/values, precomputed

    paged = block_table is not None
    if chunk_offs is not None and not paged:
        raise ValueError("chunk_offs is the chunked-prefill surface of "
                         "block-paged caches; pass block_table/page_size")
    if paged:
        if page_size is None:
            raise ValueError("paged caches need a static page_size")
        if local:
            raise NotImplementedError(
                "block-paged KV does not cover local/ring layers (a ring "
                "overwrite would need page recycling inside a slot)")
        if cache is None or cross_kv is not None:
            raise ValueError("block_table requires a self-attention KV cache")
        if slot_lens is None:
            raise ValueError("paged caches take their per-slot lengths from "
                             "slot_lens")
        if pad_lens is not None:
            raise ValueError("paged slots are never left-padded; pad_lens "
                             "does not apply")

    new_cache = None
    if paged:
        ps = int(page_size)
        lens = jnp.asarray(slot_lens, jnp.int32)
        bt = jnp.asarray(block_table, jnp.int32)
        rows = jnp.arange(b)
        if chunk_offs is not None:
            # chunked prefill: fenced physical targets from the shared
            # routing helper (trash page 0 for dead or overflow columns)
            pages, slot = paged_write_targets_chunk(bt, lens, chunk_offs,
                                                    sq, ps)
            ck = cache["k"].at[pages, slot].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[pages, slot].set(v.astype(cache["v"].dtype))
        else:
            if sq != 1:
                raise ValueError("paged caches take Sq=1 decode steps or "
                                 "chunked prefill (chunk_offs); whole-prompt "
                                 "prefill goes through Model.prefill_chunk")
            pages, slot = paged_write_targets_decode(bt, lens, ps)
            ck = cache["k"].at[pages, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[pages, slot].set(
                v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": ck, "v": cv, "idx": lens}
        k, v = ck, cv
    elif cache is not None and cross_kv is None:
        idx = cache["idx"]
        per_slot = getattr(idx, "ndim", 0) == 1  # slot-pool cache
        L = cache["k"].shape[1]
        if sq >= L:
            # prefill past the buffer (ring caches of local layers): keep the
            # last L rotated-in-place entries; RoPE is pre-applied so storage
            # order is irrelevant under the all-valid mask.
            ck = k[:, -L:].astype(cache["k"].dtype)
            cv = v[:, -L:].astype(cache["v"].dtype)
        elif per_slot:
            # per-slot write indices: each row's new token lands at its own
            # column (slots fill independently under continuous batching)
            if sq != 1:
                raise ValueError("per-slot caches only take Sq=1 decode "
                                 "steps; prefill into a slot goes through "
                                 "a solo prefill + row scatter "
                                 "(repro.serve.continuous)")
            pos = idx % L if local else idx
            rows = jnp.arange(b)
            ck = cache["k"].at[rows, pos].set(k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[rows, pos].set(v[:, 0].astype(cache["v"].dtype))
        else:
            pos = idx % L if local else idx  # ring write for local layers
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv, "idx": idx + sq}
        if sq == 1:  # decode attends through the cache
            k, v = ck, cv

    scale = 1.0 / math.sqrt(hd)

    if paged:
        # decode or chunk step against the page pool, lengths from
        # slot_lens; kv_len is the logical fill, capped at table capacity
        mp = bt.shape[1]
        lk = mp * ps
        kv_len = jnp.minimum(lens, lk)
        pad_valid = None
        if chunk_offs is not None:
            # intra-chunk causality: query j of row b sits at absolute
            # position chunk_offs[b] + j and attends logical columns <= it
            qpos = (jnp.asarray(chunk_offs, jnp.int32)[:, None]
                    + jnp.arange(sq, dtype=jnp.int32)[None, :])
            pad_valid = (jnp.arange(lk, dtype=jnp.int32)[None, None, :]
                         <= qpos[..., None])
        if plan.op("attention_decode").spec.paged:
            o = plan.attention_decode(q, k, v, kv_len=kv_len, scale=scale,
                                      pad_valid=pad_valid, block_table=bt,
                                      page_size=ps)
        else:
            # non-paged backend under a paged cache (digital/staged
            # baselines, explicit contiguous pins): gather the table's
            # pages back to contiguous rows — a degrade, never an error.
            # Columns past each row's kv_len are zeroed to reproduce a
            # contiguous cache's never-written tail exactly: a row's
            # out-of-range columns gather the shared trash page, whose
            # content is other rows' fenced garbage — left in place it
            # would pollute whole-tensor quantizer scales in the staged
            # raceit paths and, when a faulted row parked NaNs there,
            # contaminate healthy rows through prob-0 * NaN
            kvh, hdim = k.shape[2], k.shape[3]
            live = (jnp.arange(lk, dtype=jnp.int32)[None, :]
                    < kv_len[:, None])[:, :, None, None]
            o = plan.attention_decode(
                q, jnp.where(live, k[bt].reshape(b, lk, kvh, hdim), 0),
                jnp.where(live, v[bt].reshape(b, lk, kvh, hdim), 0),
                kv_len=kv_len, scale=scale, pad_valid=pad_valid)
    elif sq == 1 and cache is not None:
        # decode: single query against the cache, masked by validity/window.
        # (ring buffers: every written slot is inside the window by design,
        # so validity is always a prefix of length min(idx, buffer_len))
        L = k.shape[1]
        # slot_lens is the per-row length authority when given (continuous
        # batching: slots at independent fill levels, 0 = empty slot);
        # otherwise the cache's own post-write index — () or (B,) — rules
        lens = (jnp.asarray(slot_lens, jnp.int32) if slot_lens is not None
                else new_cache["idx"])
        kv_len = jnp.minimum(lens, L)
        pad_valid = None
        if pad_lens is not None:
            # slot s of row b is attendable unless it still holds a pad
            # token: pads occupy slots [0, pad_lens[b]) until the ring
            # write for token s + L reclaims them (lens > L + s); non-ring
            # caches have L = max_len >= lens, so the clause is inert there
            slots = jnp.arange(L)
            pad_valid = ((slots[None, :] >= pad_lens[:, None])
                         | (jnp.reshape(lens, (-1, 1)) > L + slots[None, :]))
            if pad_prompt_len is not None:
                # prompt overflowed this ring buffer: prefill kept the last
                # L columns (column plen-L+s at slot s), so slot-space pad
                # masking would hit real tokens — drop it for this layer
                pad_valid = pad_valid | (
                    jnp.reshape(jnp.asarray(pad_prompt_len), (-1, 1)) > L)
        o = plan.attention_decode(q, k, v, kv_len=kv_len, scale=scale,
                                  pad_valid=pad_valid)
    else:
        q_off = cache["idx"] if cache is not None else 0
        if cross_kv is not None:
            kind = "cross"       # full cross attention
        elif not cfg.causal:
            kind = "bidir"       # bidirectional (encoder-only)
        elif local:
            kind = "local"       # causal sliding window
        else:
            kind = "causal"
        o = plan.attention_prefill(q, k, v, scale=scale, q_offset=q_off,
                                   kind=kind, window=cfg.window, chunk=chunk,
                                   probs_dtype=_probs_dtype(cfg),
                                   pad_lens=(pad_lens if cross_kv is None
                                             else None))

    wq = p["wq"]
    heff = wq.shape[0] if isinstance(wq, QuantizedWeight) else wq.shape[1]
    o = o.reshape(b, sq, heff, hd).astype(x.dtype)
    if heff > cfg.n_heads:  # hard-mask padded heads: function == unpadded model
        o = o * (jnp.arange(heff) < cfg.n_heads)[None, None, :, None].astype(o.dtype)
    wo = p["wo"]
    if isinstance(wo, QuantizedWeight):  # codes already (H*hd, D)
        out = _linear(o.reshape(b, sq, heff * hd), wo, plan)
    else:
        out = jnp.einsum("bshd,hdm->bsm", o, wo.astype(x.dtype))
    return out, new_cache


# --------------------------------------------------------------------------
# dense FFN
# --------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w1": _dense_init(ks[0], (cfg.d_model, cfg.d_ff), dtype),
         "w2": _dense_init(ks[1], (cfg.d_ff, cfg.d_model), dtype, fan_in=cfg.d_ff)}
    if cfg.glu:
        p["w3"] = _dense_init(ks[2], (cfg.d_model, cfg.d_ff), dtype)
    return p


def ffn(p: Params, x: jax.Array, cfg: ModelConfig,
        plan: ExecPlan | ExecConfig) -> jax.Array:
    plan = as_plan(cfg, plan)
    h = _linear(x, p["w1"], plan)
    h = plan.activation(h, cfg.activation)
    if cfg.glu:
        h = h * _linear(x, p["w3"], plan)
    h = constraint(h, "batch", None, "mlp")
    return _linear(h, p["w2"], plan)


# --------------------------------------------------------------------------
# embeddings / unembedding
# --------------------------------------------------------------------------

def init_embeddings(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"tok_emb": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                       jnp.float32) * 0.02).astype(dtype)}
    if cfg.pos_emb == "learned":
        max_pos = max(cfg.max_seq_len if cfg.family != "encoder" else 8192, 8192)
        max_pos = min(max_pos, 65_536)
        p["pos_emb"] = (jax.random.normal(ks[1], (max_pos, cfg.d_model),
                                          jnp.float32) * 0.02).astype(dtype)
    if not cfg.tie_embeddings:
        p["unembed"] = _dense_init(ks[2], (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed(p: Params, tokens: jax.Array, positions: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["tok_emb"], tokens, axis=0)
    if cfg.pos_emb == "learned":
        x = x + jnp.take(p["pos_emb"], positions, axis=0)
    elif cfg.pos_emb == "sinusoidal":
        hd = cfg.d_model
        freqs = 1.0 / (10_000 ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
        ang = positions[..., None].astype(jnp.float32) * freqs
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        x = x + pe.astype(x.dtype)
    return constraint(x, "batch", None, None)


def unembed(p: Params, x: jax.Array, cfg: ModelConfig,
            plan: ExecPlan | ExecConfig) -> jax.Array:
    """Logits through the plan's ``lm_head`` slot.

    Resident int8 unembeddings (`QuantizedWeight`, the raceit_q8 serving
    form) take the quantized path *with the plan's act_bits* — previously
    this spot rebuilt a bare ``ExecConfig(mode="raceit")`` and silently
    dropped the caller's bit-width knobs.
    """
    plan = as_plan(cfg, plan)
    w = p["tok_emb"].T if cfg.tie_embeddings else p["unembed"]
    logits = plan.lm_head(x, w)
    return constraint(logits, "batch", None, "vocab")
