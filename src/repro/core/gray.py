"""Gray-code output encoding (paper Section V-A, Table I).

Encoding the ACAM *output* bits in Gray code halves the number of runs-of-1s
per output bit, which halves the number of stored ranges (= ACAM cells).
The binary result is recovered with an XOR prefix over the higher-order bits —
cheap CMOS gates (the XOR row in Table II).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = ["gray_encode", "gray_decode", "gray_decode_bits"]


def gray_encode(n):
    """Binary code -> Gray code (works on ints, numpy, or jax arrays)."""
    return n ^ (n >> 1)


def gray_decode(g, bits: int):
    """Gray code -> binary code via XOR-prefix (b_i = XOR of g_{n-1..i})."""
    b = g
    shift = 1
    while shift < bits:
        b = b ^ (b >> shift)
        shift <<= 1
    mask = (1 << bits) - 1
    return b & mask


def gray_decode_bits(bits_array, axis: int = -1):
    """Decode a Gray bit-plane array (MSB first along `axis`) to binary planes.

    This mirrors the hardware: each binary bit is the XOR of all higher-order
    Gray bits (paper eq. for b_i). Accepts numpy or jax arrays of 0/1.
    """
    xp = jnp if not isinstance(bits_array, np.ndarray) else np
    moved = xp.moveaxis(bits_array, axis, 0)
    acc = xp.cumsum(moved.astype(xp.int32), axis=0) % 2  # XOR prefix of 0/1
    return xp.moveaxis(acc.astype(bits_array.dtype), 0, axis)
