"""Standard Compute-ACAM operator library (paper Section IV).

All Transformer non-MVM operators the paper maps onto Compute-ACAM:

* 4-bit 1-var  — the ACAM-based ADC (identity function, folded 2x4-bit);
* 4-bit 2-var  — multiplication for data-dependent matmuls (8-bit products
  decompose into four 4-bit nibble products + three adds);
* 8-bit 1-var  — GeLU / SiLU activations, exp and log for the Softmax dataflow.

Because the ACAM is reconfigurable, *any* scalar op is one `compile()` away —
this is the paper's adaptability claim, and why new activations (SiLU, GeGLU,
softplus for Mamba) need no new hardware.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from .acam import Acam2VarFunction, AcamFunction
from .quant import FixedPointFormat, PoTFormat, ScaledFormat

__all__ = [
    "int4s", "int4u", "int8s", "int8u",
    "GELU_FMT", "LOGIT_FMT", "PROB_FMT", "EXP_POT", "LOG_OUT_FMT",
    "get_op", "mult4_programs", "mult8_codes", "OPS",
]

# ---- formats -------------------------------------------------------------
int4s = FixedPointFormat(int_bits=3, frac_bits=0, signed=True)    # [-8, 7]
int4u = FixedPointFormat(int_bits=4, frac_bits=0, signed=False)   # [0, 15]
int8s = FixedPointFormat(int_bits=7, frac_bits=0, signed=True)    # [-128, 127]
int8u = FixedPointFormat(int_bits=8, frac_bits=0, signed=False)   # [0, 255]

GELU_FMT = FixedPointFormat(int_bits=2, frac_bits=5)   # 1-2-5: [-4, 3.97]
LOGIT_FMT = FixedPointFormat(int_bits=4, frac_bits=3)  # 1-4-3: [-16, 15.875]
PROB_FMT = FixedPointFormat(int_bits=0, frac_bits=8, signed=False)  # [0, 1)
EXP_POT = PoTFormat(e_min=-24, bits=8)                 # exp output, PoT (§VIII-C)
LOG_OUT_FMT = FixedPointFormat(int_bits=5, frac_bits=2)  # log output: [-32, 31.75]


def _np_gelu(x):
    return 0.5 * x * (1.0 + np.tanh(np.sqrt(2.0 / np.pi) * (x + 0.044715 * x**3)))


def _np_silu(x):
    return x / (1.0 + np.exp(-x))


def _np_softplus(x):
    return np.log1p(np.exp(np.minimum(x, 30.0))) + np.maximum(x - 30.0, 0.0)


def _np_log_with_floor(x):
    """log(v); log(0) hard-set to the output format's minimum (paper §IV-C)."""
    out = np.full_like(x, LOG_OUT_FMT.min_value, dtype=np.float64)
    pos = x > 0
    out[pos] = np.log(x[pos])
    return out


_OP_SPECS = {
    # name: (fn, in_fmt, out_fmt)
    "gelu": (_np_gelu, GELU_FMT, GELU_FMT),
    "silu": (_np_silu, GELU_FMT, GELU_FMT),
    "relu": (lambda x: np.maximum(x, 0.0), GELU_FMT, GELU_FMT),
    "softplus": (_np_softplus, GELU_FMT, GELU_FMT),
    "identity4": (lambda x: x, int4u, int4u),  # the Compute-ACAM ADC (§IV-A)
    "exp_pot": (np.exp, LOGIT_FMT, EXP_POT),   # softmax step 1/5, PoT output
    # Ablation (paper Fig. 14): "straightforward" 8-bit uniform quantization of
    # the exp output. Scale covers exp(max logit); everything below half a step
    # collapses to 0 because exp outputs are exponentially distributed.
    "exp_uniform": (np.exp, LOGIT_FMT,
                    ScaledFormat(scale_value=float(np.exp(LOGIT_FMT.max_value)) / 255.0,
                                 bits=8, signed=False)),
    "exp_prob": (np.exp, LOGIT_FMT, PROB_FMT),  # softmax step 5 (x - logsum <= 0)
    "log": (_np_log_with_floor, PoTFormat(e_min=-24, bits=8), LOG_OUT_FMT),
    # Beyond-paper: fractional-octave PoT (log-uniform). Same 8-bit tables and
    # ACAM cost; quarter-octave steps cut the +-41% PoT error to +-9%.
    "exp_pot_fine": (np.exp, LOGIT_FMT, PoTFormat(e_min=-24, bits=8, octave_step=0.25)),
    "log_fine": (_np_log_with_floor, PoTFormat(e_min=-24, bits=8, octave_step=0.25), LOG_OUT_FMT),
}

OPS = tuple(_OP_SPECS.keys())


@lru_cache(maxsize=None)
def get_op(name: str, encode: bool = True) -> AcamFunction:
    fn, in_fmt, out_fmt = _OP_SPECS[name]
    return AcamFunction.compile(name, fn, in_fmt, out_fmt, encode=encode)


# ---- 4-bit multiplication (paper §IV-B, Figures 7 & 9(b)) -----------------

@lru_cache(maxsize=None)
def mult4_programs(encode: bool = True):
    """The three nibble-product tables needed for signed 8-bit multiply:
    ss (signed x signed), su (signed x unsigned), uu (unsigned x unsigned)."""
    mul = lambda x, y: x * y
    ss = Acam2VarFunction.compile("mult4_ss", mul, int4s, int4s,
                                  FixedPointFormat(int_bits=7, frac_bits=0), encode=encode)
    su = Acam2VarFunction.compile("mult4_su", mul, int4s, int4u,
                                  FixedPointFormat(int_bits=7, frac_bits=0), encode=encode)
    uu = Acam2VarFunction.compile("mult4_uu", mul, int4u, int4u,
                                  FixedPointFormat(int_bits=8, frac_bits=0, signed=False), encode=encode)
    return ss, su, uu


@lru_cache(maxsize=None)
def mult4_paper(encode: bool = False):
    """The exact configuration of paper Figure 7: x, y in 1-1-2; z in 1-2-1."""
    f_in = FixedPointFormat(int_bits=1, frac_bits=2)
    f_out = FixedPointFormat(int_bits=2, frac_bits=1)
    return Acam2VarFunction.compile("mult4_fig7", lambda x, y: x * y, f_in, f_in, f_out,
                                    encode=encode)


def mult8_codes(x: jax.Array, y: jax.Array, hw: bool = False) -> jax.Array:
    """8-bit signed multiply from four 4-bit ACAM products + three adds.

    x, y: int codes in [-128, 127]. Returns x*y exactly (int32) — the
    decomposition p = (xh*yh)<<8 + (xh*yl + yh*xl)<<4 + xl*yl with arithmetic
    high nibbles and unsigned low nibbles.
    """
    ss, su, uu = mult4_programs()
    x = x.astype(jnp.int32)
    y = y.astype(jnp.int32)
    xh, xl = x >> 4, x & 0xF
    yh, yl = y >> 4, y & 0xF
    p_hh = ss.apply_codes(xh, yh, hw=hw)
    p_hl = su.apply_codes(xh, yl, hw=hw)
    p_lh = su.apply_codes(yh, xl, hw=hw)
    p_ll = uu.apply_codes(xl, yl, hw=hw)
    return (p_hh << 8) + ((p_hl + p_lh) << 4) + p_ll
