"""The Compute-ACAM Softmax dataflow (paper Figure 8 and §IV-C).

softmax(x)_i = exp(x_i) / sum_j exp(x_j), computed without divider hardware via
a/b = exp(log a - log b):

  1. e_i = EXP(x_i)         8-bit 1-var Compute-ACAM, PoT-quantized output
  2. S   = sum_i e_i        CMOS adder lane
  3. L   = LOG(S)           8-bit 1-var Compute-ACAM (log(0) := min code)
  4. d_i = x_i - L          CMOS adder lane (subtract)
  5. p_i = EXP(d_i)         8-bit 1-var Compute-ACAM, uniform [0,1) output

`mode="pot"` is the paper's configuration; `mode="uniform"` reproduces the
Fig. 14 ablation where step 1 uses straightforward uniform quantization and
accuracy collapses (exp outputs are exponentially distributed, so a uniform
8-bit grid zeroes almost everything).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import ops
from .ops import LOGIT_FMT, LOG_OUT_FMT

__all__ = ["acam_softmax", "noisy_acam_softmax", "softmax_reference"]


def softmax_reference(x: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(x, axis=axis)


@partial(jax.jit, static_argnames=("axis", "mode", "hw"))
def acam_softmax(x: jax.Array, axis: int = -1, mode: str = "pot", hw: bool = False) -> jax.Array:
    """Softmax over float logits with full ACAM integer semantics.

    x is first quantized into the div-add stage's LOGIT format (1-4-3); masked
    positions should already be at LOGIT_FMT.min_value.
    """
    exp_name = {"pot": "exp_pot", "pot_fine": "exp_pot_fine", "uniform": "exp_uniform"}[mode]
    exp_op = ops.get_op(exp_name)
    log_op = ops.get_op("log_fine" if mode == "pot_fine" else "log")
    final_op = ops.get_op("exp_prob")

    xc = LOGIT_FMT.encode(x)  # step 0: output of the div-add stage
    e_codes = exp_op.apply_codes(xc, hw=hw)  # step 1
    e_vals = exp_op.out_fmt.decode(e_codes)
    S = jnp.sum(e_vals, axis=axis, keepdims=True)  # step 2 (adder lane)
    s_codes = log_op.in_fmt.encode(S)  # PoT re-quantization of the sum
    L = log_op.apply_codes(s_codes, hw=hw)  # step 3, LOG_OUT (1-5-2) codes
    # step 4: subtract in a common fixed-point grid. LOGIT has 3 frac bits,
    # LOG_OUT has 2 -> shift L left by 1. Saturate to the exp table's domain.
    d = xc - (L << (LOGIT_FMT.frac_bits - LOG_OUT_FMT.frac_bits))
    d = jnp.clip(d, LOGIT_FMT.code_min, LOGIT_FMT.code_max)
    p = final_op.apply_codes(d, hw=hw)  # step 5
    return final_op.out_fmt.decode(p)


def noisy_acam_softmax(x: jax.Array, axis: int = -1, mode: str = "pot",
                       noise=None, key=None) -> jax.Array:
    """`acam_softmax` under ACAM device variation — same Fig. 8 dataflow,
    with the three ACAM stages (EXP, LOG, final EXP) evaluated through
    `AcamFunction.apply_codes_noisy`: ``noise.acam_sigma`` of
    input-referred threshold jitter and ``noise.readout_sigma`` of output
    readout noise each (the CMOS adder lanes of steps 2 and 4 stay exact —
    they are digital). ``noise`` is a `repro.hw.noise.NoiseConfig`;
    ``key`` the injection site's derived key. Delegates to the clean
    (jitted) `acam_softmax` when both sigmas are zero, so zero-noise
    outputs are bit-identical.
    """
    if noise is None or (noise.acam_sigma <= 0.0
                         and noise.readout_sigma <= 0.0):
        return acam_softmax(x, axis=axis, mode=mode)
    exp_name = {"pot": "exp_pot", "pot_fine": "exp_pot_fine",
                "uniform": "exp_uniform"}[mode]
    exp_op = ops.get_op(exp_name)
    log_op = ops.get_op("log_fine" if mode == "pot_fine" else "log")
    final_op = ops.get_op("exp_prob")
    k1, k2, k3 = jax.random.split(key, 3)

    xc = LOGIT_FMT.encode(x)
    e_codes = exp_op.apply_codes_noisy(xc, k1, noise.acam_sigma,
                                       noise.readout_sigma)
    e_vals = exp_op.out_fmt.decode(e_codes)
    S = jnp.sum(e_vals, axis=axis, keepdims=True)
    s_codes = log_op.in_fmt.encode(S)
    L = log_op.apply_codes_noisy(s_codes, k2, noise.acam_sigma,
                                 noise.readout_sigma)
    d = xc - (L << (LOGIT_FMT.frac_bits - LOG_OUT_FMT.frac_bits))
    d = jnp.clip(d, LOGIT_FMT.code_min, LOGIT_FMT.code_max)
    p = final_op.apply_codes_noisy(d, k3, noise.acam_sigma,
                                   noise.readout_sigma)
    return final_op.out_fmt.decode(p)
