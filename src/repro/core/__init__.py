"""Compute-ACAM core: the paper's contribution as a composable JAX library."""
from .quant import (  # noqa: F401
    FixedPointFormat, ScaledFormat, PoTFormat, QuantizedTensor,
    quantize_tensor, dequantize_tensor, fake_quant,
)
from .gray import gray_encode, gray_decode, gray_decode_bits  # noqa: F401
from .compiler import (  # noqa: F401
    compile_1var, compile_2var, build_table_1var, build_table_2var,
    eval_range_program, eval_rect_program, array_cost,
    RangeProgram, RectProgram, ArrayCost,
)
from .acam import AcamFunction, Acam2VarFunction, RangeArrays, RectArrays  # noqa: F401
from .ops import get_op, mult4_programs, mult8_codes, OPS  # noqa: F401
from .crossbar import CrossbarConfig, bit_sliced_matmul, crossbar_linear  # noqa: F401
from .softmax import acam_softmax, softmax_reference  # noqa: F401
from .attention import raceit_attention, dd_matmul_codes  # noqa: F401
