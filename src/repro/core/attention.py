"""RACE-IT attention numerics: the five-stage MHA pipeline (paper Fig. 12).

mvm       Q = X W_q on the crossbar DPE lane           (crossbar.py)
matmul-1  r = q . K^T as 4-bit 2-var ACAM multiplies   (ops.mult8_codes)
div-add   r / sqrt(d_k) + mask on the adder lane        (scale folding)
softmax   Compute-ACAM dataflow                         (softmax.py)
matmul-2  out = s . V as ACAM multiplies + adds

This is the bit-accurate reference used to validate the RACE-IT execution mode
of the model stack and the Pallas kernels. The data-dependent matmuls operate
on int8 codes; `fidelity="acam"` routes every scalar product through the
compiled 4-bit nibble tables (slow, exact), `fidelity="int"` uses the
equivalent integer matmul (proven equal in tests).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ops import LOGIT_FMT, mult8_codes
from .quant import quantize_tensor
from .softmax import acam_softmax

__all__ = ["raceit_attention", "dd_matmul_codes", "fused_attention_supported"]

# softmax configs the fused Pallas kernels cover (every mode the staged
# acam_softmax accepts); kept in sync with kernels.acam_attention's
# FUSED_SOFTMAX_MODES by tests/test_attention_decode_fused.py (duplicated
# here so this module never imports repro.kernels at load time)
_FUSED_SOFTMAX_MODES = ("pot", "pot_fine", "uniform")


def fused_attention_supported(fidelity: str = "int", softmax_mode: str = "pot",
                              hw: bool = False) -> str | None:
    """None if the fused kernel covers this config, else a reason string.

    The kernel-side dispatchability predicate for ``fused=True`` /
    ``ExecConfig.fused_attention``. Callers choose their policy on a
    non-None reason: `raceit_attention` raises (explicit ``fused=True`` is
    a hard request), while the ``raceit_fused`` attention backends plug
    this predicate into the RaceOp registry (`repro.exec.backends`), where
    `repro.exec.resolve_plan` degrades to ``raceit_staged`` with the
    reason recorded on the plan and a one-time warning
    (``fused_attention=True`` there is a performance preference, not a
    numerics contract).

    Supported: ``fidelity="int"``, ``hw=False``, ``softmax_mode`` in
    ``("pot", "pot_fine", "uniform")`` — both proven bit-equal to the slow
    paths (tests/test_core_acam.py), so the kernel loses nothing. Unsupported
    and the reasons why:

    * ``hw=True`` — per-cell ACAM match-line emulation has no kernel path;
    * ``fidelity="acam"`` — the 4-bit nibble-table matmul is a test-only
      fidelity mode (bit-identical to the integer matmul the kernel uses).
    """
    if hw:
        return "hw=True (per-cell ACAM emulation has no kernel path)"
    if fidelity != "int":
        return (f"fidelity={fidelity!r} (the kernel uses the bit-equal "
                f"integer matmul; only fidelity='int' is supported)")
    if softmax_mode not in _FUSED_SOFTMAX_MODES:
        return (f"softmax_mode={softmax_mode!r} not in "
                f"{_FUSED_SOFTMAX_MODES}")
    return None


def dd_matmul_codes(a_codes: jax.Array, b_codes: jax.Array, fidelity: str = "int") -> jax.Array:
    """Data-dependent matmul on int8 codes: (..., M, K) x (..., K, N) -> int32.

    fidelity="acam": each scalar product goes through the four compiled 4-bit
    Compute-ACAM nibble tables + three adds (paper §IV-B).
    fidelity="int": plain integer dot products (bit-identical, fast path).
    """
    if fidelity == "acam":
        prod = mult8_codes(a_codes[..., :, :, None], b_codes[..., None, :, :])
        return jnp.sum(prod, axis=-2, dtype=jnp.int32)
    a = a_codes.astype(jnp.int32)
    b = b_codes.astype(jnp.int32)
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (b.ndim - 2,)), (tuple(range(a.ndim - 2)),) * 2),
        preferred_element_type=jnp.int32,
    )


@partial(jax.jit, static_argnames=("fidelity", "softmax_mode", "hw", "fused"))
def raceit_attention(
    q: jax.Array,  # (B, H, Sq, D) float
    k: jax.Array,  # (B, H, Sk, D) float
    v: jax.Array,  # (B, H, Sk, D) float
    mask: jax.Array | None = None,  # broadcastable to (B, H, Sq, Sk), bool
    fidelity: str = "int",
    softmax_mode: str = "pot",
    hw: bool = False,
    fused: bool = False,
) -> jax.Array:
    """Bit-accurate RACE-IT attention (float in/out, int8 internal).

    ``fused=True`` dispatches to the streaming Pallas kernel
    (`repro.kernels.acam_attention`), which executes the whole pipeline per
    VMEM tile without ever materializing the (Sq, Sk) logit/probability
    matrices; this staged path stays as the bit-accurate oracle it is
    validated against (tests/test_attention_fused.py).

    Dispatch rules for ``fused=True`` (see `fused_attention_supported`):
    every ``softmax_mode`` ("pot", "pot_fine", "uniform") and any mask are
    supported; ``hw=True`` or ``fidelity="acam"`` raise ValueError — an
    explicit ``fused=True`` here is a hard request, so an impossible combo
    is an error rather than a silent fallback (the resolved ExecPlan makes
    the opposite choice and degrades with a recorded reason). For the Sq=1
    KV-cache serving step use
    `repro.kernels.ops.raceit_attention_decode_fused`, which is bit-exact
    vs this oracle evaluated on the cache slice.
    """
    d = q.shape[-1]
    if fused:
        reason = fused_attention_supported(fidelity, softmax_mode, hw)
        if reason:
            raise ValueError(f"fused attention unsupported: {reason}")
        from repro.kernels.ops import raceit_attention_fused  # lazy: no cycle
        return raceit_attention_fused(q, k, v, mask=mask,
                                      softmax_mode=softmax_mode)
    qq = quantize_tensor(q, bits=8)
    kq = quantize_tensor(k, bits=8)
    vq = quantize_tensor(v, bits=8)

    # matmul-1: r = q . K^T on the GCE multiplier lane.
    r = dd_matmul_codes(qq.codes, jnp.swapaxes(kq.codes, -1, -2), fidelity)
    # div-add: scale by s_q s_k / sqrt(d) and apply the mask additively.
    logits = r.astype(jnp.float32) * (qq.scale * kq.scale) / jnp.sqrt(jnp.float32(d))
    if mask is not None:
        logits = jnp.where(mask, logits, LOGIT_FMT.min_value)
    # softmax: the Fig. 8 dataflow (integer, table-driven).
    probs = acam_softmax(logits, axis=-1, mode=softmax_mode, hw=hw)
    # matmul-2: out = s . V, probs re-enter the multiplier lane as 8-bit codes.
    pq = quantize_tensor(probs, bits=8)
    out = dd_matmul_codes(pq.codes, vq.codes, fidelity)
    return out.astype(jnp.float32) * (pq.scale * vq.scale)
