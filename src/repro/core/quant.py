"""Fixed-point formats and quantizers used by Compute-ACAM numerics.

The paper uses S-I-F notation (sign / integer / fraction bits) for fixed-point
data, uniform symmetric quantization for tensors, and Power-of-Two (PoT)
quantization for the outputs of exponent functions (Section VIII-C).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FixedPointFormat",
    "ScaledFormat",
    "PoTFormat",
    "QuantizedTensor",
    "quantize_tensor",
    "dequantize_tensor",
    "fake_quant",
]


@dataclasses.dataclass(frozen=True)
class FixedPointFormat:
    """An S-I-F fixed point format, e.g. 1-0-3 = sign + 0 int bits + 3 frac bits.

    Codes are two's-complement integers in [-2^(n-1), 2^(n-1)) for signed
    formats, [0, 2^n) for unsigned; value = code * 2^-frac_bits.
    """

    int_bits: int
    frac_bits: int
    signed: bool = True

    @property
    def bits(self) -> int:
        return int(self.signed) + self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return 2.0 ** (-self.frac_bits)

    @property
    def num_codes(self) -> int:
        return 1 << self.bits

    @property
    def code_min(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def code_max(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1

    @property
    def min_value(self) -> float:
        return self.code_min * self.scale

    @property
    def max_value(self) -> float:
        return self.code_max * self.scale

    def __str__(self) -> str:  # S-I-F, as in the paper
        return f"{int(self.signed)}-{self.int_bits}-{self.frac_bits}"

    # ---- encoding / decoding (work on numpy or jax arrays) ----
    def encode(self, x):
        """Float -> two's complement code (saturating round-to-nearest-even)."""
        xp = jnp if isinstance(x, jax.Array) else np
        c = xp.clip(xp.round(x / self.scale), self.code_min, self.code_max)
        return c.astype(xp.int32)

    def decode(self, code):
        xp = jnp if isinstance(code, jax.Array) else np
        return code.astype(xp.float32) * self.scale

    def to_unsigned(self, code):
        """Two's-complement code -> unsigned LUT index in [0, 2^n)."""
        if not self.signed:
            return code
        return code + (1 << (self.bits - 1))

    def from_unsigned(self, u):
        if not self.signed:
            return u
        return u - (1 << (self.bits - 1))

    def to_bits(self, code) -> np.ndarray:
        """Unsigned bit-pattern of the two's-complement code (numpy)."""
        u = np.asarray(self.to_unsigned(np.asarray(code)))
        return u.astype(np.uint32)

    def all_codes_value_order(self) -> np.ndarray:
        """All codes sorted by their analog (decoded) value, ascending."""
        return np.arange(self.code_min, self.code_max + 1, dtype=np.int64)

    def quantize_value(self, x):
        """Round-trip through the format (= what ACAM output quantization does)."""
        return self.decode(self.encode(x))


@dataclasses.dataclass(frozen=True)
class ScaledFormat:
    """Integer format with an arbitrary (calibrated) float scale.

    Same interface as FixedPointFormat; used when a power-of-two step is too
    coarse/fine — e.g. the paper's "straightforward uniform quantization" of
    exp outputs (§VIII-C ablation), or calibrated activation formats.
    """

    scale_value: float
    bits: int = 8
    signed: bool = True

    @property
    def scale(self) -> float:
        return self.scale_value

    @property
    def num_codes(self) -> int:
        return 1 << self.bits

    @property
    def code_min(self) -> int:
        return -(1 << (self.bits - 1)) if self.signed else 0

    @property
    def code_max(self) -> int:
        return (1 << (self.bits - 1)) - 1 if self.signed else (1 << self.bits) - 1

    @property
    def min_value(self) -> float:
        return self.code_min * self.scale

    @property
    def max_value(self) -> float:
        return self.code_max * self.scale

    def encode(self, x):
        xp = jnp if isinstance(x, jax.Array) else np
        c = xp.clip(xp.round(x / self.scale), self.code_min, self.code_max)
        return c.astype(xp.int32)

    def decode(self, code):
        xp = jnp if isinstance(code, jax.Array) else np
        return code.astype(xp.float32) * self.scale

    def to_unsigned(self, code):
        return code + (1 << (self.bits - 1)) if self.signed else code

    def from_unsigned(self, u):
        return u - (1 << (self.bits - 1)) if self.signed else u

    def to_bits(self, code) -> np.ndarray:
        return np.asarray(self.to_unsigned(np.asarray(code))).astype(np.uint32)

    def all_codes_value_order(self) -> np.ndarray:
        return np.arange(self.code_min, self.code_max + 1, dtype=np.int64)

    def quantize_value(self, x):
        return self.decode(self.encode(x))


@dataclasses.dataclass(frozen=True)
class PoTFormat:
    """Power-of-Two quantization for non-negative values (exp outputs).

    Code 0 represents exactly 0; code c >= 1 represents
    2^(e_min + (c-1)*octave_step). octave_step=1 is the paper's PoT (§VIII-C):
    255 integer octaves of dynamic range. octave_step<1 ("fractional PoT",
    i.e. log-domain uniform) is our beyond-paper refinement — same ACAM table
    cost, ~step/2 octaves of relative error instead of +-0.5 octave.
    """

    e_min: int
    bits: int = 8
    octave_step: float = 1.0

    @property
    def num_codes(self) -> int:
        return 1 << self.bits

    @property
    def e_max(self) -> float:
        return self.e_min + (self.num_codes - 2) * self.octave_step

    def encode(self, x):
        xp = jnp if isinstance(x, jax.Array) else np
        x = xp.asarray(x, xp.float64 if xp is np else xp.float32)
        safe = xp.maximum(x, 2.0 ** (self.e_min - 1))
        e = xp.clip(xp.round((xp.log2(safe) - self.e_min) / self.octave_step),
                    0, self.num_codes - 2)
        code = (e + 1).astype(xp.int32)
        return xp.where(x < 2.0 ** (self.e_min - self.octave_step / 2), 0, code)

    def decode(self, code):
        xp = jnp if isinstance(code, jax.Array) else np
        dt = xp.float64 if xp is np else xp.float32
        e = (code - 1).astype(dt) * self.octave_step + self.e_min
        val = xp.exp2(xp.minimum(e, 126.0).astype(dt))
        return xp.where(code == 0, xp.zeros((), dt), val)

    def quantize_value(self, x):
        return self.decode(self.encode(x))

    def all_codes_value_order(self) -> np.ndarray:
        # PoT codes are already monotone in value: 0, 2^e_min, 2^(e_min+1), ...
        return np.arange(self.num_codes, dtype=np.int64)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Symmetric-quantized integer tensor + scale (per-tensor or per-channel)."""

    codes: jax.Array  # int8 / int32
    scale: jax.Array  # f32, broadcastable to codes
    bits: int = 8

    def dequantize(self) -> jax.Array:
        return self.codes.astype(jnp.float32) * self.scale

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])


def _qrange(bits: int) -> int:
    return (1 << (bits - 1)) - 1


@partial(jax.jit, static_argnames=("bits", "axis"))
def quantize_tensor(x: jax.Array, bits: int = 8, axis=None) -> QuantizedTensor:
    """Symmetric max-abs quantization. axis=None -> per-tensor scale;
    axis=k -> per-channel scales along every dim except k reduced."""
    if axis is None:
        amax = jnp.max(jnp.abs(x))
    else:
        reduce_dims = tuple(d for d in range(x.ndim) if d != axis)
        amax = jnp.max(jnp.abs(x), axis=reduce_dims, keepdims=True)
    qmax = _qrange(bits)
    scale = jnp.maximum(amax, 1e-12) / qmax
    codes = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    dtype = jnp.int8 if bits <= 8 else jnp.int32
    return QuantizedTensor(codes.astype(dtype), scale.astype(jnp.float32), bits)


def dequantize_tensor(q: QuantizedTensor) -> jax.Array:
    return q.dequantize()


@partial(jax.jit, static_argnames=("bits", "axis"))
def fake_quant(x: jax.Array, bits: int = 8, axis=None) -> jax.Array:
    """Quantize-dequantize with a straight-through gradient (QAT helper)."""
    q = quantize_tensor(jax.lax.stop_gradient(x), bits=bits, axis=axis)
    y = q.dequantize()
    return x + jax.lax.stop_gradient(y - x)
