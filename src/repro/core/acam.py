"""Vectorized functional simulation of Compute-ACAM arrays (paper Section III).

Two equivalent evaluation paths are provided:

* the **hardware path** — pad the compiled ranges/rectangles into dense arrays
  and evaluate the analog semantics directly (per output bit: OR over cells of
  "input in [lo, hi)"), then Gray-decode with the XOR prefix; and
* the **LUT path** — because the compiler is exact, the range program of an
  n-bit function is equivalent to its 2^n-entry table; production kernels use
  this (a gather / one-hot matmul on TPU).

Tests assert the two paths agree bit-exactly on every input.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Union

import jax
import jax.numpy as jnp
import numpy as np

from . import compiler
from .gray import gray_decode
from .quant import FixedPointFormat, PoTFormat, ScaledFormat

Format = Union[FixedPointFormat, ScaledFormat, PoTFormat]

__all__ = ["RangeArrays", "RectArrays", "AcamFunction", "Acam2VarFunction",
           "jitter_codes"]


def _fmt_num_codes(fmt: Format) -> int:
    return fmt.num_codes


def _fmt_code_bounds(fmt: Format) -> tuple:
    """Clip bounds for jittered codes of this format (signed domain for
    fixed-point/scaled formats, [0, num_codes) for value-ordered PoT)."""
    if isinstance(fmt, PoTFormat):
        return 0, fmt.num_codes - 1
    return fmt.code_min, fmt.code_max


def jitter_codes(codes: jax.Array, sigma: float, key: jax.Array,
                 code_min: int, code_max: int) -> jax.Array:
    """Additive integer Gaussian jitter on stored/searched codes.

    The input-referred form of ACAM threshold-voltage variation: shifting a
    searched position by -e is equivalent to shifting every stored window
    edge by +e, so one rounded N(0, sigma) draw per element models the
    aggregate edge drift of the cells that element hits. Accumulation is in
    int32 (an int8 code + jitter must saturate at the clip, not wrap), and
    ``sigma <= 0`` returns the input unchanged — zero-noise paths stay
    bit-identical to the clean ones at zero cost.
    """
    if sigma <= 0.0:
        return codes
    n = jnp.round(sigma * jax.random.normal(key, jnp.shape(codes)))
    out = jnp.clip(codes.astype(jnp.int32) + n.astype(jnp.int32),
                   code_min, code_max)
    return out.astype(codes.dtype)


def _fmt_to_position(fmt: Format, codes):
    """Map stored codes to value-order positions (= unsigned code)."""
    if isinstance(fmt, PoTFormat):
        return codes  # PoT codes are already value-ordered, unsigned
    return fmt.to_unsigned(codes)


def _fmt_from_position(fmt: Format, pos):
    if isinstance(fmt, PoTFormat):
        return pos
    return fmt.from_unsigned(pos)


@dataclasses.dataclass
class RangeArrays:
    """Padded [lo, hi) ranges per output bit for vectorized evaluation."""

    lo: np.ndarray  # (out_bits, R) int32
    hi: np.ndarray  # (out_bits, R) int32
    mask: np.ndarray  # (out_bits, R) bool
    out_bits: int
    encoded: bool

    @classmethod
    def from_program(cls, prog: compiler.RangeProgram) -> "RangeArrays":
        R = max(1, max(len(r) for r in prog.ranges))
        lo = np.zeros((prog.out_bits, R), np.int32)
        hi = np.zeros((prog.out_bits, R), np.int32)
        mask = np.zeros((prog.out_bits, R), bool)
        for i, ranges in enumerate(prog.ranges):
            for k, (a, b) in enumerate(ranges):
                lo[i, k], hi[i, k], mask[i, k] = a, b, True
        return cls(lo, hi, mask, prog.out_bits, prog.encoded)

    def __call__(self, positions: jax.Array) -> jax.Array:
        """positions (...,) int32 -> unsigned output patterns (...,) int32."""
        p = positions[..., None, None]  # (..., 1, 1)
        lo, hi, mask = jnp.asarray(self.lo), jnp.asarray(self.hi), jnp.asarray(self.mask)
        match = (p >= lo) & (p < hi) & mask  # (..., bits, R)
        bits = jnp.any(match, axis=-1)  # (..., bits) MSB first
        weights = jnp.left_shift(1, jnp.arange(self.out_bits - 1, -1, -1))
        out = jnp.sum(bits.astype(jnp.int32) * weights, axis=-1)
        if self.encoded:
            out = gray_decode(out, self.out_bits)
        return out

    def jittered(self, sigma: float, key: jax.Array) -> "RangeArrays":
        """Per-cell Gaussian jitter on the compiled match-window bounds.

        The direct (per-edge) form of threshold-voltage variation: every
        stored [lo, hi) edge moves independently by round(N(0, sigma))
        positions. Windows whose jittered edges cross (lo >= hi) simply
        never match — a cell whose window collapsed, which is exactly the
        analog failure mode. ``sigma <= 0`` returns self unchanged.
        """
        if sigma <= 0.0:
            return self
        kl, kh = jax.random.split(key)
        dlo = np.asarray(jnp.round(sigma * jax.random.normal(
            kl, self.lo.shape)), np.int32)
        dhi = np.asarray(jnp.round(sigma * jax.random.normal(
            kh, self.hi.shape)), np.int32)
        return dataclasses.replace(self, lo=self.lo + dlo, hi=self.hi + dhi)


@dataclasses.dataclass
class RectArrays:
    x_lo: np.ndarray
    x_hi: np.ndarray
    y_lo: np.ndarray
    y_hi: np.ndarray
    mask: np.ndarray
    out_bits: int
    encoded: bool

    @classmethod
    def from_program(cls, prog: compiler.RectProgram) -> "RectArrays":
        R = max(1, max(len(r) for r in prog.rects))
        arrs = {k: np.zeros((prog.out_bits, R), np.int32) for k in ("xl", "xh", "yl", "yh")}
        mask = np.zeros((prog.out_bits, R), bool)
        for i, rects in enumerate(prog.rects):
            for k, r in enumerate(rects):
                arrs["xl"][i, k], arrs["xh"][i, k] = r.x_lo, r.x_hi
                arrs["yl"][i, k], arrs["yh"][i, k] = r.y_lo, r.y_hi
                mask[i, k] = True
        return cls(
            arrs["xl"], arrs["xh"], arrs["yl"], arrs["yh"],
            mask, prog.out_bits, prog.encoded,
        )

    def __call__(self, xpos: jax.Array, ypos: jax.Array) -> jax.Array:
        xp = xpos[..., None, None]
        yp = ypos[..., None, None]
        match = (
            (xp >= jnp.asarray(self.x_lo)) & (xp < jnp.asarray(self.x_hi))
            & (yp >= jnp.asarray(self.y_lo)) & (yp < jnp.asarray(self.y_hi))
            & jnp.asarray(self.mask)
        )
        bits = jnp.any(match, axis=-1)
        weights = jnp.left_shift(1, jnp.arange(self.out_bits - 1, -1, -1))
        out = jnp.sum(bits.astype(jnp.int32) * weights, axis=-1)
        if self.encoded:
            out = gray_decode(out, self.out_bits)
        return out


@dataclasses.dataclass
class AcamFunction:
    """A compiled 1-variable Compute-ACAM function."""

    name: str
    in_fmt: Format
    out_fmt: Format
    table: np.ndarray  # unsigned output pattern per value-ordered input
    program: compiler.RangeProgram
    cost: compiler.ArrayCost
    _lut: np.ndarray = None  # value-position -> output code (signed domain)
    _hw: RangeArrays = None

    @classmethod
    def compile(
        cls,
        name: str,
        fn: Callable,
        in_fmt: Format,
        out_fmt: Format,
        encode: bool = True,
    ) -> "AcamFunction":
        if isinstance(in_fmt, PoTFormat):
            x = in_fmt.decode(np.arange(in_fmt.num_codes))
        else:
            x = in_fmt.decode(in_fmt.all_codes_value_order())
        y = np.asarray(fn(x), dtype=np.float64)
        if isinstance(out_fmt, PoTFormat):
            table = out_fmt.encode(y).astype(np.uint32)
        else:
            table = out_fmt.to_bits(out_fmt.encode(y))
        out_bits = 8 if isinstance(out_fmt, PoTFormat) else out_fmt.bits
        prog = compiler.compile_1var(table, out_bits, encode=encode)
        # LUT in signed-code domain for the fast path.
        out_codes = table.astype(np.int64)
        if not isinstance(out_fmt, PoTFormat):
            out_codes = out_fmt.from_unsigned(out_codes)
        return cls(
            name=name, in_fmt=in_fmt, out_fmt=out_fmt, table=table,
            program=prog, cost=compiler.array_cost(prog),
            _lut=out_codes.astype(np.int32),
            _hw=RangeArrays.from_program(prog),
        )

    # ---- code-domain application ----
    def apply_codes(self, codes: jax.Array, hw: bool = False) -> jax.Array:
        """Input codes -> output codes. hw=True uses the analog range semantics."""
        pos = _fmt_to_position(self.in_fmt, codes)
        if hw:
            pattern = self._hw(pos)
            if not isinstance(self.out_fmt, PoTFormat):
                return _fmt_from_position(self.out_fmt, pattern)
            return pattern
        return jnp.take(jnp.asarray(self._lut), pos, axis=0)

    def apply_codes_noisy(self, codes: jax.Array, key: jax.Array,
                          in_sigma: float = 0.0,
                          out_sigma: float = 0.0) -> jax.Array:
        """`apply_codes` under device variation.

        ``in_sigma`` is the input-referred threshold jitter (the aggregate
        of per-edge `RangeArrays.jittered` drift), applied in the
        value-ordered position domain; ``out_sigma`` is readout/sense
        noise on the produced output codes, clipped to the output format.
        Bit-identical to `apply_codes` when both sigmas are zero.
        """
        if in_sigma <= 0.0 and out_sigma <= 0.0:
            return self.apply_codes(codes)
        kin, kout = jax.random.split(key)
        pos = _fmt_to_position(self.in_fmt, codes)
        pos = jitter_codes(pos, in_sigma, kin, 0,
                           _fmt_num_codes(self.in_fmt) - 1)
        out = jnp.take(jnp.asarray(self._lut), pos, axis=0)
        return jitter_codes(out, out_sigma, kout,
                            *_fmt_code_bounds(self.out_fmt))

    # ---- float-domain convenience (quantize -> LUT -> dequantize) ----
    def __call__(self, x: jax.Array, hw: bool = False) -> jax.Array:
        codes = self.in_fmt.encode(x)
        out = self.apply_codes(codes, hw=hw)
        return self.out_fmt.decode(out)


@dataclasses.dataclass
class Acam2VarFunction:
    """A compiled 2-variable (4-bit x 4-bit) Compute-ACAM function."""

    name: str
    x_fmt: FixedPointFormat
    y_fmt: FixedPointFormat
    out_fmt: FixedPointFormat
    table: np.ndarray  # (Nx, Ny) unsigned output patterns
    program: compiler.RectProgram
    cost: compiler.ArrayCost
    _lut: np.ndarray = None
    _hw: RectArrays = None

    @classmethod
    def compile(cls, name, fn, x_fmt, y_fmt, out_fmt, encode: bool = True):
        table = compiler.build_table_2var(fn, x_fmt, y_fmt, out_fmt)
        prog = compiler.compile_2var(table, out_fmt.bits, encode=encode)
        out_codes = out_fmt.from_unsigned(table.astype(np.int64))
        return cls(
            name=name, x_fmt=x_fmt, y_fmt=y_fmt, out_fmt=out_fmt, table=table,
            program=prog, cost=compiler.array_cost(prog),
            _lut=out_codes.astype(np.int32),
            _hw=RectArrays.from_program(prog),
        )

    def apply_codes(self, xc: jax.Array, yc: jax.Array, hw: bool = False) -> jax.Array:
        xpos = _fmt_to_position(self.x_fmt, xc)
        ypos = _fmt_to_position(self.y_fmt, yc)
        if hw:
            return _fmt_from_position(self.out_fmt, self._hw(xpos, ypos))
        return jnp.asarray(self._lut)[xpos, ypos]

    def __call__(self, x, y, hw: bool = False):
        out = self.apply_codes(self.x_fmt.encode(x), self.y_fmt.encode(y), hw=hw)
        return self.out_fmt.decode(out)
