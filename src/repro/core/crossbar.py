"""Bit-sliced ReRAM crossbar MVM with Compute-ACAM ADCs (paper §II-A, §IV-A).

Weights are spatially bit-sliced into `cell_bits`-wide conductance slices
(adjacent columns); inputs are temporally bit-sliced into `dac_bits`-wide
pulses. Each crossbar column's analog partial sum is digitized by the
Compute-ACAM-based ADC (folded 2x4-bit identity conversion, §IV-A) and the
planes are consolidated with shift-&-add. The ISAAC weight-offset encoding is
used: unsigned (offset) operands on the array, with the offset corrections
applied digitally — the row-sum of inputs comes from a ones-column, and the
column-sum of (static) weights is precomputed.

`adc_mode="exact"` models a conversion with enough resolution (the default
configuration: 128 rows x 2-bit cells x 1-bit DAC -> 385 levels ~ 8.6 bits;
with ISAAC encoding <= 8 bits, matching the paper); `adc_mode="quantize"`
applies an explicit `adc_bits` uniform transfer so resolution loss can be
studied. This module is the pure-jnp oracle; kernels/acam_mvm.py is the
Pallas TPU kernel with identical semantics.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from .quant import QuantizedTensor, quantize_tensor

__all__ = ["CrossbarConfig", "bit_sliced_matmul", "crossbar_linear",
           "noisy_crossbar_linear"]


@dataclasses.dataclass(frozen=True)
class CrossbarConfig:
    rows: int = 128        # crossbar height (K is chunked to this)
    cell_bits: int = 2     # ReRAM bits per cell
    dac_bits: int = 1      # input bits per pulse
    weight_bits: int = 8
    input_bits: int = 8
    adc_bits: int = 8      # Compute-ACAM ADC resolution
    adc_mode: str = "exact"  # "exact" | "quantize"

    @property
    def num_weight_slices(self) -> int:
        return -(-self.weight_bits // self.cell_bits)

    @property
    def num_input_slices(self) -> int:
        return -(-self.input_bits // self.dac_bits)


def _adc(p: jax.Array, cfg: CrossbarConfig, rows: int) -> jax.Array:
    """ADC transfer function on a non-negative integer partial sum."""
    p_max = rows * ((1 << cfg.cell_bits) - 1) * ((1 << cfg.dac_bits) - 1)
    levels = (1 << cfg.adc_bits) - 1
    if cfg.adc_mode == "exact" or p_max <= levels:
        return p
    step = p_max / levels
    return jnp.round(jnp.round(p / step) * step).astype(jnp.int32)


@partial(jax.jit, static_argnames=("cfg",))
def bit_sliced_matmul(
    x_codes: jax.Array, w_codes: jax.Array, cfg: CrossbarConfig = CrossbarConfig()
) -> jax.Array:
    """Integer matmul via crossbar bit-slicing. x (M, K) int; w (K, N) int.

    Exactly equals x @ w (int32) when the ADC has sufficient resolution.
    """
    M, K = x_codes.shape
    K2, N = w_codes.shape
    assert K == K2, (x_codes.shape, w_codes.shape)
    ox = 1 << (cfg.input_bits - 1)
    ow = 1 << (cfg.weight_bits - 1)
    xu = (x_codes.astype(jnp.int32) + ox).astype(jnp.uint32)
    wu = (w_codes.astype(jnp.int32) + ow).astype(jnp.uint32)

    # Pad K to a multiple of the crossbar height; offset-padding with zeros
    # contributes nothing to the unsigned accumulations below.
    pad = (-K) % cfg.rows
    if pad:
        xu = jnp.pad(xu, ((0, 0), (0, pad)))
        wu = jnp.pad(wu, ((0, pad), (0, 0)))
    n_chunks = (K + pad) // cfg.rows
    xu_c = xu.reshape(M, n_chunks, cfg.rows)
    wu_c = wu.reshape(n_chunks, cfg.rows, N)

    dac_mask = (1 << cfg.dac_bits) - 1
    cell_mask = (1 << cfg.cell_bits) - 1
    acc = jnp.zeros((M, N), jnp.int32)
    for t in range(cfg.num_input_slices):  # temporal input slices
        x_t = ((xu_c >> (t * cfg.dac_bits)) & dac_mask).astype(jnp.int32)
        for s in range(cfg.num_weight_slices):  # spatial weight slices
            w_s = ((wu_c >> (s * cfg.cell_bits)) & cell_mask).astype(jnp.int32)
            # Analog column currents per crossbar chunk -> ADC -> shift-&-add.
            p = jnp.einsum("mck,ckn->mcn", x_t, w_s,
                           preferred_element_type=jnp.int32)
            q = _adc(p, cfg, cfg.rows).sum(axis=1)
            acc = acc + (q << (t * cfg.dac_bits + s * cfg.cell_bits))

    # ISAAC offset-encoding corrections (digital).
    rowsum_x = xu.astype(jnp.int32).sum(axis=1, keepdims=True)   # ones column
    colsum_w = wu.astype(jnp.int32).sum(axis=0, keepdims=True)   # precomputed
    return acc - ow * rowsum_x - ox * colsum_w + K * ox * ow


def crossbar_linear(
    x: jax.Array,
    wq: QuantizedTensor,
    bias: jax.Array | None = None,
    cfg: CrossbarConfig = CrossbarConfig(),
) -> jax.Array:
    """Float-in/float-out linear layer on the crossbar DPE lane.

    x: (..., K) float. wq: per-out-channel int8 weights (K, N). The input is
    uniformly quantized per-tensor (the DAC path), multiplied bit-sliced, and
    rescaled.
    """
    xq = quantize_tensor(x, bits=cfg.input_bits)
    lead = x.shape[:-1]
    x2 = xq.codes.reshape(-1, x.shape[-1]).astype(jnp.int32)
    y = bit_sliced_matmul(x2, wq.codes.astype(jnp.int32), cfg)
    yf = y.astype(jnp.float32) * (xq.scale * wq.scale)
    yf = yf.reshape(*lead, -1)
    if bias is not None:
        yf = yf + bias
    return yf


def noisy_crossbar_linear(
    x: jax.Array,
    wq: QuantizedTensor,
    noise,
    key: jax.Array,
    bias: jax.Array | None = None,
    cfg: CrossbarConfig = CrossbarConfig(),
) -> jax.Array:
    """`crossbar_linear` on a device-varied array: the stored weight codes
    are perturbed by conductance spread + stuck-at cells in the ISAAC
    unsigned domain the array actually programs
    (`repro.hw.noise.perturb_weight_codes`), then the bit-sliced MVM runs
    unchanged — the variation lives in the conductances, not the dataflow.
    Bit-identical to `crossbar_linear` when the noise knobs are zero.
    """
    from repro.hw.noise import perturb_weight_codes
    codes = perturb_weight_codes(wq.codes, noise, key, bits=cfg.weight_bits)
    return crossbar_linear(x, QuantizedTensor(codes, wq.scale, wq.bits),
                           bias, cfg)
