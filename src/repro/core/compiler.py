"""Truth-table -> Compute-ACAM range/rectangle compiler (paper Sections III & V).

For a 1-variable function, each output bit ML stores the set of input ranges in
which that bit is 1 (OR-of-ranges along a match line; contiguous runs of 1s in
the value-ordered truth table merge into one cell). For a 2-variable function,
each cell stores a pair of ranges = an axis-aligned *rectangle* in the 2-D input
grid; the compiler covers the dots of Figure 7 with greedy maximal rectangles
(overlap is allowed because the ML is an OR).

Gray-encoding the output (Section V-A) roughly halves run counts; the decoder
is an XOR prefix (gray.py). Array sizing follows Section V-B: 4x8 arrays,
16 arrays per group.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import numpy as np

from .gray import gray_encode
from .quant import FixedPointFormat

__all__ = [
    "RangeProgram",
    "Rect",
    "RectProgram",
    "ArrayCost",
    "build_table_1var",
    "build_table_2var",
    "compile_1var",
    "compile_2var",
    "eval_range_program",
    "eval_rect_program",
    "array_cost",
    "ACAM_ARRAY_ROWS",
    "ACAM_ARRAY_COLS",
    "ACAM_ARRAYS_PER_GROUP",
]

# Section V-B design point: 4x8 arrays, 16 arrays per group.
ACAM_ARRAY_ROWS = 4
ACAM_ARRAY_COLS = 8
ACAM_ARRAYS_PER_GROUP = 16


# --------------------------------------------------------------------------
# Truth tables. Tables are indexed by *value position* (input codes sorted by
# analog value), because ACAM ranges live in the analog/value domain.
# --------------------------------------------------------------------------

def build_table_1var(
    fn: Callable[[np.ndarray], np.ndarray],
    in_fmt: FixedPointFormat,
    out_fmt: FixedPointFormat,
) -> np.ndarray:
    """Return out-codes (as unsigned bit patterns) for each value-ordered input."""
    codes = in_fmt.all_codes_value_order()
    x = in_fmt.decode(codes)
    y = np.asarray(fn(x), dtype=np.float64)
    out_codes = out_fmt.encode(y)
    return out_fmt.to_bits(out_codes)  # unsigned patterns, value order


def build_table_2var(
    fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
    x_fmt: FixedPointFormat,
    y_fmt: FixedPointFormat,
    out_fmt: FixedPointFormat,
) -> np.ndarray:
    """2-D truth table (value order on both axes) of unsigned output patterns."""
    xc = x_fmt.all_codes_value_order()
    yc = y_fmt.all_codes_value_order()
    X = x_fmt.decode(xc)[:, None]
    Y = y_fmt.decode(yc)[None, :]
    Z = np.asarray(fn(X, Y), dtype=np.float64)
    return out_fmt.to_bits(out_fmt.encode(Z))


# --------------------------------------------------------------------------
# 1-variable compilation: runs of 1s per output bit.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RangeProgram:
    """Per-output-bit list of half-open [lo, hi) ranges in value-position space."""

    ranges: list[list[tuple[int, int]]]  # [bit][k] -> (lo, hi), MSB first
    out_bits: int
    encoded: bool  # True if ranges were compiled against Gray-coded output

    @property
    def num_cells(self) -> int:
        return sum(len(r) for r in self.ranges)

    @property
    def cells_per_bit(self) -> list[int]:
        return [len(r) for r in self.ranges]

    def rows_needed(self, array_cols: int = ACAM_ARRAY_COLS) -> int:
        """ML rows after splitting each bit's ranges across array_cols-wide rows.

        Rows of the same bit in different arrays are OR-wired together through
        the shared global ML pull-down (Figure 10(c))."""
        return sum(max(1, -(-len(r) // array_cols)) for r in self.ranges)


def _runs_of_ones(bits: np.ndarray) -> list[tuple[int, int]]:
    """Half-open [lo, hi) index ranges where bits==1."""
    padded = np.concatenate([[0], bits.astype(np.int8), [0]])
    diff = np.diff(padded)
    starts = np.nonzero(diff == 1)[0]
    ends = np.nonzero(diff == -1)[0]
    return list(zip(starts.tolist(), ends.tolist()))


def compile_1var(table: np.ndarray, out_bits: int, encode: bool = True) -> RangeProgram:
    """Compile a value-ordered table of unsigned output patterns into ranges."""
    tab = gray_encode(table) if encode else table
    ranges = []
    for bit in range(out_bits - 1, -1, -1):  # MSB first
        plane = (tab >> bit) & 1
        ranges.append(_runs_of_ones(plane))
    return RangeProgram(ranges=ranges, out_bits=out_bits, encoded=encode)


def eval_range_program(prog: RangeProgram, positions: np.ndarray) -> np.ndarray:
    """Hardware-semantics evaluation: OR of range matches per bit -> pattern.

    `positions` are value-order indices (the analog input). Returns the
    *unsigned binary* output pattern (Gray-decoded if the program is encoded),
    so it must equal the original truth table exactly.
    """
    positions = np.asarray(positions)
    out = np.zeros(positions.shape, dtype=np.uint32)
    for i, bit_ranges in enumerate(prog.ranges):
        bit = prog.out_bits - 1 - i
        match = np.zeros(positions.shape, dtype=bool)
        for lo, hi in bit_ranges:
            match |= (positions >= lo) & (positions < hi)
        out |= match.astype(np.uint32) << bit
    if prog.encoded:
        from .gray import gray_decode

        out = gray_decode(out, prog.out_bits)
    return out


# --------------------------------------------------------------------------
# 2-variable compilation: greedy maximal-rectangle cover (Figure 7 / 9(b)).
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Rect:
    x_lo: int
    x_hi: int  # half open
    y_lo: int
    y_hi: int

    def contains(self, x, y):
        return (x >= self.x_lo) & (x < self.x_hi) & (y >= self.y_lo) & (y < self.y_hi)


@dataclasses.dataclass
class RectProgram:
    rects: list[list[Rect]]  # [bit][k], MSB first
    out_bits: int
    encoded: bool

    @property
    def num_cells(self) -> int:
        return sum(len(r) for r in self.rects)

    @property
    def cells_per_bit(self) -> list[int]:
        return [len(r) for r in self.rects]

    def rows_needed(self, array_cols: int = ACAM_ARRAY_COLS) -> int:
        return sum(max(1, -(-len(r) // array_cols)) for r in self.rects)


def _max_rect_from(plane: np.ndarray, covered: np.ndarray, i: int, j: int) -> Rect:
    """Grow a maximal all-ones rectangle from seed (i, j); two growth orders,
    keep the one covering more currently-uncovered ones."""
    H, W = plane.shape

    def grow(row_first: bool) -> Rect:
        x_lo, x_hi, y_lo, y_hi = i, i + 1, j, j + 1
        dirs = ["down", "up", "right", "left"]
        if not row_first:
            dirs = ["right", "left", "down", "up"]
        for d in dirs:
            while True:
                if d == "down" and x_hi < H and plane[x_hi, y_lo:y_hi].all():
                    x_hi += 1
                elif d == "up" and x_lo > 0 and plane[x_lo - 1, y_lo:y_hi].all():
                    x_lo -= 1
                elif d == "right" and y_hi < W and plane[x_lo:x_hi, y_hi].all():
                    y_hi += 1
                elif d == "left" and y_lo > 0 and plane[x_lo:x_hi, y_lo - 1].all():
                    y_lo -= 1
                else:
                    break
        return Rect(x_lo, x_hi, y_lo, y_hi)

    best, best_gain = None, -1
    for rf in (True, False):
        r = grow(rf)
        gain = int((~covered[r.x_lo : r.x_hi, r.y_lo : r.y_hi]).sum())
        if gain > best_gain:
            best, best_gain = r, gain
    return best


def _cover_plane(plane: np.ndarray) -> list[Rect]:
    """Greedy cover of the 1-cells of `plane` with maximal rectangles."""
    covered = np.zeros_like(plane, dtype=bool)
    rects: list[Rect] = []
    ones = np.argwhere(plane)
    # Seed order: raster scan; rectangles may overlap (ML is an OR).
    for i, j in ones:
        if covered[i, j]:
            continue
        r = _max_rect_from(plane, covered, int(i), int(j))
        covered[r.x_lo : r.x_hi, r.y_lo : r.y_hi] = True
        rects.append(r)
    return rects


def compile_2var(table2d: np.ndarray, out_bits: int, encode: bool = True) -> RectProgram:
    tab = gray_encode(table2d) if encode else table2d
    rects = []
    for bit in range(out_bits - 1, -1, -1):
        plane = ((tab >> bit) & 1).astype(bool)
        rects.append(_cover_plane(plane))
    return RectProgram(rects=rects, out_bits=out_bits, encoded=encode)


def eval_rect_program(prog: RectProgram, xi: np.ndarray, yi: np.ndarray) -> np.ndarray:
    xi, yi = np.asarray(xi), np.asarray(yi)
    out = np.zeros(np.broadcast(xi, yi).shape, dtype=np.uint32)
    for i, bit_rects in enumerate(prog.rects):
        bit = prog.out_bits - 1 - i
        match = np.zeros(out.shape, dtype=bool)
        for r in bit_rects:
            match |= r.contains(xi, yi)
        out |= match.astype(np.uint32) << bit
    if prog.encoded:
        from .gray import gray_decode

        out = gray_decode(out, prog.out_bits)
    return out


# --------------------------------------------------------------------------
# Array sizing / cost (Section V-B): 4x8 arrays, shared-ML groups of 16.
# --------------------------------------------------------------------------

@dataclasses.dataclass
class ArrayCost:
    num_cells: int
    rows: int
    arrays: float  # fractional 4x8 arrays (rows / 4)
    groups: int
    utilization: float  # used cells / provisioned cells


def array_cost(prog) -> ArrayCost:
    rows = prog.rows_needed(ACAM_ARRAY_COLS)
    arrays = rows / ACAM_ARRAY_ROWS
    groups = max(1, -(-int(np.ceil(arrays)) // ACAM_ARRAYS_PER_GROUP))
    provisioned = rows * ACAM_ARRAY_COLS
    return ArrayCost(
        num_cells=prog.num_cells,
        rows=rows,
        arrays=arrays,
        groups=groups,
        utilization=prog.num_cells / max(provisioned, 1),
    )
