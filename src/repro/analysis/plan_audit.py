"""Dispatch-totality audit: the registry/plan layer is total and live.

Exhaustively resolves execution plans over the full declarative matrix —
every `configs.catalog` architecture x mode x fused x softmax flavor x
matmul fidelity x device-noise preset — and audits the result:

PA101 — `resolve_plan` must never raise for an in-matrix config
    (degrades are recorded on the plan, never thrown).
PA102 — no capability predicate may raise: `supported(mcfg, ecfg)`
    returns None or a reason string for every registered backend against
    every matrix pair.
PA103 — every slot chain terminates in the digital baseline: the
    baseline backend exists, its predicate accepts every matrix pair, and
    every resolved plan populates every slot.
PA104 — every registered backend is *reachable*: some matrix config
    (directly or via a one-slot `op_overrides` pin) resolves to it. A
    backend nothing can reach is dead registration — a finding.
PA105 — every backend-style name (`raceit_*`) mentioned in README, docs/
    and `benchmarks/expected_rows.txt` exists in the registry or the
    public kernel API; docs must not advertise backends that don't exist.
PA106 — override order must not change the plan-cache key: two
    `ExecConfig`s carrying the same pins in different orders must be
    equal and hash-equal (else the lru cache silently doubles).
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import pathlib
import re
import warnings
from typing import Optional

from .findings import REPO_ROOT, Finding


def _anchor(obj) -> tuple[str, int]:
    try:
        path = inspect.getsourcefile(obj)
        line = inspect.getsourcelines(obj)[1]
        return str(pathlib.Path(path).resolve().relative_to(REPO_ROOT)), line
    except (TypeError, OSError, ValueError):
        return "src/repro/exec/plan.py", 0


def _matrix():
    from repro.configs import get_config
    from repro.configs.base import ExecConfig
    from repro.configs.catalog import ASSIGNED, PAPER_OWN
    from repro.dist import MeshSpec
    from repro.hw.noise import NoiseConfig

    models = [get_config(n) for n in list(ASSIGNED) + list(PAPER_OWN)]
    noise = NoiseConfig.preset("nominal")
    # mesh axis: resolution is device-independent (predicates only read
    # MeshSpec.model_size; nothing builds the mesh), so the audit covers
    # the sharded raceit_*_tp chains — including the model=3 non-divisor
    # degrade and a data+model mesh — on a 1-device host.
    meshes = (None, MeshSpec.parse("model=4"), MeshSpec.parse("model=3"),
              MeshSpec.parse("data=2,model=2"))
    execs = []
    seen = set()
    for mode, fused, softmax, fidelity, nz, mesh in itertools.product(
            ("digital", "raceit"), (False, True), ("pot", "uniform"),
            ("int", "acam"), (None, noise), meshes):
        ec = ExecConfig(mode=mode, fused_attention=fused,
                        softmax_mode=softmax, matmul_fidelity=fidelity,
                        noise=nz, mesh=mesh)
        if ec not in seen:
            seen.add(ec)
            execs.append(ec)
    return models, execs


def _describe(mcfg, ecfg) -> str:
    nz = "none" if ecfg.noise is None else "nominal"
    mesh = "none" if ecfg.mesh is None else ecfg.mesh.describe()
    return (f"{mcfg.name}/mode={ecfg.mode},fused={ecfg.fused_attention},"
            f"softmax={ecfg.softmax_mode},fidelity={ecfg.matmul_fidelity},"
            f"noise={nz},mesh={mesh}")


def run() -> tuple[list[Finding], dict]:
    # in-matrix degrades (fused+noise, fused+acam, ...) are expected and
    # recorded on the plans; their one-time warnings are not audit output
    with warnings.catch_warnings():
        warnings.filterwarnings("ignore", category=RuntimeWarning,
                                message=".*falling back.*")
        return _run()


def _run() -> tuple[list[Finding], dict]:
    from repro.exec.plan import _BASELINE, resolve_plan, reset_plan_cache
    from repro.exec.registry import OP_SLOTS, get_backend, list_backends

    findings: list[Finding] = []
    models, execs = _matrix()
    backends = list_backends()      # slot -> {name: spec}, forces import
    reset_plan_cache()

    plan_path, plan_line = _anchor(resolve_plan)

    # --- PA101/PA103: total resolution, every slot lands somewhere -------
    plans = 0
    for mcfg in models:
        for ecfg in execs:
            try:
                plan = resolve_plan(mcfg, ecfg)
                plans += 1
            except Exception as e:   # noqa: BLE001 — the audit's whole point
                findings.append(Finding(
                    "plan_audit", "PA101", plan_path, plan_line,
                    _describe(mcfg, ecfg),
                    f"resolve_plan raised {type(e).__name__}: {e}"))
                continue
            missing = [s for s in OP_SLOTS if s not in
                       {op.slot for op in plan.ops}]
            if missing:
                findings.append(Finding(
                    "plan_audit", "PA103", plan_path, plan_line,
                    _describe(mcfg, ecfg),
                    f"resolved plan is missing slots {missing}"))

    # --- PA102/PA103: predicates never raise; baselines always accept ----
    pred_calls = 0
    for slot, named in sorted(backends.items()):
        base_name = _BASELINE[slot][0]
        base = get_backend(slot, base_name)
        if base is None:
            findings.append(Finding(
                "plan_audit", "PA103", plan_path, plan_line, slot,
                f"slot has no {base_name!r} baseline backend registered"))
            continue
        for name, spec in sorted(named.items()):
            spath, sline = _anchor(spec.impl)
            for mcfg in models:
                for ecfg in execs:
                    pred_calls += 1
                    try:
                        reason = spec.supported(mcfg, ecfg)
                    except Exception as e:  # noqa: BLE001
                        findings.append(Finding(
                            "plan_audit", "PA102", spath, sline,
                            f"{slot}:{name}",
                            f"capability predicate raised "
                            f"{type(e).__name__}: {e} for "
                            f"{_describe(mcfg, ecfg)}"))
                        break
                    if name == base_name and reason is not None:
                        findings.append(Finding(
                            "plan_audit", "PA103", spath, sline,
                            f"{slot}:{name}",
                            f"baseline backend rejects "
                            f"{_describe(mcfg, ecfg)}: {reason} — the "
                            f"slot chain cannot terminate"))
                        break
                else:
                    continue
                break

    # --- PA104: every registered backend reachable -----------------------
    unreachable = []
    for slot, named in sorted(backends.items()):
        for name, spec in sorted(named.items()):
            reached = False
            for mcfg in models:
                for ecfg in execs:
                    try:
                        pinned = dataclasses.replace(
                            ecfg, op_overrides=((slot, name),))
                        if resolve_plan(mcfg, pinned).backend(slot) == name:
                            reached = True
                            break
                    except Exception:  # noqa: BLE001 — PA101 covers raises
                        continue
                if reached:
                    break
            if not reached:
                spath, sline = _anchor(spec.impl)
                findings.append(Finding(
                    "plan_audit", "PA104", spath, sline, f"{slot}:{name}",
                    "backend is unreachable: no matrix config, even with "
                    "an explicit op_overrides pin, resolves to it"))
                unreachable.append(f"{slot}:{name}")

    # --- PA105: names advertised in docs/bench gates exist ---------------
    findings += _audit_doc_names(backends)

    # --- PA106: override order must not split the cache key --------------
    from repro.configs.base import ExecConfig
    a = ExecConfig(op_overrides=(("lm_head", "raceit_q8"),
                                 ("softmax", "digital")))
    b = ExecConfig(op_overrides=(("softmax", "digital"),
                                 ("lm_head", "raceit_q8")))
    if a != b or hash(a) != hash(b):
        import repro.configs.base as base_mod
        findings.append(Finding(
            "plan_audit", "PA106", _anchor(base_mod.ExecConfig)[0],
            _anchor(base_mod.ExecConfig)[1], "ExecConfig.op_overrides",
            "the same overrides in a different order produce unequal "
            "configs — duplicate resolve_plan cache entries"))

    stats = dict(
        models=len(models), exec_configs=len(execs), plans_resolved=plans,
        predicate_calls=pred_calls,
        backends=sum(len(v) for v in backends.values()),
        unreachable=unreachable,
    )
    return findings, stats


_NAME_RE = re.compile(r"\braceit_[a-z0-9_]+\b")


def _audit_doc_names(backends) -> list[Finding]:
    import repro.core.attention as core_attn_mod
    import repro.kernels.ops as ops_mod

    known = {n for named in backends.values() for n in named}
    for mod in (ops_mod, core_attn_mod):
        known |= {n for n in dir(mod) if not n.startswith("_")}
    try:
        import repro.exec.noisy as noisy_mod
        known |= {n for n in dir(noisy_mod) if not n.startswith("_")}
    except ImportError:
        pass
    # launcher/example script stems (docs reference them by filename)
    for d in (REPO_ROOT / "examples", REPO_ROOT / "src" / "repro" / "launch"):
        if d.exists():
            known |= {p.stem for p in d.glob("*.py")}

    findings: list[Finding] = []
    targets = [REPO_ROOT / "README.md",
               REPO_ROOT / "benchmarks" / "expected_rows.txt"]
    targets += sorted((REPO_ROOT / "docs").glob("*.md"))
    for path in targets:
        if not path.exists():
            continue
        rel = str(path.relative_to(REPO_ROOT))
        for lineno, line in enumerate(path.read_text().splitlines(), 1):
            for tok in _NAME_RE.findall(line):
                if tok in known:
                    continue
                if tok.endswith("_") and any(n.startswith(tok)
                                             for n in known):
                    continue   # family glob like raceit_noisy_*
                findings.append(Finding(
                    "plan_audit", "PA105", rel, lineno, tok,
                    f"references backend-style name `{tok}` that is not "
                    f"in the registry or public kernel API"))
    return findings
