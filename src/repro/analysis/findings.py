"""Structured findings + the committed suppression file.

Every analysis pass reports `Finding` records — one per violated proof
obligation or lint rule, each anchored to a real file:line so the CLI
output is clickable. Known-and-justified exceptions live in the committed
`analysis_suppressions.txt` at the repo root: one line per exception with
a mandatory justification. A suppression that matches no current finding
is *stale* and becomes a finding itself (rule SUP001), so the file can
only shrink when the code actually improves.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Iterable, Optional

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_SUPPRESSION_FILE = REPO_ROOT / "analysis_suppressions.txt"

# Rule registry (id -> one-line meaning). Keeping it here makes rule ids a
# closed set: a suppression naming an unknown rule is itself a finding.
RULES = {
    # kernelcheck — BlockSpec / index-map contract proofs
    "KC101": "block index provably or possibly out of bounds",
    "KC102": "dead-block clamp is not a fixed point (kv operand refetches)",
    "KC103": "dead-block fetch not elided (non-kv operand, k-dependent map)",
    "KC104": "output BlockSpec index map depends on prefetched scalars",
    "KC105": "block-table column consulted beyond the live page frontier",
    "KC106": "estimated VMEM footprint exceeds the declared budget",
    "KC107": "paged cache write routing violates the trash-page fence",
    "KC108": "page allocator can issue the trash page",
    "KC109": "scalar-prefetch vector indexed out of bounds by an index map",
    # tracelint — trace-safety AST lint
    "TL101": "Python branch on a traced value inside a jit/pallas scope",
    "TL102": "tracer concretization (int()/float()/bool()/.item()) in jit scope",
    "TL103": "shape-dependent fallback branch inside a registered backend impl",
    "TL104": "plan-cache key dataclass member unhashable or order-unstable",
    # plan_audit — dispatch totality
    "PA101": "plan resolution raised for an in-matrix config",
    "PA102": "capability predicate raised instead of returning a reason",
    "PA103": "slot chain does not terminate in the digital baseline",
    "PA104": "registered backend unreachable by any matrix config or override",
    "PA105": "backend name referenced in docs/bench rows missing from registry",
    "PA106": "override-order changes the resolve_plan cache key",
    # suppression hygiene
    "SUP001": "stale suppression: matches no current finding",
    "SUP002": "malformed suppression line",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    tool: str            # "kernelcheck" | "tracelint" | "plan_audit" | ...
    rule: str            # key of RULES
    path: str            # repo-relative file the finding anchors to
    line: int            # 1-based line number (0 = whole file)
    site: str            # stable anchor, e.g. "decode_paged_gqa:k"
    message: str
    severity: str = "error"   # "error" | "warn"

    def render(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        return f"[{self.rule}] {loc} ({self.site}) {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    rule: str
    path: str
    fragment: str        # substring matched against finding.site + message
    justification: str
    lineno: int

    def matches(self, f: Finding) -> bool:
        return (f.rule == self.rule and f.path == self.path
                and (self.fragment in f.site or self.fragment in f.message))


def load_suppressions(path: Optional[pathlib.Path] = None,
                      ) -> tuple[list[Suppression], list[Finding]]:
    """Parse the suppression file; malformed lines come back as findings."""
    path = pathlib.Path(path) if path else DEFAULT_SUPPRESSION_FILE
    sups: list[Suppression] = []
    bad: list[Finding] = []
    if not path.exists():
        return sups, bad
    rel = _rel(path)
    for i, raw in enumerate(path.read_text().splitlines(), start=1):
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        parts = [p.strip() for p in line.split("|")]
        if len(parts) != 4 or not all(parts):
            bad.append(Finding("suppressions", "SUP002", rel, i, f"line {i}",
                               f"expected 'RULE | path | fragment | why', "
                               f"got {raw!r}"))
            continue
        rule, fpath, fragment, why = parts
        if rule not in RULES:
            bad.append(Finding("suppressions", "SUP002", rel, i, f"line {i}",
                               f"unknown rule {rule!r}"))
            continue
        sups.append(Suppression(rule, fpath, fragment, why, i))
    return sups, bad


def apply_suppressions(findings: Iterable[Finding],
                       suppressions: Iterable[Suppression],
                       suppression_path: Optional[pathlib.Path] = None,
                       ) -> tuple[list[Finding], list[Finding], list[Finding]]:
    """Split findings into (active, suppressed) and report stale suppressions.

    Returns (active, suppressed, stale) where `stale` are SUP001 findings
    for suppression lines that matched nothing.
    """
    suppressions = list(suppressions)
    findings = list(findings)
    hit = [False] * len(suppressions)
    active, suppressed = [], []
    for f in findings:
        matched = False
        for j, s in enumerate(suppressions):
            if s.matches(f):
                hit[j] = True
                matched = True
        (suppressed if matched else active).append(f)
    rel = _rel(pathlib.Path(suppression_path)
               if suppression_path else DEFAULT_SUPPRESSION_FILE)
    stale = [Finding("suppressions", "SUP001", rel, s.lineno,
                     f"{s.rule}|{s.fragment}",
                     f"suppression matches no current finding "
                     f"(justified as: {s.justification})")
             for j, s in enumerate(suppressions) if not hit[j]]
    return active, suppressed, stale


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.resolve().relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


def render_report(active: list[Finding], suppressed: list[Finding],
                  stale: list[Finding], coverage: dict) -> str:
    out = []
    for title, group in (("FINDINGS", active), ("STALE SUPPRESSIONS", stale)):
        if group:
            out.append(f"== {title} ({len(group)}) ==")
            out += [f.render() for f in group]
    if suppressed:
        out.append(f"== suppressed ({len(suppressed)}, justified in "
                   f"analysis_suppressions.txt) ==")
        out += [f"  {f.render()}" for f in suppressed]
    out.append("== coverage ==")
    for k in sorted(coverage):
        out.append(f"  {k}: {coverage[k]}")
    verdict = "CLEAN" if not active and not stale else "FAIL"
    out.append(f"analysis: {verdict} ({len(active)} active finding(s), "
               f"{len(stale)} stale suppression(s), "
               f"{len(suppressed)} suppressed)")
    return "\n".join(out)


def to_json(active, suppressed, stale, coverage) -> str:
    return json.dumps({
        "active": [f.to_json() for f in active],
        "suppressed": [f.to_json() for f in suppressed],
        "stale": [f.to_json() for f in stale],
        "coverage": coverage,
    }, indent=2, sort_keys=True)
