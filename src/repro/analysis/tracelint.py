"""Trace-safety AST lint over src/repro.

Static companions to the dynamic kernel probes — each rule targets a bug
class this repo has actually hit or is structurally exposed to:

TL101 — Python `if`/`while` on a *traced* value inside a jit scope or a
    Pallas kernel body. Traced scopes are found syntactically: functions
    decorated with `jax.jit` / `partial(jax.jit, ...)` (parameters not in
    `static_argnames` are traced) and `*_kernel` functions in `kernels/`
    (every Ref parameter's loads are traced), plus their nested defs.
    `x is None` tests, `.shape`/`.ndim`/`.dtype` inspection, and branches
    on static arguments are all fine — the lint taints values, not names.

TL102 — tracer concretization: `int()`/`float()`/`bool()` or `.item()`/
    `.tolist()` on a traced value in a traced scope. These raise
    `ConcretizationTypeError` at trace time on TPU paths that interpret
    mode can mask.

TL103 — shape-dependent fallback branch inside a `@register(...)`-ed
    backend implementation (warn): capability decisions belong in the
    `supported=` predicate where resolve_plan can record a structured
    degrade, not silently inside the impl. The known fused-path
    `RACEIT_ATTENTION_MAX_KEYS` fallbacks are suppressed with
    justification rather than exempted in code, so the next one is loud.

TL104 — plan-cache key hygiene on the dataclasses in `resolve_plan`'s
    lru_cache key (found by reading `exec/plan.py`, not hardcoded):
    * list/dict/set-annotated fields are unhashable — always an error;
    * fields with opaque annotations (`object`, `Any`, …) must be
      fail-fast hashed in `__post_init__` (`hash(self.<field>)`), the
      guard PR 6's hand-added `ExecConfig.noise` needed;
    * a field the class itself sorts in a `with_*` builder is
      order-insensitive by its own admission, so `__post_init__` must
      canonicalize it too — direct construction must not mint a second
      cache entry for the same logical config.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Optional

from .findings import REPO_ROOT, Finding

SRC = REPO_ROOT / "src" / "repro"
SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize"}
CONCRETIZERS = {"int", "float", "bool"}
CONCRETIZER_METHODS = {"item", "tolist"}
HASHABLE_ANNOTATIONS = {"int", "float", "bool", "str", "bytes", "tuple"}


def _rel(path: pathlib.Path) -> str:
    try:
        return str(path.resolve().relative_to(REPO_ROOT))
    except ValueError:
        return str(path)


# ---------------------------------------------------------------------------
# scope discovery
# ---------------------------------------------------------------------------

def _jit_static_names(dec: ast.expr) -> Optional[set]:
    """If `dec` is a jit decorator, return its static_argnames (else None)."""
    target = dec
    statics: set = set()
    if isinstance(dec, ast.Call):
        fn = dec.func
        # functools.partial(jax.jit, static_argnames=(...)) | jax.jit(...)
        if (isinstance(fn, ast.Attribute) and fn.attr == "partial") or (
                isinstance(fn, ast.Name) and fn.id == "partial"):
            if not dec.args:
                return None
            target = dec.args[0]
        else:
            target = fn
        for kw in dec.keywords:
            if kw.arg == "static_argnames":
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) and isinstance(
                            node.value, str):
                        statics.add(node.value)
    if isinstance(target, ast.Attribute) and target.attr == "jit":
        return statics
    if isinstance(target, ast.Name) and target.id == "jit":
        return statics
    return None


def _register_decorator(dec: ast.expr) -> bool:
    if isinstance(dec, ast.Call):
        fn = dec.func
        name = fn.attr if isinstance(fn, ast.Attribute) else getattr(
            fn, "id", "")
        return name == "register"
    return False


def _supported_predicates(tree: ast.AST) -> set:
    """Names passed as supported=/serving_supported= to @register calls."""
    preds: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _register_decorator(node):
            for kw in node.keywords:
                if kw.arg and "supported" in kw.arg and isinstance(
                        kw.value, ast.Name):
                    preds.add(kw.value.id)
    return preds


# ---------------------------------------------------------------------------
# taint walk within one traced scope
# ---------------------------------------------------------------------------

class _Taint:
    def __init__(self, tainted: set):
        self.tainted = set(tainted)

    def expr_tainted(self, node: ast.expr) -> bool:
        """Does evaluating `node` produce a traced value? `.shape`-family
        attribute access and len() launder taint (static under tracing)."""
        if isinstance(node, ast.Attribute) and node.attr in SHAPE_ATTRS:
            return False
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id == "len":
                return False
            if isinstance(fn, ast.Attribute) and fn.attr in SHAPE_ATTRS:
                return False
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False     # `x is None` yields a Python bool
        return any(self.expr_tainted(c) for c in ast.iter_child_nodes(node)
                   if isinstance(c, ast.expr))

    def assign(self, stmt: ast.stmt):
        if isinstance(stmt, ast.Assign):
            if self.expr_tainted(stmt.value):
                for t in stmt.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            self.tainted.add(n.id)
        elif isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name) and (
                    self.expr_tainted(stmt.value)
                    or stmt.target.id in self.tainted):
                self.tainted.add(stmt.target.id)


def _lint_traced_scope(fn: ast.FunctionDef, statics: set, rel: str,
                       is_kernel: bool) -> list[Finding]:
    findings: list[Finding] = []
    params = [a.arg for a in (fn.args.posonlyargs + fn.args.args
                              + fn.args.kwonlyargs)]
    tainted = {p for p in params if p not in statics}
    if is_kernel:
        # kernel kwonly params are compile-time closures bound via partial
        tainted -= {a.arg for a in fn.args.kwonlyargs}
    taint = _Taint(tainted)
    site = fn.name

    for node in ast.walk(fn):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            taint.assign(node)

    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            if taint.expr_tainted(node.test):
                kind = "while" if isinstance(node, ast.While) else "if"
                findings.append(Finding(
                    "tracelint", "TL101", rel, node.lineno, site,
                    f"Python `{kind}` on a traced value "
                    f"({ast.unparse(node.test)}) — use jnp.where/"
                    f"lax.cond/pl.when"))
        elif isinstance(node, ast.Call):
            fnode = node.func
            if isinstance(fnode, ast.Name) and fnode.id in CONCRETIZERS:
                if node.args and taint.expr_tainted(node.args[0]):
                    findings.append(Finding(
                        "tracelint", "TL102", rel, node.lineno, site,
                        f"`{fnode.id}()` on a traced value "
                        f"({ast.unparse(node.args[0])})"))
            elif isinstance(fnode, ast.Attribute) and \
                    fnode.attr in CONCRETIZER_METHODS:
                if taint.expr_tainted(fnode.value):
                    findings.append(Finding(
                        "tracelint", "TL102", rel, node.lineno, site,
                        f"`.{fnode.attr}()` on a traced value "
                        f"({ast.unparse(fnode.value)})"))
    return findings


def _lint_backend_impl(fn: ast.FunctionDef, rel: str) -> list[Finding]:
    """TL103: shape-derived `if` fallbacks inside a registered backend."""
    findings: list[Finding] = []
    shape_names: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            if any(isinstance(n, ast.Attribute) and n.attr in SHAPE_ATTRS
                   for n in ast.walk(node.value)):
                for t in node.targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            shape_names.add(n.id)
    for node in ast.walk(fn):
        if not isinstance(node, ast.If):
            continue
        test_names = {n.id for n in ast.walk(node.test)
                      if isinstance(n, ast.Name)}
        direct = any(isinstance(n, ast.Attribute) and n.attr in SHAPE_ATTRS
                     for n in ast.walk(node.test))
        if direct or (test_names & shape_names):
            findings.append(Finding(
                "tracelint", "TL103", rel, node.lineno, fn.name,
                f"shape-dependent fallback `{ast.unparse(node.test)}` "
                f"inside a registered backend impl — belongs in the "
                f"supported= capability predicate", severity="warn"))
    return findings


# ---------------------------------------------------------------------------
# TL104: plan-cache key dataclass hygiene
# ---------------------------------------------------------------------------

def _cache_key_classes(plan_path: pathlib.Path) -> set:
    """Annotation names of lru_cache'd resolve-function params in plan.py."""
    classes: set = set()
    try:
        tree = ast.parse(plan_path.read_text())
    except (OSError, SyntaxError):
        return classes
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        cached = any("lru_cache" in ast.unparse(d) or "cache" == getattr(
            getattr(d, "attr", None), "__str__", lambda: "")()
            for d in node.decorator_list)
        if not cached:
            continue
        for a in node.args.args + node.args.kwonlyargs:
            if a.annotation is not None:
                ann = ast.unparse(a.annotation)
                classes.add(ann.split("[")[0].split(".")[-1])
    return classes


def _lint_cache_key_class(cls: ast.ClassDef, rel: str) -> list[Finding]:
    findings: list[Finding] = []
    post = next((n for n in cls.body if isinstance(n, ast.FunctionDef)
                 and n.name == "__post_init__"), None)
    post_src = ast.unparse(post) if post else ""

    # fields the class itself sorts in any builder method -> must be
    # canonicalized at construction time too. The builder idiom is
    # `dataclasses.replace(self, field=tuple(sorted(...)))`, so look for
    # any call keyword named after a field whose value contains sorted()
    field_names = {f.target.id for f in cls.body
                   if isinstance(f, ast.AnnAssign)
                   and isinstance(f.target, ast.Name)}
    sorted_fields: set = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Call):
            continue
        for kw in node.keywords:
            if kw.arg in field_names and "sorted(" in ast.unparse(kw.value):
                sorted_fields.add(kw.arg)
        if isinstance(node.func, ast.Name) and node.func.id == "sorted":
            src = ast.unparse(node)
            sorted_fields |= {n for n in field_names if n in src}

    for f in cls.body:
        if not (isinstance(f, ast.AnnAssign)
                and isinstance(f.target, ast.Name)):
            continue
        name = f.target.id
        ann = ast.unparse(f.annotation)
        base = ann.replace("Optional[", "").rstrip("]").split("[")[0]
        site = f"{cls.name}.{name}"
        if base in ("list", "List", "dict", "Dict", "set", "Set"):
            findings.append(Finding(
                "tracelint", "TL104", rel, f.lineno, site,
                f"unhashable annotation `{ann}` on a plan-cache key field"))
        elif base not in HASHABLE_ANNOTATIONS:
            if f"hash(self.{name})" not in post_src:
                findings.append(Finding(
                    "tracelint", "TL104", rel, f.lineno, site,
                    f"opaque annotation `{ann}` on a plan-cache key field "
                    f"without a fail-fast `hash(self.{name})` in "
                    f"__post_init__"))
        if name in sorted_fields:
            if "sorted" not in post_src or name not in post_src:
                findings.append(Finding(
                    "tracelint", "TL104", rel, f.lineno, site,
                    f"`{name}` is sorted by a builder method (order is "
                    f"non-semantic) but __post_init__ does not "
                    f"canonicalize it — direct construction mints "
                    f"duplicate cache entries"))
    return findings


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def lint_source(src: str, rel: str, in_kernels: bool,
                ) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []
    tree = ast.parse(src)
    preds = _supported_predicates(tree)
    scopes = 0

    def visit_fn(fn: ast.FunctionDef, inherited: Optional[set]):
        nonlocal scopes
        statics = inherited
        for dec in fn.decorator_list:
            s = _jit_static_names(dec)
            if s is not None:
                statics = s
        is_kernel = in_kernels and "kernel" in fn.name
        if statics is not None or is_kernel:
            scopes += 1
            findings.extend(_lint_traced_scope(
                fn, statics or set(), rel, is_kernel))
            child_statics: Optional[set] = statics or set()
        else:
            child_statics = None
        if any(_register_decorator(d) for d in fn.decorator_list) \
                and fn.name not in preds:
            findings.extend(_lint_backend_impl(fn, rel))
        for node in fn.body:
            for sub in ast.walk(node):
                if isinstance(sub, ast.FunctionDef):
                    visit_fn(sub, child_statics)
                    break

    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            visit_fn(node, None)
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, ast.FunctionDef):
                    visit_fn(sub, None)
    return findings, dict(traced_scopes=scopes)


def run(root: Optional[pathlib.Path] = None) -> tuple[list[Finding], dict]:
    root = pathlib.Path(root) if root else SRC
    findings: list[Finding] = []
    files = scopes = 0
    for path in sorted(root.rglob("*.py")):
        if "analysis" in path.parts:
            continue
        rel = _rel(path)
        try:
            src = path.read_text()
        except OSError:
            continue
        files += 1
        in_kernels = "kernels" in path.parts
        f, stats = lint_source(src, rel, in_kernels)
        findings += f
        scopes += stats["traced_scopes"]

    # cache-key hygiene on whatever classes resolve_plan's cache keys on
    plan_path = root / "exec" / "plan.py"
    key_classes = _cache_key_classes(plan_path) if plan_path.exists() else set()
    checked = []
    if key_classes:
        cfg_path = root / "configs" / "base.py"
        if cfg_path.exists():
            tree = ast.parse(cfg_path.read_text())
            for node in ast.walk(tree):
                if isinstance(node, ast.ClassDef) and node.name in key_classes:
                    checked.append(node.name)
                    findings += _lint_cache_key_class(node, _rel(cfg_path))
    stats = dict(files=files, traced_scopes=scopes,
                 cache_key_classes=sorted(checked))
    return findings, stats
