"""CLI: `python -m repro.analysis [--strict] [--json] [--write-contracts]`.

Exit status: 0 when every proof obligation holds (all findings either
absent or justified in `analysis_suppressions.txt`, no stale
suppressions); 1 otherwise. `--strict` is accepted for explicitness and
CI readability — the gate is always strict; without it the report still
prints but a dirty tree only warns (exit 0), which is the local
iterate-on-a-fix mode.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from . import (DEFAULT_SUPPRESSION_FILE, apply_suppressions,
               load_suppressions, render_report, run_all, to_json)
from .findings import REPO_ROOT


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any active finding or stale "
                         "suppression (CI mode)")
    ap.add_argument("--json", action="store_true",
                    help="emit machine-readable findings JSON")
    ap.add_argument("--write-contracts", metavar="PATH", nargs="?",
                    const=str(REPO_ROOT / "docs" / "kernel_contracts.md"),
                    default=None,
                    help="write the per-kernel contract report "
                         "(default docs/kernel_contracts.md)")
    ap.add_argument("--suppressions", metavar="PATH",
                    default=str(DEFAULT_SUPPRESSION_FILE),
                    help="suppression file (default %(default)s)")
    args = ap.parse_args(argv)

    findings, coverage, contracts = run_all()
    sups, malformed = load_suppressions(pathlib.Path(args.suppressions))
    active, suppressed, stale = apply_suppressions(
        findings, sups, pathlib.Path(args.suppressions))
    active = malformed + active

    if args.write_contracts:
        out = pathlib.Path(args.write_contracts)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(contracts)
        print(f"wrote {out}", file=sys.stderr)

    if args.json:
        print(to_json(active, suppressed, stale, coverage))
    else:
        print(render_report(active, suppressed, stale, coverage))

    dirty = bool(active or stale)
    if dirty and not args.strict:
        print("(non-strict: exiting 0 despite findings)", file=sys.stderr)
    return 1 if (dirty and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
