"""Static analysis for the Pallas stack: proofs before TPU.

Three passes, one CLI (`python -m repro.analysis --strict`):

* `kernelcheck` — abstract interpretation of every captured BlockSpec
  index map over the full grid and scalar-prefetch domain (bounds,
  dead-block clamp fixed points, trash-page fencing, VMEM budgets).
* `tracelint`  — AST lint for trace-safety (traced branches, tracer
  concretization, shape fallbacks in backends, plan-cache key hygiene).
* `plan_audit` — exhaustive dispatch totality over the config matrix
  (no raises, digital termination, no dead backends, honest docs).

Findings are structured `findings.Finding` records with file:line
anchors; justified exceptions live in `analysis_suppressions.txt` at the
repo root, and stale suppressions are findings themselves.
"""
from __future__ import annotations

from . import findings as findings_mod
from .findings import (DEFAULT_SUPPRESSION_FILE, Finding, RULES,
                       apply_suppressions, load_suppressions,
                       render_report, to_json)

__all__ = ["Finding", "RULES", "run_all", "load_suppressions",
           "apply_suppressions", "render_report", "to_json",
           "DEFAULT_SUPPRESSION_FILE"]


def run_all() -> tuple[list, dict, str]:
    """Run every pass: (findings, merged coverage, contracts markdown)."""
    from . import kernelcheck, plan_audit, tracelint

    kc_findings, kc_cov, contracts = kernelcheck.run()
    tl_findings, tl_cov = tracelint.run()
    pa_findings, pa_cov = plan_audit.run()
    coverage = {}
    for prefix, cov in (("kernelcheck", kc_cov), ("tracelint", tl_cov),
                        ("plan_audit", pa_cov)):
        for k, v in cov.items():
            coverage[f"{prefix}.{k}"] = v
    return kc_findings + tl_findings + pa_findings, coverage, contracts
