"""Integer interval arithmetic + symbolic expressions for index-map proofs.

Two abstract domains, both driven through the *real* BlockSpec index-map
closures (no re-implementation of the maps, so the proof can't drift from
the code):

* `Iv` — closed integer intervals. Sound over +, -, *, //, %, min, max
  for the operations the kernels' index maps use. Evaluating a map with
  prefetched scalars as intervals yields an interval per block-index
  component; bounds proofs compare those against the operand's block grid.

* `Sym` — opaque integer expression trees with structural equality.
  Block-table lookups return a `Sym` leaf keyed by the accessed cell, so
  two evaluations of a map produce equal trees iff they read the same
  table cells and combine them identically — exactly the "clamped dead
  block re-addresses the live frontier's tile" fixed-point obligation,
  valid for *every* table permutation at once.

Index maps call `jnp.minimum`/`jnp.maximum`; evaluation temporarily swaps
the map's module-global `jnp` for `JnpProxy`, which dispatches to the
abstract domain when either argument is abstract and to real jnp
otherwise.
"""
from __future__ import annotations

from typing import Any, Union

Num = Union[int, "Iv", "Sym"]


class Iv:
    """Closed integer interval [lo, hi]."""

    __slots__ = ("lo", "hi")

    def __init__(self, lo: int, hi: int):
        if lo > hi:
            raise ValueError(f"empty interval [{lo}, {hi}]")
        self.lo, self.hi = int(lo), int(hi)

    @staticmethod
    def lift(x: Num) -> "Iv":
        if isinstance(x, Iv):
            return x
        if isinstance(x, Sym):
            raise TypeError("cannot lift a symbolic value to an interval")
        return Iv(int(x), int(x))

    @property
    def concrete(self) -> bool:
        return self.lo == self.hi

    def __repr__(self):
        return f"[{self.lo},{self.hi}]" if not self.concrete else f"[{self.lo}]"

    def __add__(self, o):
        if isinstance(o, Sym):
            return NotImplemented
        o = Iv.lift(o)
        return Iv(self.lo + o.lo, self.hi + o.hi)

    __radd__ = __add__

    def __sub__(self, o):
        if isinstance(o, Sym):
            return NotImplemented
        o = Iv.lift(o)
        return Iv(self.lo - o.hi, self.hi - o.lo)

    def __rsub__(self, o):
        return Iv.lift(o).__sub__(self)

    def __mul__(self, o):
        if isinstance(o, Sym):
            return NotImplemented
        o = Iv.lift(o)
        c = [self.lo * o.lo, self.lo * o.hi, self.hi * o.lo, self.hi * o.hi]
        return Iv(min(c), max(c))

    __rmul__ = __mul__

    def __floordiv__(self, o):
        o = Iv.lift(o)
        if not o.concrete or o.lo <= 0:
            raise ValueError(f"interval floordiv by {o}: need a positive "
                             f"constant divisor")
        return Iv(self.lo // o.lo, self.hi // o.lo)

    def __mod__(self, o):
        o = Iv.lift(o)
        if not o.concrete or o.lo <= 0:
            raise ValueError(f"interval mod by {o}: need a positive "
                             f"constant divisor")
        d = o.lo
        if self.lo // d == self.hi // d and self.lo >= 0:
            return Iv(self.lo % d, self.hi % d)  # same quotient: exact
        return Iv(0, d - 1)

    # equality is *structural* (used by the fixed-point comparison on
    # degenerate intervals); ordering is deliberately not defined.
    def __eq__(self, o):
        if isinstance(o, Iv):
            return self.lo == o.lo and self.hi == o.hi
        if isinstance(o, int) or (hasattr(o, "__int__")
                                  and not isinstance(o, Sym)):
            return self.concrete and self.lo == int(o)
        return NotImplemented

    def __hash__(self):
        return hash(("Iv", self.lo, self.hi))

    @staticmethod
    def min2(a: Num, b: Num) -> "Iv":
        a, b = Iv.lift(a), Iv.lift(b)
        return Iv(min(a.lo, b.lo), min(a.hi, b.hi))

    @staticmethod
    def max2(a: Num, b: Num) -> "Iv":
        a, b = Iv.lift(a), Iv.lift(b)
        return Iv(max(a.lo, b.lo), max(a.hi, b.hi))


class Sym:
    """Opaque integer expression with structural equality.

    Leaves are `("var", key)`; internal nodes record the operator and
    operand trees. Two `Sym`s compare equal iff their trees are identical,
    which for index maps means: same table cells read, same arithmetic
    applied — equal for any concrete table contents.
    """

    __slots__ = ("op", "args")

    def __init__(self, op: str, args: tuple):
        self.op = op
        self.args = args

    @staticmethod
    def var(key: Any) -> "Sym":
        return Sym("var", (key,))

    @staticmethod
    def _norm(x) -> Any:
        if isinstance(x, Iv):
            if not x.concrete:
                raise TypeError(f"symbolic arithmetic with a non-degenerate "
                                f"interval {x}")
            return x.lo
        return x

    def _bin(self, op, a, b):
        a, b = Sym._norm(a), Sym._norm(b)
        return Sym(op, (a, b))

    def __add__(self, o):
        return self._bin("add", self, o)

    def __radd__(self, o):
        return self._bin("add", o, self)

    def __sub__(self, o):
        return self._bin("sub", self, o)

    def __rsub__(self, o):
        return self._bin("sub", o, self)

    def __mul__(self, o):
        return self._bin("mul", self, o)

    def __rmul__(self, o):
        return self._bin("mul", o, self)

    def __floordiv__(self, o):
        return self._bin("floordiv", self, o)

    def __mod__(self, o):
        return self._bin("mod", self, o)

    def __eq__(self, o):
        if not isinstance(o, Sym):
            return False
        return self.op == o.op and len(self.args) == len(o.args) and all(
            (a == b if isinstance(a, Sym) else
             (not isinstance(b, Sym) and a == b))
            for a, b in zip(self.args, o.args))

    def __hash__(self):
        return hash((self.op, tuple(repr(a) for a in self.args)))

    def __repr__(self):
        if self.op == "var":
            return f"${self.args[0]}"
        return f"({self.op} {' '.join(map(repr, self.args))})"


def is_abstract(x) -> bool:
    return isinstance(x, (Iv, Sym))


class JnpProxy:
    """Stand-in for the `jnp` module inside index-map closures.

    minimum/maximum dispatch to the abstract domain when an argument is
    abstract; everything else forwards to the real jnp (index maps in this
    repo only use minimum/maximum, but forwarding keeps the swap honest if
    one ever grows another call).
    """

    def __init__(self, real_jnp):
        self._real = real_jnp

    def __getattr__(self, name):
        return getattr(self._real, name)

    def minimum(self, a, b):
        if isinstance(a, Sym) or isinstance(b, Sym):
            if a == b:
                return a
            return Sym("min", (Sym._norm(a), Sym._norm(b)))
        if isinstance(a, Iv) or isinstance(b, Iv):
            return Iv.min2(a, b)
        return self._real.minimum(a, b)

    def maximum(self, a, b):
        if isinstance(a, Sym) or isinstance(b, Sym):
            if a == b:
                return a
            return Sym("max", (Sym._norm(a), Sym._norm(b)))
        if isinstance(a, Iv) or isinstance(b, Iv):
            return Iv.max2(a, b)
        return self._real.maximum(a, b)


def concretize(x) -> Num:
    """Degenerate intervals become ints; everything else passes through."""
    if isinstance(x, Iv) and x.concrete:
        return x.lo
    return x
