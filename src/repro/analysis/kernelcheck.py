"""Kernel contract checker: static proofs over the real Pallas call sites.

The pass never re-implements a kernel. It monkeypatches `pl.pallas_call`
with a recorder, traces each public kernel wrapper under `jax.eval_shape`
(abstract values only — nothing executes, interpret padding rules are the
compile-path ones), and then drives the *captured* BlockSpec index-map
closures through the interval/symbolic domains of `analysis.intervals`:

P1 (KC101/KC109) — every block index each map can produce, over the full
    scalar-prefetch domain (kv_len in [0, Smax] scalar and per-group
    vector, block tables holding arbitrary page ids), lies inside the
    operand's block grid.
P2 (KC102/KC103) — dead-block clamping is a genuine fixed point: for every
    live frontier f (including zero-length rows), a k/v map evaluated at a
    dead step k > f yields *structurally the same* address as at step f —
    for every block-table permutation at once, via symbolic table cells —
    so the dead step never DMAs a fresh tile. k/v operands whose maps
    ignore the prefetched frontier on a multi-block dynamic grid are
    KC102; other k-dependent operands that refetch per dead step are the
    softer KC103.
P3 (KC104/KC105) — output maps never depend on prefetched scalars (writes
    are fence-routed by the serving layer, not the grid), and every
    block-table column a k/v map consults is at or below the live page
    frontier — composed with the serving-side invariants (KC107/KC108)
    this is the "live rows never read the trash page" proof.
P4 (KC106) — per-invocation VMEM footprint (double-buffered in/out blocks
    + VMEM scratch) against a declared budget per bench shape.

Concrete companions that anchor the serving half of the paged contract:
KC107 exhaustively checks the cache-write routing helpers in
`models.layers` (every write lands on the written token's own page or the
trash page — never another live page, including fill levels *past* table
capacity), and KC108 drives `serve.paged.PageAllocator` through
alloc/free/promote/evict cycles asserting the trash page is never issued.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .findings import REPO_ROOT, Finding
from .intervals import Iv, JnpProxy, Sym, concretize

MIB = 2 ** 20
DEFAULT_VMEM_BUDGET = 16 * MIB     # one TPU core's VMEM


# ---------------------------------------------------------------------------
# capture
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CapturedCall:
    grid: tuple
    num_scalar_prefetch: int
    in_specs: list
    out_specs: list
    scratch: list
    out_shape: object
    operands: list          # ShapeDtypeStructs, prefetch operands included
    kernel_name: str = ""


class _Capture:
    def __init__(self):
        self.calls: list[CapturedCall] = []

    def fake_pallas_call(self, kernel, *, out_shape=None, grid_spec=None,
                         grid=None, in_specs=None, out_specs=None,
                         scratch_shapes=None, interpret=False, **kw):
        if grid_spec is not None:
            rec = CapturedCall(
                grid=tuple(grid_spec.grid),
                num_scalar_prefetch=int(grid_spec.num_scalar_prefetch or 0),
                in_specs=list(grid_spec.in_specs),
                out_specs=list(jax.tree_util.tree_leaves(grid_spec.out_specs)),
                scratch=list(grid_spec.scratch_shapes or []),
                out_shape=out_shape, operands=[])
        else:
            rec = CapturedCall(
                grid=tuple(grid) if grid is not None else (),
                num_scalar_prefetch=0,
                in_specs=list(in_specs or []),
                out_specs=list(jax.tree_util.tree_leaves(out_specs)),
                scratch=list(scratch_shapes or []),
                out_shape=out_shape, operands=[])
        rec.kernel_name = getattr(
            kernel, "func", kernel).__name__ if not isinstance(
            kernel, functools.partial) else kernel.func.__name__

        def runner(*operands):
            rec.operands = [jax.ShapeDtypeStruct(jnp.shape(a), a.dtype)
                            for a in operands]
            self.calls.append(rec)
            return jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, s.dtype), out_shape)

        return runner


def capture_call(fn: Callable, arg_structs: Sequence, statics: dict,
                 ) -> CapturedCall:
    """Trace `fn` (the unjitted wrapper) abstractly; return its pallas call."""
    cap = _Capture()
    real = pl.pallas_call
    pl.pallas_call = cap.fake_pallas_call
    try:
        jax.clear_caches()   # nested jits would otherwise replay cached traces
        jax.eval_shape(functools.partial(fn, **statics), *arg_structs)
    finally:
        pl.pallas_call = real
    if len(cap.calls) != 1:
        raise RuntimeError(f"expected exactly one pallas_call under "
                           f"{fn.__name__}, captured {len(cap.calls)}")
    return cap.calls[0]


# ---------------------------------------------------------------------------
# probe registry: representative bench shapes per kernel entry point
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PagedMeta:
    page_size: int
    max_pages: int
    n_pages: int
    groups_per_slot: int


@dataclasses.dataclass
class Probe:
    name: str
    family: str                  # "attention" | "softmax" | "lut" | "mvm"
    fn_name: str                 # dotted public entry point (for the report)
    build: Callable[[], tuple]   # () -> (unjitted fn, arg_structs, statics)
    smax: int = 0                # logical key extent (0 = no kv domain)
    kv_vector: bool = False      # per-group kv_len vector (else scalar)
    paged: Optional[PagedMeta] = None
    budget: int = DEFAULT_VMEM_BUDGET


def _st(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _probes() -> list[Probe]:
    from repro.core.crossbar import CrossbarConfig
    from repro.kernels import ops
    from repro.kernels.acam_lut import acam_lut_2d
    from repro.kernels.acam_softmax import acam_softmax_codes

    f32, i32, b8 = jnp.float32, jnp.int32, jnp.bool_

    def softmax():
        return (acam_softmax_codes.__wrapped__,
                (_st((256, 512), i32),),
                dict(mode="pot", interpret=False))

    def lut():
        return (acam_lut_2d.__wrapped__,
                (_st((256, 512), i32), _st((256,), i32)),
                dict(bias=128, interpret=False))

    def mvm():
        return (ops.acam_mvm.__wrapped__,
                (_st((256, 256), jnp.int8), _st((256, 256), jnp.int8)),
                dict(cfg=CrossbarConfig(), interpret=False))

    def prefill():
        q = _st((1, 8, 512, 64), f32)
        k = _st((1, 8, 512, 64), f32)
        return (ops.raceit_attention_fused.__wrapped__, (q, k, k),
                dict(softmax_mode="pot", causal=True, fold_scale=False,
                     interpret=False))

    def prefill_masked():
        q = _st((1, 8, 256, 64), f32)
        k = _st((1, 8, 1024, 64), f32)
        m = _st((1, 8, 256, 1024), b8)
        return (ops.raceit_attention_fused.__wrapped__, (q, k, k, m),
                dict(softmax_mode="pot", causal=False, fold_scale=False,
                     interpret=False))

    def dec(kv_shape):
        def build():
            q = _st((4, 2, 1, 64), f32)
            k = _st((4, 2, 2048, 64), f32)
            return (ops.raceit_attention_decode_fused.__wrapped__,
                    (q, k, k, _st(kv_shape, i32)),
                    dict(softmax_mode="pot", fold_scale=False,
                         interpret=False))
        return build

    def dec_onetile():
        q = _st((4, 2, 1, 64), f32)
        k = _st((4, 2, 256, 64), f32)
        return (ops.raceit_attention_decode_fused.__wrapped__,
                (q, k, k, _st((4,), i32)),
                dict(softmax_mode="pot", fold_scale=False, interpret=False))

    def dec_gqa():
        q = _st((2, 8, 1, 64), f32)
        k = _st((2, 2, 2048, 64), f32)
        return (ops.raceit_attention_decode_gqa.__wrapped__,
                (q, k, k, _st((2,), i32)),
                dict(softmax_mode="pot", fold_scale=False, interpret=False))

    def dec_paged(sq, masked):
        def build():
            q = _st((4, 2, sq, 64), f32)
            pool = _st((33, 256, 2, 64), f32)
            args = [q, pool, pool, _st((4,), i32), _st((4, 8), i32)]
            if masked:
                args.append(_st((4, sq, 2048), b8))
            return (ops.raceit_attention_decode_paged.__wrapped__,
                    tuple(args),
                    dict(softmax_mode="pot", fold_scale=False,
                         interpret=False))
        return build

    def dec_gqa_paged():
        q = _st((4, 4, 1, 64), f32)
        pool = _st((33, 256, 2, 64), f32)
        return (ops.raceit_attention_decode_gqa_paged.__wrapped__,
                (q, pool, pool, _st((4,), i32), _st((4, 8), i32)),
                dict(softmax_mode="pot", fold_scale=False, interpret=False))

    A = "attention"
    return [
        Probe("softmax_256x512", "softmax",
              "kernels.acam_softmax.acam_softmax_codes", softmax),
        Probe("lut_256x512", "lut", "kernels.acam_lut.acam_lut_2d", lut),
        Probe("mvm_256x256x256", "mvm", "kernels.ops.acam_mvm", mvm),
        Probe("prefill_8x512x512x64_causal", A,
              "kernels.ops.raceit_attention_fused", prefill),
        Probe("prefill_masked_8x256x1024x64", A,
              "kernels.ops.raceit_attention_fused", prefill_masked),
        Probe("decode_scalar_8x1x2048x64", A,
              "kernels.ops.raceit_attention_decode_fused", dec(()),
              smax=2048),
        Probe("decode_rows_8x1x2048x64", A,
              "kernels.ops.raceit_attention_decode_fused", dec((4,)),
              smax=2048, kv_vector=True),
        Probe("decode_onetile_8x1x256x64", A,
              "kernels.ops.raceit_attention_decode_fused", dec_onetile,
              smax=256, kv_vector=True),
        Probe("decode_gqa_2x8x2048x64_rep4", A,
              "kernels.ops.raceit_attention_decode_gqa", dec_gqa,
              smax=2048, kv_vector=True),
        Probe("decode_paged_4x2x2048x64_ps256", A,
              "kernels.ops.raceit_attention_decode_paged",
              dec_paged(1, False), smax=2048, kv_vector=True,
              paged=PagedMeta(256, 8, 33, 2)),
        Probe("chunk_paged_masked_4x2x256q_2048x64_ps256", A,
              "kernels.ops.raceit_attention_decode_paged",
              dec_paged(256, True), smax=2048, kv_vector=True,
              paged=PagedMeta(256, 8, 33, 2)),
        Probe("decode_gqa_paged_4x4kv2x2048x64_ps256", A,
              "kernels.ops.raceit_attention_decode_gqa_paged",
              dec_gqa_paged, smax=2048, kv_vector=True,
              paged=PagedMeta(256, 8, 33, 2)),
    ]


def _roles(probe: Probe, call: CapturedCall) -> tuple[list[str], list[str]]:
    """Operand role per in_spec (kernel-module layout is fixed by the
    builders; see acam_attention.acam_attention_codes) and per out_spec."""
    n = len(call.in_specs)
    if probe.family == "softmax":
        return ["x", "lut_exp", "lut_log", "lut_prob"][:n], ["out"]
    if probe.family == "lut":
        return ["x", "lut"][:n], ["out"]
    if probe.family == "mvm":
        return ["x", "w"][:n], ["out"]
    roles = ["scale", "qoff", "cmax_floor", "q", "k", "v"]
    if call.num_scalar_prefetch == 0:
        roles = ["kvlen", "kvmax"] + roles
    if n == len(roles) + 4:
        roles = roles + ["mask"]
    roles = roles + ["lut_exp", "lut_log", "lut_prob"]
    if len(roles) != n:
        raise RuntimeError(f"{probe.name}: cannot assign operand roles "
                           f"({n} in_specs, guessed {len(roles)})")
    return roles, ["out", "cmax"][:len(call.out_specs)]


# ---------------------------------------------------------------------------
# abstract prefetch refs
# ---------------------------------------------------------------------------

class _AbsVec:
    """Scalar-prefetch vector: any index returns `value`; reads recorded."""

    def __init__(self, length: int, value, oob: list):
        self.length, self.value, self.oob = length, value, oob
        self.reads = 0

    def __getitem__(self, i):
        self.reads += 1
        i = concretize(i)
        lo, hi = (i.lo, i.hi) if isinstance(i, Iv) else (i, i)
        if lo < 0 or hi >= self.length:
            self.oob.append(f"index [{lo},{hi}] into a length-{self.length} "
                            f"prefetch vector")
        return self.value


class _AbsTable:
    """Block table: interval mode returns any-page; sym mode returns an
    opaque per-cell variable. Every access is recorded for the frontier
    (KC105) and bounds (KC109) checks."""

    def __init__(self, rows: int, cols: int, n_pages: int, mode: str,
                 oob: list):
        self.rows, self.cols, self.n_pages = rows, cols, n_pages
        self.mode, self.oob = mode, oob
        self.accesses: list[tuple] = []

    def __getitem__(self, rc):
        r, c = (concretize(x) for x in rc)
        self.accesses.append((r, c))
        for v, n, what in ((r, self.rows, "row"), (c, self.cols, "column")):
            lo, hi = (v.lo, v.hi) if isinstance(v, Iv) else (v, v)
            if lo < 0 or hi >= n:
                self.oob.append(f"block-table {what} index [{lo},{hi}] "
                                f"outside [0,{n})")
        if self.mode == "interval":
            return Iv(0, self.n_pages - 1)
        if isinstance(r, Iv) or isinstance(c, Iv):
            raise RuntimeError("symbolic table access with non-concrete "
                               "indices")
        return Sym.var(("bt", r, c))


@dataclasses.dataclass
class _EvalResult:
    idx: tuple
    vec_reads: int
    table: Optional[_AbsTable]
    oob: list


def _eval_map(idx_map, grid_idx, kvl, kvm, bt) -> _EvalResult:
    """Run a real index-map closure on abstract args, jnp proxied."""
    args = list(grid_idx)
    extra = idx_map.__code__.co_argcount - len(args)
    args += [kvl, kvm, bt][:max(extra, 0)]
    g = idx_map.__globals__
    oob: list = []
    had, prev = "jnp" in g, g.get("jnp")
    if had:
        g["jnp"] = JnpProxy(prev)
    try:
        out = idx_map(*args)
    finally:
        if had:
            g["jnp"] = prev
    reads = (kvl.reads if isinstance(kvl, _AbsVec) else 0) + \
            (kvm.reads if isinstance(kvm, _AbsVec) else 0)
    for ref in (kvl, kvm):
        if isinstance(ref, _AbsVec):
            oob += ref.oob
    if isinstance(bt, _AbsTable):
        oob += bt.oob
    return _EvalResult(tuple(concretize(x) for x in out), reads,
                       bt if isinstance(bt, _AbsTable) else None, oob)


def _map_anchor(idx_map) -> tuple[str, int]:
    """(repo-relative path, line) of the *inner* map, unwrapping `_im`."""
    fn = idx_map
    for cell in (fn.__closure__ or ()):
        if callable(getattr(cell, "cell_contents", None)):
            inner = cell.cell_contents
            if getattr(inner, "__code__", None) is not None:
                fn = inner
                break
    code = fn.__code__
    path = code.co_filename
    try:
        import pathlib
        path = str(pathlib.Path(path).resolve().relative_to(REPO_ROOT))
    except ValueError:
        pass
    return path, code.co_firstlineno


def _grid_points(grid):
    return np.ndindex(*grid) if grid else iter([()])


# ---------------------------------------------------------------------------
# the per-call contract analysis
# ---------------------------------------------------------------------------

def analyze_call(probe: Probe, call: CapturedCall) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []
    in_roles, out_roles = _roles(probe, call)
    nsp = call.num_scalar_prefetch
    ops_in = call.operands[nsp:]
    smax = probe.smax
    nk = call.grid[3] if len(call.grid) == 4 else 0
    paged = probe.paged

    def prefetch_refs(mode: str, kv_value, oob):
        if nsp == 0 and smax == 0:
            return None, None, None
        ng = call.grid[1] if len(call.grid) == 4 else 1
        kvl_len = call.operands[0].shape[0] if nsp else max(
            1, ops_in[0].shape[0] if in_roles[:1] == ["kvlen"] else 1)
        kvl = _AbsVec(kvl_len, kv_value, oob)
        kvm = _AbsVec(ng, kv_value, oob)
        bt = None
        if paged is not None:
            bt = _AbsTable(call.operands[2].shape[0],
                           call.operands[2].shape[1],
                           paged.n_pages, mode, oob)
        return kvl, kvm, bt

    specs = ([(r, s, ops_in[j]) for j, (r, s) in
              enumerate(zip(in_roles, call.in_specs))] +
             [(r, s, o) for r, s, o in
              zip(out_roles, call.out_specs,
                  jax.tree_util.tree_leaves(call.out_shape))])

    # ---- P1: bounds over the whole grid x full prefetch domain -----------
    for role, spec, operand in specs:
        path, line = _map_anchor(spec.index_map)
        site = f"{probe.name}:{role}"
        block = spec.block_shape
        for gp in _grid_points(call.grid):
            oob: list = []
            kvl, kvm, bt = prefetch_refs("interval", Iv(0, max(smax, 0)), oob)
            res = _eval_map(spec.index_map, gp, kvl, kvm, bt)
            for msg in res.oob:
                findings.append(Finding("kernelcheck", "KC109", path, line,
                                        site, f"at grid {gp}: {msg}"))
            if len(res.idx) != len(block):
                findings.append(Finding(
                    "kernelcheck", "KC101", path, line, site,
                    f"map returned {len(res.idx)} indices for a "
                    f"{len(block)}-d block"))
                break
            for d, (ix, b, dim) in enumerate(
                    zip(res.idx, block, operand.shape)):
                n_blocks = -(-dim // b)
                lo, hi = (ix.lo, ix.hi) if isinstance(ix, Iv) else (ix, ix)
                if isinstance(ix, Sym):
                    findings.append(Finding(
                        "kernelcheck", "KC101", path, line, site,
                        f"dim {d}: symbolic index escaped interval "
                        f"analysis at grid {gp}"))
                elif lo < 0 or hi > n_blocks - 1:
                    findings.append(Finding(
                        "kernelcheck", "KC101", path, line, site,
                        f"dim {d}: block index range [{lo},{hi}] outside "
                        f"[0,{n_blocks - 1}] (operand dim {dim}, block {b}) "
                        f"at grid {gp}"))
    # ---- classify maps: does each read the prefetched frontier? ----------
    dyn = nsp >= 2 and nk > 1
    reads_kvm: dict[int, bool] = {}
    k_dependent: dict[int, bool] = {}
    if len(call.grid) == 4:
        base = (0, 0, 0, 0)
        bumped = (0, 0, 0, min(1, nk - 1))
        for j, (role, spec, _) in enumerate(specs):
            # classify in *symbolic* table mode: when block_k == page_size
            # the in-page dims are constant and an interval-mode table
            # collapses every k to the same any-page interval, hiding the
            # k-dependence that flows through the table lookup
            oob: list = []
            kvl, kvm, bt = prefetch_refs("sym",
                                         Iv(max(smax, 1), max(smax, 1)), oob)
            r0 = _eval_map(spec.index_map, base, kvl, kvm, bt)
            reads_kvm[j] = r0.vec_reads > 0 or (
                r0.table is not None and len(r0.table.accesses) > 0)
            oob2: list = []
            kvl, kvm, bt = prefetch_refs("sym",
                                         Iv(max(smax, 1), max(smax, 1)), oob2)
            r1 = _eval_map(spec.index_map, bumped, kvl, kvm, bt)
            k_dependent[j] = r0.idx != r1.idx

    # ---- P2: dead-block clamp is a fixed point (per live frontier) -------
    frontier_domains = 0
    if dyn:
        k_spec = next(s for r, s, _ in specs if r == "k")
        bk = k_spec.block_shape[1]
        spb = (paged.page_size // bk) if paged else None
        p_grid, ng, nq = call.grid[0], call.grid[1], call.grid[2]
        frontiers = [None]       # None = empty rows (kv_len == 0)
        frontiers += [f for f in range(nk) if f * bk + 1 <= smax]
        for j, (role, spec, operand) in enumerate(specs):
            path, line = _map_anchor(spec.index_map)
            site = f"{probe.name}:{role}"
            is_kv = role in ("k", "v")
            if role in out_roles:
                continue
            if not k_dependent.get(j, False):
                continue
            if not reads_kvm[j]:
                rule = "KC102" if is_kv else "KC103"
                sev = "error" if is_kv else "warn"
                findings.append(Finding(
                    "kernelcheck", rule, path, line, site,
                    f"k-dependent index map ignores the prefetched live "
                    f"frontier on a {nk}-block dynamic grid: every dead "
                    f"block DMAs a fresh tile", severity=sev))
                continue
            for f in frontiers:
                frontier_domains += 1
                if f is None:
                    kv_iv, live_k, page_frontier = Iv(0, 0), 0, 0
                else:
                    kv_iv = Iv(f * bk + 1, min((f + 1) * bk, smax))
                    live_k, page_frontier = f, (f // spb if spb else None)
                for p in range(p_grid):
                    for g in range(ng):
                        for i in range(nq):
                            oob: list = []
                            kvl, kvm, bt = prefetch_refs("sym", kv_iv, oob)
                            ref = _eval_map(spec.index_map,
                                            (p, g, i, live_k), kvl, kvm, bt)
                            _check_frontier(findings, ref, page_frontier,
                                            path, line, site, f)
                            for k in range(live_k + 1, nk):
                                oob2: list = []
                                kvl2, kvm2, bt2 = prefetch_refs(
                                    "sym", kv_iv, oob2)
                                dead = _eval_map(spec.index_map,
                                                 (p, g, i, k),
                                                 kvl2, kvm2, bt2)
                                _check_frontier(findings, dead,
                                                page_frontier, path, line,
                                                site, f)
                                if dead.idx != ref.idx:
                                    findings.append(Finding(
                                        "kernelcheck", "KC102", path, line,
                                        site,
                                        f"frontier {f} (kv_len in "
                                        f"[{kv_iv.lo},{kv_iv.hi}]): dead "
                                        f"step k={k} addresses "
                                        f"{dead.idx}, live frontier "
                                        f"addresses {ref.idx} — not a "
                                        f"fixed point"))
                                    break
                            else:
                                continue
                            break

    # ---- P3: out maps independent of prefetched scalars ------------------
    for role, spec, _ in ((r, s, o) for r, s, o in specs if r in out_roles):
        if len(call.grid) != 4 or (nsp == 0 and smax == 0):
            break
        path, line = _map_anchor(spec.index_map)
        oob: list = []
        kvl, kvm, bt = prefetch_refs("interval", Iv(0, max(smax, 1)), oob)
        res = _eval_map(spec.index_map, (0, 0, 0, 0), kvl, kvm, bt)
        tbl = res.table is not None and len(res.table.accesses) > 0
        if res.vec_reads or tbl:
            findings.append(Finding(
                "kernelcheck", "KC104", path, line, f"{probe.name}:{role}",
                "output BlockSpec index map reads prefetched scalars — "
                "write routing must not depend on runtime lengths"))

    # ---- P4: VMEM footprint ---------------------------------------------
    vmem = 0
    for (role, spec, operand) in specs:
        vmem += 2 * int(np.prod(spec.block_shape)) * np.dtype(
            operand.dtype).itemsize
    for s in call.scratch:
        space = str(getattr(s, "memory_space", "vmem")).lower()
        if "smem" not in space:
            vmem += int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
    if vmem > probe.budget:
        path, line = _map_anchor(call.in_specs[0].index_map)
        findings.append(Finding(
            "kernelcheck", "KC106", path, line, f"{probe.name}:vmem",
            f"estimated VMEM footprint {vmem / MIB:.2f} MiB exceeds the "
            f"{probe.budget / MIB:.0f} MiB budget"))

    stats = dict(grid_points=int(np.prod(call.grid)) if call.grid else 1,
                 spec_sites=len(specs), vmem_bytes=vmem,
                 frontier_domains=frontier_domains,
                 map_anchors=sorted({_map_anchor(s.index_map)
                                     for _, s, _ in specs}))
    return findings, stats


def _check_frontier(findings, res: _EvalResult, page_frontier, path, line,
                    site, f):
    """KC105: consulted block-table columns stay at/below the live page
    frontier for this kv_len domain (empty rows must consult column 0)."""
    if res.table is None or page_frontier is None:
        return
    for (_, c) in res.table.accesses:
        lo, hi = (c.lo, c.hi) if isinstance(c, Iv) else (c, c)
        if hi > page_frontier:
            findings.append(Finding(
                "kernelcheck", "KC105", path, line, site,
                f"frontier {f}: consults block-table column [{lo},{hi}] "
                f"past the last live page {page_frontier}"))


# ---------------------------------------------------------------------------
# concrete serving-side probes: write fencing + allocator
# ---------------------------------------------------------------------------

def check_write_fence(route_chunk: Optional[Callable] = None,
                      route_decode: Optional[Callable] = None,
                      ) -> list[Finding]:
    """KC107: every paged cache write lands on the written token's own page
    or the trash page — exhaustively, including fills past table capacity."""
    from repro.models import layers
    route_chunk = route_chunk or layers.paged_write_targets_chunk
    route_decode = route_decode or layers.paged_write_targets_decode
    findings: list[Finding] = []
    ps, mp, b_rows = 4, 2, 3
    cap = ps * mp
    bt = np.asarray([[3, 1], [5, 2], [4, 6]], np.int32)   # distinct, no 0

    def anchor(fn):
        code = fn.__code__
        try:
            import pathlib
            path = str(pathlib.Path(code.co_filename).resolve()
                       .relative_to(REPO_ROOT))
        except ValueError:
            path = code.co_filename
        return path, code.co_firstlineno

    # chunk path: all (lens, offs) with lens up to past-capacity overflow
    sq = 4
    path, line = anchor(route_chunk)
    for l0 in range(0, cap + 3):
        for o0 in range(0, l0 + 1):
            lens = np.asarray([l0, cap, 0], np.int32)
            offs = np.asarray([o0, 0, 0], np.int32)
            pages, slot = (np.asarray(a) for a in route_chunk(
                jnp.asarray(bt), jnp.asarray(lens), jnp.asarray(offs),
                sq, ps))
            for b in range(b_rows):
                for j in range(sq):
                    col = int(offs[b]) + j
                    live = col < min(int(lens[b]), cap)
                    want_page = int(bt[b, col // ps]) if live else 0
                    want_slot = col % ps if live else None
                    if int(pages[b, j]) != want_page or (
                            live and int(slot[b, j]) != want_slot):
                        findings.append(Finding(
                            "kernelcheck", "KC107", path, line,
                            f"write_fence:chunk",
                            f"lens={lens.tolist()} offs={offs.tolist()} "
                            f"row {b} token {j} (col {col}): wrote page "
                            f"{int(pages[b, j])} slot {int(slot[b, j])}, "
                            f"contract wants "
                            f"{'page %d slot %d' % (want_page, want_slot) if live else 'trash page 0'}"))
                        return findings   # first violation is enough
    # decode path: every fill level incl. 0 and past-capacity
    path, line = anchor(route_decode)
    for l0 in range(0, cap + 3):
        lens = np.asarray([l0, 1, cap + 2], np.int32)
        pages, slot = (np.asarray(a) for a in route_decode(
            jnp.asarray(bt), jnp.asarray(lens), ps))
        for b in range(b_rows):
            lb = int(lens[b])
            live = 0 < lb <= cap
            pos = lb - 1
            want_page = int(bt[b, pos // ps]) if live else 0
            if int(pages[b]) != want_page or (
                    live and int(slot[b]) != pos % ps):
                findings.append(Finding(
                    "kernelcheck", "KC107", path, line,
                    f"write_fence:decode",
                    f"lens={lens.tolist()} row {b}: wrote page "
                    f"{int(pages[b])} slot {int(slot[b])}, contract wants "
                    f"{'page %d slot %d' % (want_page, pos % ps) if live else 'trash page 0'}"))
                return findings
    return findings


def check_allocator() -> list[Finding]:
    """KC108: PageAllocator never issues physical page 0 through any
    alloc/free/promote/evict/leak cycle."""
    from repro.serve.paged import PageAllocator
    findings: list[Finding] = []
    import inspect
    import pathlib
    src = inspect.getsourcefile(PageAllocator)
    try:
        path = str(pathlib.Path(src).resolve().relative_to(REPO_ROOT))
    except ValueError:
        path = src
    line = inspect.getsourcelines(PageAllocator)[1]

    def issue(pages):
        if pages and 0 in pages:
            findings.append(Finding(
                "kernelcheck", "KC108", path, line, "allocator",
                f"alloc() handed out the trash page: {pages}"))

    a = PageAllocator(8)
    p0 = a.alloc(0, 7) or []
    issue(p0)                       # exhaustion: every page but 0 issued
    assert a.alloc(1, 1) is None or issue(a.alloc(1, 1))
    a.free_slot(0)
    p1 = a.alloc(1, 3) or []
    issue(p1)
    if p1:
        a.promote(1, p1[0])         # slot-owned -> shared
        a.acquire(2, p1[0])
        a.release_refs(2)
        a.free_slot(1)
        a.evict_shared(p1[0])       # shared -> free again
    p2 = a.alloc(3, 7) or []
    issue(p2)
    a.leak_slot(3)
    a.assert_invariants()
    return findings


# ---------------------------------------------------------------------------
# entry point + contract report
# ---------------------------------------------------------------------------

def run() -> tuple[list[Finding], dict, str]:
    """(findings, coverage, kernel-contracts markdown) over all probes."""
    findings: list[Finding] = []
    rows = []
    anchors: set = set()
    grid_points = spec_sites = frontier_domains = 0
    for probe in _probes():
        fn, args, statics = probe.build()
        call = capture_call(fn, args, statics)
        f, stats = analyze_call(probe, call)
        findings += f
        anchors.update(tuple(a) for a in stats["map_anchors"])
        grid_points += stats["grid_points"]
        spec_sites += stats["spec_sites"]
        frontier_domains += stats["frontier_domains"]
        rows.append((probe, call, stats,
                     sum(1 for x in f if x.severity == "error")))
    findings += check_write_fence()
    findings += check_allocator()
    modules = sorted({a[0] for a in anchors})
    coverage = dict(
        probes=len(rows),
        pallas_calls=len(rows),
        spec_sites=spec_sites,
        index_map_sites=len(anchors),
        kernel_modules=modules,
        grid_points=grid_points,
        frontier_domains=frontier_domains,
    )
    return findings, coverage, contracts_markdown(rows, coverage)


def contracts_markdown(rows, coverage) -> str:
    """Deterministic per-kernel contract report (docs/kernel_contracts.md)."""
    out = [
        "# Kernel contracts",
        "",
        "Generated by `python -m repro.analysis --write-contracts` — do not",
        "edit by hand; `tests/test_docs.py` checks this file matches the",
        "analyzer's current output. One section per analyzed bench shape:",
        "the captured grid, each operand's block and index-map class, and",
        "the proof obligations discharged (P1 bounds, P2 dead-block clamp",
        "fixed point, P3 prefetch-independent writes / live-frontier table",
        "columns, P4 VMEM footprint vs budget).",
        "",
    ]
    for probe, call, stats, n_err in rows:
        in_roles, out_roles = _roles(probe, call)
        out.append(f"## {probe.name}")
        out.append("")
        out.append(f"- entry: `{probe.fn_name}`")
        out.append(f"- grid: `{call.grid}` "
                   f"(scalar-prefetch operands: {call.num_scalar_prefetch})")
        kv = ("none (static extent)" if probe.smax == 0 else
              f"kv_len in [0, {probe.smax}] "
              f"({'per-group vector' if probe.kv_vector else 'scalar'})")
        out.append(f"- prefetch domain: {kv}")
        if probe.paged:
            p = probe.paged
            out.append(f"- paging: page_size={p.page_size}, "
                       f"max_pages={p.max_pages}, pool={p.n_pages} pages, "
                       f"block table permutation-free proof via symbolic "
                       f"cells")
        out.append(f"- VMEM estimate: {stats['vmem_bytes'] / MIB:.2f} MiB "
                   f"of {probe.budget / MIB:.0f} MiB budget "
                   f"(double-buffered blocks + scratch)")
        out.append(f"- verdict: "
                   f"{'PROVEN' if n_err == 0 else f'{n_err} violation(s)'}")
        out.append("")
        out.append("| operand | block | index map |")
        out.append("|---|---|---|")
        specs = list(zip(in_roles, call.in_specs)) + \
            list(zip(out_roles, call.out_specs))
        for role, spec in specs:
            path, line = _map_anchor(spec.index_map)
            out.append(f"| {role} | `{spec.block_shape}` | "
                       f"`{path}:{line}` |")
        out.append("")
    out.append("## Coverage")
    out.append("")
    for k in sorted(coverage):
        v = coverage[k]
        if isinstance(v, list):
            v = ", ".join(f"`{x}`" for x in v)
        out.append(f"- {k}: {v}")
    out.append("")
    return "\n".join(out)
