"""Distributed training launcher (pjit on the production mesh).

On real hardware this runs under `jax.distributed.initialize()`; here it
drives the same code path on however many devices exist. The dry-run
(`dryrun.py`) is the compile-only proof for the 256/512-chip meshes.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 20 \
      --set n_layers=2 d_model=128 vocab_size=512 --data 1 --model 1
"""
from __future__ import annotations

import argparse
import json


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.data import SyntheticLM
    from repro.dist.sharding import (MeshContext, ShardingPolicy,
                                     named_sharding_tree, param_specs,
                                     use_policy)
    from repro.launch.mesh import make_host_mesh
    from repro.models import Model
    from repro.train import (TrainLoopConfig, optim, run_training, trainer)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    cfg = get_config(args.arch).replace(
        param_dtype="float32", compute_dtype="float32", **overrides)

    mesh = make_host_mesh(data=args.data, model=args.model)
    policy = ShardingPolicy(mesh)
    mctx = MeshContext(mesh)
    model = Model(cfg, mesh_ctx=mctx)

    with use_policy(policy, mctx):
        params = model.init(jax.random.PRNGKey(0))
        pspecs = param_specs(params, cfg, policy)
        params = jax.device_put(params, named_sharding_tree(pspecs, mesh))
        opt_state = optim.adamw_init(params)
        step = jax.jit(trainer.make_train_step(
            model, optim.AdamWConfig(lr=3e-4,
                                     schedule=optim.warmup_cosine(20, args.steps))))
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=0)
        params, opt_state, out = run_training(
            step, params, opt_state, data,
            TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                            ckpt_every=max(10, args.steps // 4)),
            make_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    h = out["history"]
    if h:
        print(f"[train] {args.arch}: step {out['final_step']} "
              f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
