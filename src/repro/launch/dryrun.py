import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_FLAGS")
                           or "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16x16 single-pod / 2x16x16 multi-pod) and records:
memory_analysis (fits HBM?), XLA cost_analysis, and our trip-count-aware HLO
cost (flops / HBM bytes / collective bytes by type) for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama4-scout-17b-a16e --shape train_4k \
      --mesh single --out results/dryrun.json
  python -m repro.launch.dryrun --all             # every valid cell, both meshes
"""

import argparse
import json
import time
import traceback
from pathlib import Path


def valid_cells(arch_names=None, shape_names=None):
    """The assigned 40-cell grid, minus skips documented in DESIGN.md §5."""
    from repro.configs import SHAPES, get_config
    from repro.configs.catalog import ASSIGNED

    cells = []
    for arch in arch_names or ASSIGNED:
        cfg = get_config(arch)
        for shp in shape_names or list(SHAPES):
            shape = SHAPES[shp]
            if shape.kind == "decode" and cfg.family == "encoder":
                cells.append((arch, shp, "skip:encoder-only, no decode step"))
                continue
            if shp == "long_500k" and not cfg.supports_long_context:
                cells.append((arch, shp, "skip:full-attention at 500k (DESIGN §5)"))
                continue
            cells.append((arch, shp, None))
    return cells


def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str = "digital",
             overrides: dict | None = None) -> dict:
    import jax
    from repro.configs import SHAPES, get_config
    from repro.configs.base import ExecConfig
    from repro.dist.sharding import MeshContext, use_policy
    from repro.launch import hlo_analysis, inputs
    from repro.launch.mesh import (HBM_BW, ICI_LINK_BW, PEAK_BF16_FLOPS,
                                   make_production_mesh)
    from repro.models import Model
    from repro.train import optim, trainer

    t0 = time.time()
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size

    policy = inputs.make_policy(mesh, cfg, shape)
    mesh_ctx = MeshContext(mesh)
    exec_cfg = ExecConfig(mode="raceit" if mode.startswith("raceit") else mode)
    model = Model(cfg, exec_cfg, mesh_ctx)

    with use_policy(policy, mesh_ctx):
        spec = inputs.input_specs(cfg, shape, policy, model,
                                  quantize=(mode == "raceit_q8"))
        if shape.kind == "train":
            step = trainer.make_train_step(model, optim.AdamWConfig(
                schedule=optim.warmup_cosine(100, 10_000)))
            args = (spec["params"], spec["opt_state"], spec["batch"])
            jitted = jax.jit(step, donate_argnums=(0, 1))
        elif shape.kind == "prefill":
            if cfg.family == "encoder":
                step = lambda params, batch: model.forward(params, batch,
                                                           use_remat=False)
                args = (spec["params"], spec["batch"])
            else:
                def step(params, batch, cache):
                    return model.prefill(params, batch["tokens"], cache,
                                         enc_feats=batch.get("enc_feats"))
                args = (spec["params"], spec["batch"], spec["cache"])
            jitted = jax.jit(step)
        else:  # decode (serve_step: one new token against the KV/SSM cache)
            def step(params, token, cache):
                return model.decode_step(params, token, cache)
            args = (spec["params"], spec["token"], spec["cache"])
            jitted = jax.jit(step, donate_argnums=(2,))

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x returns [dict]
        ca = ca[0] if ca else {}
    hlo = hlo_analysis.analyze_hlo(compiled.as_text())

    mf = inputs.model_flops(cfg, spec["params"], shape)
    bytes_per_device = (ma.argument_size_in_bytes + ma.temp_size_in_bytes +
                        ma.output_size_in_bytes - ma.alias_size_in_bytes)
    compute_s = hlo.flops / PEAK_BF16_FLOPS
    memory_s = hlo.memory_bytes / HBM_BW
    collective_s = hlo.collective_bytes / ICI_LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "mode": mode,
        "status": "ok", "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "per_device_bytes": bytes_per_device,
            "fits_16GB": bool(bytes_per_device < 16e9),
        },
        "xla_cost": {k: ca.get(k) for k in ("flops", "bytes accessed")},
        "hlo": hlo.to_dict(),
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / hlo.flops if hlo.flops else None,
        "roofline": {**terms, "dominant": dominant,
                     "bound_s": max(terms.values()),
                     "roofline_fraction": (mf / n_chips / PEAK_BF16_FLOPS)
                                          / max(max(terms.values()), 1e-30)},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="digital",
                    choices=["digital", "raceit", "raceit_q8"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists():
        results = json.loads(out_path.read_text())

    if args.all:
        cells = [(a, s, skip, m)
                 for (a, s, skip) in valid_cells()
                 for m in ("single", "multi")]
    else:
        cells = [(args.arch, args.shape, None, args.mesh)]

    for arch, shp, skip, mesh_kind in cells:
        key = f"{arch}|{shp}|{mesh_kind}|{args.mode}"
        if key in results and results[key].get("status") in ("ok", "skipped"):
            continue
        if skip:
            results[key] = {"arch": arch, "shape": shp, "mesh": mesh_kind,
                            "status": "skipped", "reason": skip}
        else:
            print(f"=== {key}", flush=True)
            try:
                results[key] = run_cell(arch, shp, mesh_kind, args.mode,
                                        overrides or None)
                r = results[key]
                print(f"    ok: compile={r['compile_s']}s "
                      f"mem/dev={r['memory']['per_device_bytes']/1e9:.2f}GB "
                      f"dominant={r['roofline']['dominant']} "
                      f"frac={r['roofline']['roofline_fraction']:.3f}", flush=True)
            except Exception as e:  # noqa: BLE001 — record and continue
                results[key] = {"arch": arch, "shape": shp, "mesh": mesh_kind,
                                "status": "error", "error": str(e),
                                "traceback": traceback.format_exc()[-4000:]}
                print(f"    ERROR: {e}", flush=True)
        out_path.write_text(json.dumps(results, indent=1))


if __name__ == "__main__":
    main()
