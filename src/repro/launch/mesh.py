"""Production mesh construction (DESIGN.md §4).

Defined as functions (never module-level constants) so importing this module
never touches JAX device state. The dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* importing jax.
"""
from __future__ import annotations

from repro.dist.sharding import compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e target: one pod = 16x16 = 256 chips; multi-pod = 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    shape = ((pod, data, model) if pod else (data, model))
    axes = (("pod", "data", "model") if pod else ("data", "model"))
    return compat_make_mesh(shape, axes)


# Hardware constants for the roofline (TPU v5e, per chip).
PEAK_BF16_FLOPS = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_LINK_BW = 50e9             # B/s per link
