"""Static cost analysis of compiled (post-SPMD) HLO text.

XLA's HloCostAnalysis counts `while` bodies once, which silently underreports
scanned-layer programs by ~n_layers x. This analyzer walks the computation
call graph with loop trip counts (from `backend_config.known_trip_count`) and
produces per-device totals:

* flops            — 2*M*N*K for every dot (incl. dots inside fusions)
* memory_bytes     — HBM traffic model: result + operand bytes of every
                     materialized top-level instruction (fusion internals are
                     free; parameters/tuples/bitcasts are not traffic)
* collective_bytes — link-traffic model per op type (ring algorithms):
                     all-gather/all-to-all/permute ~= result bytes,
                     reduce-scatter ~= input bytes, all-reduce ~= 2x input

These feed the three roofline terms in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

__all__ = ["HloCost", "analyze_hlo"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[\w.\-]+) = (?P<type>.+?) "
    r"(?P<op>[\w\-]+)\((?P<rest>.*)$")
_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                        r"\{?%?([\w.\-]+(?:, ?%[\w.\-]+)*)\}?")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_MEM_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "copy-start", "copy-done", "opt-barrier",
}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(type_str: str) -> int:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _ARRAY_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_type: dict = dataclasses.field(default_factory=dict)
    collective_count: int = 0
    notes: list = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(
            self.flops * k, self.memory_bytes * k, self.collective_bytes * k,
            {t: v * k for t, v in self.collective_by_type.items()},
            int(self.collective_count * k), list(self.notes))

    def add(self, other: "HloCost"):
        self.flops += other.flops
        self.memory_bytes += other.memory_bytes
        self.collective_bytes += other.collective_bytes
        for t, v in other.collective_by_type.items():
            self.collective_by_type[t] = self.collective_by_type.get(t, 0.0) + v
        self.collective_count += other.collective_count

    def to_dict(self) -> dict:
        return {"flops": self.flops, "memory_bytes": self.memory_bytes,
                "collective_bytes": self.collective_bytes,
                "collective_by_type": self.collective_by_type,
                "collective_count": self.collective_count, "notes": self.notes}


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur_name, cur_lines = None, []
    for line in text.splitlines():
        stripped = line.rstrip()
        if cur_name is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$", stripped)
            if m:
                cur_name = m.group(1)
                cur_lines = []
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur_lines
        else:
            if stripped == "}":
                comps[cur_name] = comps.get(cur_name, cur_lines)
                if cur_lines is not comps[cur_name]:
                    comps[cur_name] = cur_lines
                cur_name = None
            else:
                cur_lines.append(stripped)
    return comps


def _fusion_traffic(comp_lines: list[str], operand_bytes_by_idx: dict,
                    result_bytes: int) -> float:
    """HBM traffic of a fusion: full reads of non-sliced params, slice-sized
    reads for params consumed via dynamic-slice/gather, in-place accounting
    for root dynamic-update-slice (update-sized write, aliased result)."""
    params: dict[str, int] = {}
    defs: dict[str, tuple] = {}
    root_line = None
    all_ops = set()
    for line in comp_lines:
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, itype, op, rest = (m.group("name"), m.group("type"),
                                 m.group("op"), m.group("rest"))
        ops = re.findall(r"%([\w.\-]+)", rest.split("), ")[0])
        defs[name] = (op, itype, ops)
        all_ops.add(op)
        if op == "parameter":
            pm = re.match(r"(\d+)\)", rest)
            if pm:
                params[name] = int(pm.group(1))
        if line.strip().startswith("ROOT"):
            root_line = (op, itype, ops)

    # Fusions made only of dtype/layout plumbing are CPU bf16-emulation
    # artifacts; a TPU backend never materializes them. Zero traffic.
    if all_ops <= {"parameter", "constant", "convert", "bitcast", "reshape",
                   "copy", "transpose", "reduce-precision", "tuple",
                   "get-tuple-element"}:
        return 0.0

    def resolve_param(name: str, depth=0):
        """Walk through layout/precision-preserving ops to a parameter index.

        convert/reduce-precision are included deliberately: the CPU backend
        emulates bf16 by upcasting whole buffers around in-place updates —
        a TPU backend keeps the buffer dtype and updates in place, which is
        the semantics the roofline should reflect."""
        while depth < 10 and name in defs:
            op, _, ops = defs[name]
            if op == "parameter":
                return params.get(name)
            if op in ("bitcast", "reshape", "copy", "transpose", "convert",
                      "reduce-precision") and ops:
                name = ops[0]
                depth += 1
                continue
            return None
        return None

    sliced: set[int] = set()
    excluded: set[int] = set()
    extra = 0.0
    # root may be convert(DUS(...)) on the CPU backend: walk through wrappers
    root_is_dus = False
    if root_line is not None:
        op, _, ops = root_line
        depth = 0
        while depth < 10:
            if op == "dynamic-update-slice":
                root_is_dus = True
                break
            if op in ("bitcast", "reshape", "copy", "transpose", "convert",
                      "reduce-precision") and ops and ops[0] in defs:
                op, _, ops = defs[ops[0]]
                depth += 1
                continue
            break
    for name, (op, itype, ops) in defs.items():
        if op in ("dynamic-slice", "gather") and ops:
            idx = resolve_param(ops[0])
            if idx is not None:
                sliced.add(idx)
                extra += _type_bytes(itype)  # read only the slice
        if op == "dynamic-update-slice" and len(ops) >= 2:
            tgt = resolve_param(ops[0])
            if tgt is not None:
                excluded.add(tgt)  # aliased in-place target: not re-read
            upd = resolve_param(ops[1])
            ub = (operand_bytes_by_idx.get(upd, 0) if upd is not None
                  else _type_bytes(defs.get(ops[1], ("", "", []))[1]))
            extra += 2.0 * ub  # write the region (+ its read-modify)
    total = extra
    for idx, b in operand_bytes_by_idx.items():
        if idx not in sliced and idx not in excluded:
            total += b
    if not root_is_dus:
        total += result_bytes
    return total


def _collective_traffic(op: str, result_bytes: int, operand_bytes: int,
                        group: int) -> float:
    if op == "all-gather":
        return float(result_bytes)
    if op == "all-reduce":
        return 2.0 * operand_bytes
    if op == "reduce-scatter":
        return float(operand_bytes)
    return float(max(result_bytes, operand_bytes))  # all-to-all / permute


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 1


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    # find entry: computation named like main / with ENTRY marker
    entry = None
    for name in comps:
        if name.startswith("main"):
            entry = name
    if entry is None:
        entry = next(iter(comps))

    memo: dict[str, HloCost] = {}

    def comp_cost(name: str) -> HloCost:
        if name in memo:
            return memo[name]
        memo[name] = HloCost()  # cycle guard
        lines = comps.get(name, [])
        symtab: dict[str, str] = {}
        cost = HloCost()
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, itype, op, rest = (m.group("name"), m.group("type"),
                                      m.group("op"), m.group("rest"))
            symtab[iname] = itype

        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            iname, itype, op, rest = (m.group("name"), m.group("type"),
                                      m.group("op"), m.group("rest"))
            called = []
            for grp in _CALLED_RE.findall(line):
                for c in grp.split(","):
                    called.append(c.strip().lstrip("%"))
            # operand names = %refs in the call parens, excluding called comps
            paren = rest.split("), ")[0]
            operands = [o.lstrip("%") for o in re.findall(r"%([\w.\-]+)", paren)
                        if o.lstrip("%") not in called]
            operand_bytes = sum(_type_bytes(symtab.get(o, "")) for o in operands)
            result_bytes = _type_bytes(itype)

            base_op = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if base_op in _COLLECTIVES:
                grp_sz = _group_size(line)
                traffic = _collective_traffic(base_op, result_bytes,
                                              operand_bytes, grp_sz)
                # scale to the fraction actually crossing links: (g-1)/g
                if grp_sz > 1:
                    traffic *= (grp_sz - 1) / grp_sz
                else:
                    traffic = 0.0
                cost.collective_bytes += traffic
                cost.collective_by_type[base_op] = (
                    cost.collective_by_type.get(base_op, 0.0) + traffic)
                cost.collective_count += 1
                cost.memory_bytes += result_bytes + operand_bytes
                continue

            if op == "dot":
                cd = _CDIMS_RE.search(line)
                lhs_type = symtab.get(operands[0], "") if operands else ""
                lhs_dims = _shape_dims(lhs_type)
                contract = 1
                if cd and cd.group(1):
                    for d in cd.group(1).split(","):
                        if int(d) < len(lhs_dims):
                            contract *= lhs_dims[int(d)]
                cost.flops += 2.0 * _type_elems(itype) * contract
            if op == "convolution":
                # rough: 2 * result_elems * (operand1 elems / out_channels)
                cost.flops += 2.0 * _type_elems(itype) * max(
                    1, _type_elems(symtab.get(operands[1], "")) // max(
                        1, _shape_dims(itype)[-1] if _shape_dims(itype) else 1))

            if op == "while":
                trips = 1
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    cost.notes.append(f"unknown trip count for {iname}")
                body = [c for c in called if "region" in c or "body" in c.lower()]
                for c in called:
                    sub = comp_cost(c)
                    cost.add(sub.scaled(trips))
                cost.memory_bytes += result_bytes
                continue

            if op in ("fusion", "call", "conditional", "custom-call",
                      "reduce", "sort", "scatter", "map", "reduce-window",
                      "select-and-scatter"):
                for c in called:
                    sub = comp_cost(c)
                    # fusion internals: count only flops (memory stays at the
                    # fusion boundary); calls/conditionals count fully.
                    if op == "fusion":
                        cost.flops += sub.flops
                        cost.collective_bytes += sub.collective_bytes
                    else:
                        cost.add(sub)
                if op == "fusion" and called:
                    ob_idx = {i: _type_bytes(symtab.get(o, ""))
                              for i, o in enumerate(operands)}
                    cost.memory_bytes += _fusion_traffic(
                        comps.get(called[0], []), ob_idx, result_bytes)
                else:
                    cost.memory_bytes += result_bytes + operand_bytes
                continue

            # slicing ops touch only the slice, not the backing buffer;
            # dynamic-update-slice writes in place (result aliases operand 0).
            if op in ("dynamic-slice", "gather"):
                cost.memory_bytes += 2.0 * result_bytes
                continue
            if op == "dynamic-update-slice":
                upd = (_type_bytes(symtab.get(operands[1], ""))
                       if len(operands) > 1 else 0)
                cost.memory_bytes += 2.0 * upd
                continue
            if op not in _SKIP_MEM_OPS:
                cost.memory_bytes += result_bytes + operand_bytes
        memo[name] = cost
        return cost

    return comp_cost(entry)
