"""ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

No device allocation ever happens here: params/opt/caches come from
jax.eval_shape over the real init functions, and inputs are synthesized
ShapeDtypeStructs with NamedShardings attached (weak-type-correct and
shardable, per the dry-run contract).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.dist.sharding import MeshContext, ShardingPolicy, param_specs

__all__ = ["input_specs", "cache_specs", "attach", "batch_specs", "model_flops"]


def attach(shapes_tree, shard_tree):
    """Zip ShapeDtypeStructs with NamedShardings."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes_tree, shard_tree)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, policy: ShardingPolicy):
    """Input batch ShapeDtypeStructs (+shardings) for a cell."""
    mesh = policy.mesh
    B, S = shape.global_batch, shape.seq_len
    tok_spec = policy.spec_for((B, S), ("batch", None))
    out = {"tokens": jax.ShapeDtypeStruct(
        (B, S), jnp.int32, sharding=NamedSharding(mesh, tok_spec))}
    if cfg.frontend in ("audio_stub", "vision_stub") or cfg.is_encoder_decoder:
        # precomputed frame/patch embeddings from the (stub) modality frontend
        enc_len = cfg.encoder_len
        fe_spec = policy.spec_for((B, enc_len, cfg.d_model), ("batch", None, None))
        out["enc_feats"] = jax.ShapeDtypeStruct(
            (B, enc_len, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, fe_spec))
    return out


def _cache_axes_for(path: str, shape: tuple) -> tuple:
    name = path.split("/")[-1]
    if name in ("k", "v") or "enc_kv" in path:
        return ("batch", "seq", None, None) if len(shape) == 4 else \
               tuple(None for _ in shape)
    if name == "state":
        return ("batch", "heads", "headdim", None)
    if name.startswith("conv"):
        return ("batch", None, "heads")
    return tuple(None for _ in shape)


def cache_specs(cache_shapes, policy: ShardingPolicy):
    flat = jax.tree_util.tree_flatten_with_path(cache_shapes)[0]
    treedef = jax.tree_util.tree_structure(cache_shapes)
    out = []
    for keypath, leaf in flat:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        stacked = path.split("/")[0] == "scan" and leaf.ndim >= 1
        base_shape = leaf.shape[1:] if stacked else leaf.shape
        axes = _cache_axes_for(path, base_shape)
        if len(axes) != len(base_shape):
            axes = tuple(None for _ in base_shape)
        if stacked:
            axes = (None,) + axes
        out.append(policy.spec_for(leaf.shape, axes))
    return jax.tree_util.tree_unflatten(treedef, out)


def make_policy(mesh, cfg: ModelConfig, shape: ShapeSpec) -> ShardingPolicy:
    """Shape-aware policy: when the batch can't use the dp axes (B=1 long
    decode), hand them to the sequence dimension of caches instead."""
    policy = ShardingPolicy(mesh)
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if shape.global_batch % dp_size != 0:
        policy.axis_map = dict(policy.axis_map)
        policy.axis_map["seq"] = dp + ("model",)
    return policy


def model_flops(cfg: ModelConfig, params, shape: ShapeSpec) -> float:
    """Analytic MODEL_FLOPS: 6*N_active*D train / 2*N_active*D inference."""
    sizes = {}
    for keypath, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in keypath)
        sizes[path] = int(np.prod(leaf.shape))
    total = sum(sizes.values())
    moe = sum(v for p, v in sizes.items() if "moe" in p and p.split("/")[-1] in
              ("w1", "w2", "w3"))
    emb = sum(v for p, v in sizes.items() if p.split("/")[-1] in
              ("tok_emb", "pos_emb"))
    n_active = total - emb - (moe * (1 - cfg.top_k / max(cfg.n_experts, 1))
                              if cfg.n_experts else 0)
    if cfg.tie_embeddings:
        n_active += cfg.vocab_size * cfg.d_model  # unembed matmul reuses tok_emb
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def input_specs(cfg: ModelConfig, shape: ShapeSpec, policy: ShardingPolicy,
                model, quantize: bool = False) -> dict:
    """Everything the step function needs, as sharded ShapeDtypeStructs."""
    from repro.dist.sharding import named_sharding_tree
    from repro.train import optim

    mesh = policy.mesh
    rng = jax.random.PRNGKey(0)
    if quantize:  # resident int8 crossbar weights (serving, paper-faithful)
        from repro.models.model import quantize_model_params
        params_shapes = jax.eval_shape(
            lambda r: quantize_model_params(model.init(r)), rng)
    else:
        params_shapes = jax.eval_shape(model.init, rng)
    pspecs = param_specs(params_shapes, cfg, policy)
    params_sds = attach(params_shapes, named_sharding_tree(pspecs, mesh))
    out = {"params": params_sds, "param_specs": pspecs}

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(optim.adamw_init, params_shapes)
        ospecs = {"mu": pspecs, "nu": pspecs, "step": P()}
        out["opt_state"] = attach(opt_shapes, named_sharding_tree(ospecs, mesh))
        out["ospecs"] = ospecs
        out["batch"] = batch_specs(cfg, shape, policy)
    elif shape.kind == "prefill":
        out["batch"] = batch_specs(cfg, shape, policy)
        if cfg.family != "encoder":
            cache_shapes = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cspecs = cache_specs(cache_shapes, policy)
            out["cache"] = attach(cache_shapes, named_sharding_tree(cspecs, mesh))
            out["cspecs"] = cspecs
    else:  # decode
        B = shape.global_batch
        tok_spec = policy.spec_for((B, 1), ("batch", None))
        out["token"] = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                            sharding=NamedSharding(mesh, tok_spec))
        cache_shapes = jax.eval_shape(
            lambda: model.init_cache(B, shape.seq_len))
        cspecs = cache_specs(cache_shapes, policy)
        out["cache"] = attach(cache_shapes, named_sharding_tree(cspecs, mesh))
        out["cspecs"] = cspecs
    return out
