"""Serving launcher: load (or init) a model and serve batched requests,
optionally through the RACE-IT analog-faithful path with resident int8
crossbar weights.

  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-large --mode raceit_q8 \
      --set n_layers=2 d_model=128 vocab_size=512 --requests 4

Operator dispatch is a resolved ExecPlan (printed at startup): A/B runs
name backends per op slot instead of flipping booleans, e.g.

  --exec-plan attention_decode=raceit_staged lm_head=raceit_q8
"""
from __future__ import annotations

import argparse
import json


def parse_exec_plan(pairs: list[str]) -> tuple:
    """["slot=backend", ...] -> ExecConfig.op_overrides tuple."""
    overrides = []
    for pair in pairs:
        slot, _, backend = pair.partition("=")
        if not slot or not backend:
            raise SystemExit(f"--exec-plan entries are slot=backend, got "
                             f"{pair!r}")
        overrides.append((slot, backend))
    return tuple(overrides)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="digital",
                    choices=["digital", "raceit", "raceit_q8"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--n-new", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--continuous", action="store_true",
                    help="serve with the slot-level continuous batcher "
                         "(per-request kv_len decode, retire-and-admit "
                         "mid-stream, one compiled decode step) instead of "
                         "the bucketed scheduler")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool size (--continuous) / bucket size")
    ap.add_argument("--page-size", type=int, default=64,
                    help="KV page granularity for block-paged continuous "
                         "serving (the --continuous default on decoder-only "
                         "all-attention models): the slot pool becomes a "
                         "page pool and prompts stream into their slot in "
                         "chunks instead of one pinned-width prefill")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="tokens streamed per slot per step while a prompt "
                         "is mid-prefill (paged mode; default: --page-size)")
    ap.add_argument("--prefill-len", type=int, default=None,
                    help="pin the contiguous admission-prefill width "
                         "(opts OUT of paged serving; prompts are then "
                         "capped at this width)")
    ap.add_argument("--staged-attention", action="store_true",
                    help="opt out of the fused-attention serving default "
                         "(sugar for --exec-plan attention_prefill="
                         "raceit_staged attention_decode=raceit_staged)")
    ap.add_argument("--exec-plan", nargs="*", default=[], metavar="SLOT=BACKEND",
                    help="pin op slots to named backends (see "
                         "repro.exec.registry.OP_SLOTS); unsupported combos "
                         "degrade and the startup plan table says why")
    ap.add_argument("--noise", default=None, metavar="PRESET|SIGMA",
                    help="serve on device-varied analog arrays: a "
                         "repro.hw.noise preset (clean/nominal/worst_case) "
                         "or a float scale of the nominal profile; routes "
                         "the raceit slots to the raceit_noisy_* backends "
                         "(fused kernels degrade, reason in the plan table)")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="device-variation seed (--noise); one seed = one "
                         "simulated chip, reproducibly")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ExecConfig
    from repro.ckpt import CheckpointManager
    from repro.models import Model
    from repro.models.model import quantize_model_params
    from repro.serve import (BatchScheduler, ContinuousBatcher,
                             GenerationEngine, Request)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    cfg = get_config(args.arch).replace(
        param_dtype="float32", compute_dtype="float32", **overrides)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        (params, _), _ = CheckpointManager(args.ckpt).restore((params, None))
    # serving defaults to the fused streaming attention kernel on both the
    # prefill and decode paths (ExecConfig.serving); --exec-plan pins
    # individual op slots to named backends on top of that
    noise = None
    if args.noise is not None:
        from repro.hw.noise import NoiseConfig
        noise = NoiseConfig.parse(args.noise, seed=args.noise_seed)
        print(f"[serve] device noise: {noise}")
    exec_cfg = ExecConfig.serving(
        mode="raceit" if args.mode.startswith("raceit") else "digital",
        fused_attention=not args.staged_attention,
        op_overrides=parse_exec_plan(args.exec_plan),
        noise=noise)
    if args.mode == "raceit_q8":
        params = quantize_model_params(params)
        print("[serve] weights quantized to resident int8 crossbar codes")

    eng = GenerationEngine(cfg, params, exec_cfg=exec_cfg, max_len=128)
    print("[serve] resolved execution plan:")
    print("\n".join("  " + l for l in eng.explain_plan().splitlines()))
    if args.continuous:
        sched = ContinuousBatcher(eng, n_slots=args.slots,
                                  prefill_len=args.prefill_len,
                                  page_size=args.page_size,
                                  prefill_chunk=args.prefill_chunk)
        if sched.paged:
            print(f"[serve] block-paged KV: page_size={sched.page_size}, "
                  f"prefill_chunk={sched.prefill_chunk}, "
                  f"{sched.n_pages} pages "
                  f"({sched.n_pages - 1} allocatable + trash)")
    else:
        sched = BatchScheduler(eng, bucket_size=args.slots)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        sched.submit(Request(rid, rng.integers(0, cfg.vocab_size,
                                               rng.integers(4, 9)).astype(np.int32),
                             n_new=args.n_new))
    done = sched.run_all()
    for rid in sorted(done):
        r = done[rid]
        if r.error is not None:  # fail-safe retirement (structured error)
            print(f"[serve] req{rid}: FAILED at {r.error.stage} "
                  f"step {r.error.step}: {r.error.reason}")
        else:
            print(f"[serve] req{rid}: {r.result.tolist()}")
    if args.continuous:
        occ = (sched.decode_tokens / sched.decode_steps
               if sched.decode_steps else float("nan"))
        extra = (f", {sched.chunk_calls} chunk calls" if sched.paged else "")
        print(f"[serve] continuous: {sched.prefills} prefills{extra}, "
              f"{sched.decode_steps} decode steps, "
              f"{occ:.2f} tokens/step occupancy")


if __name__ == "__main__":
    main()
