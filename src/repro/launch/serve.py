"""Serving launcher: load (or init) a model and serve batched requests,
optionally through the RACE-IT analog-faithful path with resident int8
crossbar weights.

  PYTHONPATH=src python -m repro.launch.serve --arch gpt2-large --mode raceit_q8 \
      --set n_layers=2 d_model=128 vocab_size=512 --requests 4

Operator dispatch is a resolved ExecPlan (printed at startup): A/B runs
name backends per op slot instead of flipping booleans, e.g.

  --exec-plan attention_decode=raceit_staged lm_head=raceit_q8
"""
from __future__ import annotations

import argparse
import json


def parse_tenant_weights(pairs: list[str]) -> dict:
    """["tenant=weight", ...] -> AdmissionRouter weights dict."""
    weights = {}
    for pair in pairs:
        tenant, _, w = pair.partition("=")
        try:
            weights[tenant] = float(w)
        except ValueError:
            w = ""
        if not tenant or not w:
            raise SystemExit(f"--tenant-weights entries are tenant=weight, "
                             f"got {pair!r}")
    return weights


def parse_exec_plan(pairs: list[str]) -> tuple:
    """["slot=backend", ...] -> ExecConfig.op_overrides tuple."""
    overrides = []
    for pair in pairs:
        slot, _, backend = pair.partition("=")
        if not slot or not backend:
            raise SystemExit(f"--exec-plan entries are slot=backend, got "
                             f"{pair!r}")
        overrides.append((slot, backend))
    return tuple(overrides)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mode", default="digital",
                    choices=["digital", "raceit", "raceit_q8"])
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--n-new", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--continuous", action="store_true",
                    help="serve with the slot-level continuous batcher "
                         "(per-request kv_len decode, retire-and-admit "
                         "mid-stream, one compiled decode step) instead of "
                         "the bucketed scheduler")
    ap.add_argument("--slots", type=int, default=4,
                    help="slot-pool size (--continuous) / bucket size")
    ap.add_argument("--page-size", type=int, default=64,
                    help="KV page granularity for block-paged continuous "
                         "serving (the --continuous default on decoder-only "
                         "all-attention models): the slot pool becomes a "
                         "page pool and prompts stream into their slot in "
                         "chunks instead of one pinned-width prefill")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="tokens streamed per slot per step while a prompt "
                         "is mid-prefill (paged mode; default: --page-size)")
    ap.add_argument("--prefill-len", type=int, default=None,
                    help="pin the contiguous admission-prefill width "
                         "(opts OUT of paged serving; prompts are then "
                         "capped at this width)")
    ap.add_argument("--router", default="fifo",
                    choices=["fifo", "priority", "wfq"],
                    help="admission policy across tenants (--continuous): "
                         "global arrival order, strict priority by tenant "
                         "weight, or weighted-fair deficit round-robin on "
                         "a token budget")
    ap.add_argument("--tenant-weights", nargs="*", default=[],
                    metavar="TENANT=WEIGHT",
                    help="tenant weights for --router priority/wfq, e.g. "
                         "'paid=3 free=1'; the synthetic request trace "
                         "round-robins over the named tenants (default: "
                         "one 'default' tenant at weight 1)")
    ap.add_argument("--tenant-cap", type=int, default=None,
                    help="per-tenant queue-depth cap: submits past it are "
                         "rejected with a structured admit-stage error")
    ap.add_argument("--prefix-cache", dest="prefix_cache",
                    action="store_true", default=None,
                    help="share identical prompt-prefix KV pages across "
                         "requests (content-addressed, refcounted; the "
                         "paged default)")
    ap.add_argument("--no-prefix-cache", dest="prefix_cache",
                    action="store_false",
                    help="disable prompt-prefix KV page sharing")
    ap.add_argument("--staged-attention", action="store_true",
                    help="opt out of the fused-attention serving default "
                         "(sugar for --exec-plan attention_prefill="
                         "raceit_staged attention_decode=raceit_staged)")
    ap.add_argument("--exec-plan", nargs="*", default=[], metavar="SLOT=BACKEND",
                    help="pin op slots to named backends (see "
                         "repro.exec.registry.OP_SLOTS); unsupported combos "
                         "degrade and the startup plan table says why")
    ap.add_argument("--noise", default=None, metavar="PRESET|SIGMA",
                    help="serve on device-varied analog arrays: a "
                         "repro.hw.noise preset (clean/nominal/worst_case) "
                         "or a float scale of the nominal profile; routes "
                         "the raceit slots to the raceit_noisy_* backends "
                         "(fused kernels degrade, reason in the plan table)")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="device-variation seed (--noise); one seed = one "
                         "simulated chip, reproducibly")
    ap.add_argument("--mesh", default=None, metavar="AXES",
                    help="serve on a device mesh: '4' / 'model=4' / "
                         "'data=2,model=4' (repro.dist.MeshSpec syntax). "
                         "A 'model' axis shards attention heads and the "
                         "paged KV pool over the raceit_*_tp backends; "
                         "params load under FSDP specs when the config "
                         "sets fsdp=True. Simulate N devices on one host "
                         "with XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ExecConfig
    from repro.ckpt import CheckpointManager
    from repro.models import Model
    from repro.models.model import quantize_model_params
    from repro.serve import (BatchScheduler, ContinuousBatcher,
                             GenerationEngine, Request)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v
    cfg = get_config(args.arch).replace(
        param_dtype="float32", compute_dtype="float32", **overrides)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        (params, _), _ = CheckpointManager(args.ckpt).restore((params, None))
    # serving defaults to the fused streaming attention kernel on both the
    # prefill and decode paths (ExecConfig.serving); --exec-plan pins
    # individual op slots to named backends on top of that
    noise = None
    if args.noise is not None:
        from repro.hw.noise import NoiseConfig
        noise = NoiseConfig.parse(args.noise, seed=args.noise_seed)
        print(f"[serve] device noise: {noise}")
    mesh = None
    if args.mesh is not None:
        from repro.dist import MeshSpec
        mesh = MeshSpec.parse(args.mesh)
        print(f"[serve] device mesh: {mesh.describe()} "
              f"({mesh.n_devices} devices)")
    exec_cfg = ExecConfig.serving(
        mode="raceit" if args.mode.startswith("raceit") else "digital",
        fused_attention=not args.staged_attention,
        op_overrides=parse_exec_plan(args.exec_plan),
        noise=noise, mesh=mesh)
    if args.mode == "raceit_q8":
        params = quantize_model_params(params)
        print("[serve] weights quantized to resident int8 crossbar codes")

    eng = GenerationEngine(cfg, params, exec_cfg=exec_cfg, max_len=128)
    print("[serve] resolved execution plan:")
    print("\n".join("  " + l for l in eng.explain_plan().splitlines()))
    weights = parse_tenant_weights(args.tenant_weights)
    if args.continuous:
        sched = ContinuousBatcher(eng, n_slots=args.slots,
                                  prefill_len=args.prefill_len,
                                  page_size=args.page_size,
                                  prefill_chunk=args.prefill_chunk,
                                  router=args.router,
                                  tenant_weights=weights or None,
                                  tenant_cap=args.tenant_cap,
                                  prefix_cache=args.prefix_cache)
        if sched.paged:
            print(f"[serve] block-paged KV: page_size={sched.page_size}, "
                  f"prefill_chunk={sched.prefill_chunk}, "
                  f"{sched.n_pages} pages "
                  f"({sched.n_pages - 1} allocatable + trash); "
                  f"prefix cache "
                  f"{'on' if sched.prefix is not None else 'off'}")
        print(f"[serve] router: {sched.queue.policy}"
              + (f", weights {weights}" if weights else "")
              + (f", depth cap {args.tenant_cap}" if args.tenant_cap else ""))
    else:
        if (args.router != "fifo" or weights or args.tenant_cap is not None
                or args.prefix_cache is not None):
            raise SystemExit("--router/--tenant-weights/--tenant-cap/"
                             "--prefix-cache belong to the continuous "
                             "batcher; add --continuous")
        sched = BatchScheduler(eng, bucket_size=args.slots)
    # the synthetic trace round-robins requests over the named tenants so
    # the routing policies have traffic classes to arbitrate
    tenants = sorted(weights) or ["default"]
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        sched.submit(Request(rid, rng.integers(0, cfg.vocab_size,
                                               rng.integers(4, 9)).astype(np.int32),
                             n_new=args.n_new,
                             tenant=tenants[rid % len(tenants)]))
    done = sched.run_all()
    for rid in sorted(done):
        r = done[rid]
        if r.error is not None:  # fail-safe retirement (structured error)
            print(f"[serve] req{rid}: FAILED at {r.error.stage} "
                  f"step {r.error.step}: {r.error.reason}")
        else:
            print(f"[serve] req{rid}: {r.result.tolist()}")
    if args.continuous:
        occ = (sched.decode_tokens / sched.decode_steps
               if sched.decode_steps else float("nan"))
        extra = (f", {sched.chunk_calls} chunk calls" if sched.paged else "")
        print(f"[serve] continuous: {sched.prefills} prefills{extra}, "
              f"{sched.decode_steps} decode steps, "
              f"{occ:.2f} tokens/step occupancy")
        s = sched.summary()
        print(f"[serve] latency (steps): "
              f"ttft p50={s['ttft_p50']} p99={s['ttft_p99']} "
              f"(n={s['ttft_n']}); "
              f"per-token p50={s['tpl_p50']} p99={s['tpl_p99']} "
              f"(n={s['tpl_n']})")
        print(f"[serve] tenants: tokens {s['tenant_tokens']}, "
              f"fairness (Jain) {s['fairness_jain']:.3f}, "
              f"rejected {s['rejected']}, errored {s['errored']}")
        if sched.paged:
            print(f"[serve] pages: {s['pages_in_use']} private + "
                  f"{s['pages_shared']} shared in use, "
                  f"{s['pages_leaked']} leaked, {s['pages_free']} free "
                  f"(peak {s['pages_peak_in_use']} of "
                  f"{s['pages_allocatable']})")
            if sched.prefix is not None:
                print(f"[serve] prefix cache: "
                      f"{s['prefix_hit_pages']} hit / "
                      f"{s['prefix_miss_pages']} miss pages "
                      f"({s['prefix_hit_rate_pct']:.1f}% hit rate), "
                      f"{s['prefix_pages_saved']} pages saved, "
                      f"{s['prefix_promotions']} promotions, "
                      f"{s['prefix_evictions']} evictions, "
                      f"{s['prefix_entries']} entries resident")


if __name__ == "__main__":
    main()
