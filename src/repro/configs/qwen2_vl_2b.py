"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE, dynamic resolution; vision frontend is a stub (precomputed patch
embeddings via input_specs). [arXiv:2409.12191; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-2b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab_size=151_936, head_dim=128,
    activation="silu", glu=True, norm="rmsnorm", qkv_bias=True,
    pos_emb="mrope", mrope_sections=(16, 24, 24), rope_theta=1e6,
    tie_embeddings=True, frontend="vision_stub",
    family="vlm", supports_long_context=False,
))
