"""Import every architecture config so the registry is populated."""
from . import (  # noqa: F401
    llama4_scout_17b_a16e, mixtral_8x22b, command_r_35b, gemma3_4b,
    starcoder2_15b, olmo_1b, mamba2_130m, jamba_v0_1_52b, qwen2_vl_2b,
    whisper_tiny, bert_base, bert_large, gpt2_large,
)

ASSIGNED = [
    "llama4-scout-17b-a16e", "mixtral-8x22b", "command-r-35b", "gemma3-4b",
    "starcoder2-15b", "olmo-1b", "mamba2-130m", "jamba-v0.1-52b",
    "qwen2-vl-2b", "whisper-tiny",
]
PAPER_OWN = ["bert-base", "bert-large", "gpt2-large"]
