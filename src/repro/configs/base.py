"""Model / execution / training configuration system.

Every assigned architecture is a `ModelConfig`; layer heterogeneity (jamba's
1:7 mamba:attn interleave, gemma3's 5:1 local:global, MoE-every-other-layer)
is expressed with cyclic *patterns* that the block machinery turns into
scan-able parameter stacks.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["ModelConfig", "ExecConfig", "register", "get_config", "list_configs", "SHAPES", "ShapeSpec"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // n_heads
    # pad attention heads up to this count for TP divisibility; padded head
    # outputs are hard-masked to zero, so the function equals the unpadded
    # model (standard head-padding trick; waste shows up in useful-FLOPs).
    head_pad_to: Optional[int] = None

    # --- layer heterogeneity (cycled over layer index) ---
    mixer_pattern: tuple = ("attn",)       # "attn" | "attn_local" | "mamba"
    ffn_pattern: tuple = ("dense",)        # "dense" | "moe" | "none"
    window: int = 1024                     # local-attention window

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    expert_parallel: bool = False          # EP over "model" (else TP-in-expert)

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 256

    # --- misc ---
    activation: str = "silu"               # "silu" | "gelu"
    glu: bool = True                       # gated FFN (SwiGLU/GeGLU)
    norm: str = "rmsnorm"                  # "rmsnorm"|"layernorm"|"np_layernorm"
    qkv_bias: bool = False
    pos_emb: str = "rope"                  # "rope"|"mrope"|"learned"|"sinusoidal"|"none"
    rope_theta: float = 1e6
    mrope_sections: Optional[tuple] = None
    causal: bool = True                    # False => encoder-only (BERT)
    tie_embeddings: bool = False
    max_seq_len: int = 524_288

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_len: int = 1500                # audio frames after the (stub) conv
    frontend: str = "none"                 # "none"|"audio_stub"|"vision_stub"

    # --- distribution policy ---
    fsdp: bool = False                     # shard weights over "data" too
    remat: str = "dots"                    # "none"|"full"|"dots"
    scan_unroll: int = 1

    # --- dtypes / perf knobs (hillclimb levers, see EXPERIMENTS.md §Perf) ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    matmul_out_dtype: str = "compute"   # "compute" (bf16 boundary/collectives)
                                        # | "f32" (paper-baseline behavior)
    attn_probs_dtype: str = "bfloat16"  # p matrix fed to the PV matmul

    # --- applicability (see DESIGN.md) ---
    supports_long_context: bool = False    # run long_500k?
    family: str = "dense"                  # dense|moe|ssm|hybrid|vlm|audio|encoder

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def block_period(self) -> int:
        import math
        return math.lcm(len(self.mixer_pattern), len(self.ffn_pattern))

    def layer_spec(self, i: int) -> tuple:
        """(mixer, ffn) kind of layer i."""
        return (self.mixer_pattern[i % len(self.mixer_pattern)],
                self.ffn_pattern[i % len(self.ffn_pattern)])

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Execution mode: digital baseline vs RACE-IT analog-faithful inference.

    This is the *declarative* half of execution dispatch: it names what the
    run wants (mode, softmax flavor, matmul fidelity, bit widths, per-op
    backend overrides). `repro.exec.resolve_plan(model_cfg, exec_cfg)` turns
    it into the *resolved* half — an `ExecPlan` with exactly one named
    backend per operator slot, structured degrade reasons, and
    ``plan.explain()``. ``fused_attention`` and `serving()` are thin sugar
    over the plan's attention-slot preference; ``op_overrides`` pins any
    slot to any registered backend by name.
    """

    mode: str = "digital"                  # "digital" | "raceit"
    softmax_mode: str = "pot"              # "pot"|"pot_fine"|"uniform" (raceit)
    matmul_fidelity: str = "int"           # "int"|"acam" (raceit, tests only)
    crossbar_adc: str = "exact"            # "exact"|"quantize"
    act_bits: int = 8
    weight_bits: int = 8
    # prefer the fused streaming Pallas kernel (repro.kernels.acam_attention)
    # for raceit attention — both prefill and the Sq=1 KV-cache decode step.
    # Sugar for putting "raceit_fused" at the head of the attention slots'
    # preference chains; configs the kernel can't serve (e.g.
    # matmul_fidelity="acam") degrade to "raceit_staged" with the reason
    # recorded on the plan and a one-time warning. Serving entry points
    # default this to True via ExecConfig.serving(); the plain constructor
    # default stays False so tests/benchmarks compare against an honest
    # staged baseline.
    fused_attention: bool = False
    # per-op backend pins applied by repro.exec.resolve_plan before the
    # mode's default preference chain: (("attention_decode", "raceit_staged"),
    # ("lm_head", "raceit_q8"), ...). Unsupported or unknown names degrade
    # (never raise) and show up in plan.explain(). Use .with_ops() sugar.
    op_overrides: tuple = ()
    # device-variation injection: a frozen `repro.hw.noise.NoiseConfig`
    # (None = ideal devices). Typed as object to keep this module free of
    # hw imports; being a field of this frozen dataclass puts it in the
    # resolve_plan lru-cache key, so two configs differing only in noise
    # resolve to distinct plans and distinct jit closures. In raceit mode
    # a non-None noise routes the matmul/activation/softmax/attention
    # slots to the `raceit_noisy_*` backends; the fused kernels model
    # ideal devices and degrade to the noisy staged path with the reason
    # recorded on the plan. Launchers parse `--noise <preset|sigma>` into
    # this field.
    noise: Optional[object] = None
    # the declarative mesh shape (`repro.dist.MeshSpec`) this config
    # executes on. None => single-device. With a "model" axis of size > 1,
    # the tensor-parallel attention backends (`raceit_fused_tp` /
    # `raceit_gqa_tp`) lead the attention chains; a 1-device mesh resolves
    # to the same single-device chain as None. Typed object to keep
    # configs importable without jax; the __post_init__ hash guard is the
    # real contract (it rides the resolve_plan cache key).
    mesh: Optional[object] = None
    # per-mixer-kind plan overrides: ((mixer_kind, ((slot, backend), ...)),
    # ...). `models/blocks.py::apply_layer` re-resolves the plan with the
    # matching pins merged on top of op_overrides, so e.g. sliding-window
    # "attn_local" layers can run the staged path while global "attn"
    # layers stay fused — the PR-3 override surface, per layer kind.
    layer_overrides: tuple = ()

    def __post_init__(self):
        # This frozen dataclass *is* the resolve_plan lru-cache key, so two
        # guards run at construction time rather than at first resolution:
        # op_overrides order is non-semantic (later pins win; with_ops
        # already sorts) — canonicalize here so directly constructed
        # configs with permuted pins compare equal instead of minting
        # duplicate cache entries and duplicate jit closures; and the
        # object-typed noise field must be hashable *now*, not deep inside
        # the first resolve_plan call (hash() on e.g. a dict raises here
        # with a pointed message instead). repro.analysis (TL104) checks
        # these guards exist for every opaque/order-insensitive field.
        merged = {}
        for slot, backend in self.op_overrides:
            merged[slot] = backend          # later pins win, as with_ops
        object.__setattr__(self, "op_overrides",
                           tuple(sorted(merged.items())))
        # layer_overrides gets the same canonicalization, one level down:
        # mixer kinds sorted, each kind's pins merged later-wins + sorted
        by_kind = {}
        for kind, pins in self.layer_overrides:
            kind_merged = dict(by_kind.get(kind, ()))
            for slot, backend in pins:
                kind_merged[slot] = backend
            by_kind[kind] = tuple(sorted(kind_merged.items()))
        object.__setattr__(self, "layer_overrides",
                           tuple(sorted(by_kind.items())))
        try:
            hash(self.noise)
        except TypeError as e:
            raise TypeError(
                f"ExecConfig.noise must be hashable (it is part of the "
                f"resolve_plan cache key); got "
                f"{type(self.noise).__name__}: {e}") from None
        try:
            hash(self.mesh)
        except TypeError as e:
            raise TypeError(
                f"ExecConfig.mesh must be hashable (it is part of the "
                f"resolve_plan cache key) — pass a repro.dist.MeshSpec, "
                f"not a live Mesh; got "
                f"{type(self.mesh).__name__}: {e}") from None

    def with_ops(self, **slot_backends: str) -> "ExecConfig":
        """Pin op slots to named backends: ``ec.with_ops(lm_head="raceit_q8")``.

        Later pins win over earlier ones for the same slot.
        """
        merged = dict(self.op_overrides)
        merged.update(slot_backends)
        return dataclasses.replace(self,
                                   op_overrides=tuple(sorted(merged.items())))

    @classmethod
    def serving(cls, mode: str = "raceit", **kw) -> "ExecConfig":
        """The serving default: fused streaming attention on.

        Serving latency is decode-dominated, and the decode path is exactly
        where the fused kernel removes the last staged-pipeline fallback —
        so launchers (`repro.launch.serve`, `examples/raceit_serve.py`)
        build their ExecConfig here, where ``fused_attention`` defaults to
        True (override with ``fused_attention=False``, or pin the slots
        with ``op_overrides``/`with_ops`, to A/B the staged path).

        Note the flip changes raceit decode *numerics*, not just speed: the
        previous serving decode ran a float-score + ACAM-softmax shortcut
        (k/v and probabilities never quantized); the fused decode runs the
        full quantized Fig.-12 pipeline — bit-exact vs the staged
        `raceit_attention` oracle on the cache slice, i.e. *more*
        paper-faithful, and consistent with the fused prefill numerics.
        """
        kw.setdefault("fused_attention", True)
        return cls(mode=mode, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # populate the registry lazily
        from . import catalog  # noqa: F401
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from . import catalog  # noqa: F401
    return sorted(_REGISTRY)
