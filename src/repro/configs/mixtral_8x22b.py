"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x22b",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32_768, head_dim=128,
    mixer_pattern=("attn_local",), window=4096,  # SWA per assignment
    ffn_pattern=("moe",), n_experts=8, top_k=2,
    activation="silu", glu=True, norm="rmsnorm", pos_emb="rope", rope_theta=1e6,
    fsdp=True, family="moe",
    supports_long_context=True,  # SWA => sub-quadratic, bounded KV
))
