from .base import (  # noqa: F401
    ModelConfig, ExecConfig, ShapeSpec, SHAPES,
    register, get_config, list_configs,
)
