"""command-r-35b [dense]: 40L d=8192 64H (GQA kv=8) d_ff=22528 vocab=256000,
GQA, no bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
    vocab_size=256_000, head_dim=128,
    activation="silu", glu=True, norm="layernorm", qkv_bias=False,
    pos_emb="rope", rope_theta=8e6, tie_embeddings=True,
    fsdp=True, family="dense",
    supports_long_context=False,  # pure full attention
))
