"""olmo-1b [dense]: 16L d=2048 16H (MHA kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm. [arXiv:2402.00838; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmo-1b",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=50_304,
    activation="silu", glu=True, norm="np_layernorm",  # no learnable scale/bias
    pos_emb="rope", rope_theta=1e4, tie_embeddings=True,
    family="dense", supports_long_context=False,
))
