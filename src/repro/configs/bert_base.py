"""bert-base (paper's own benchmark model): 12L d=768 12H d_ff=3072
vocab=30522, encoder-only. [arXiv:1810.04805]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="bert-base",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=30_522,
    causal=False, activation="gelu", glu=False, norm="layernorm",
    qkv_bias=True, pos_emb="learned", family="encoder",
    supports_long_context=False,
))
