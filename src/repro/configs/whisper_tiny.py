"""whisper-tiny [audio]: 4L d=384 6H (MHA kv=6) d_ff=1536 vocab=51865,
encoder-decoder; conv audio frontend is a stub (precomputed frame embeddings
via input_specs). [arXiv:2212.04356; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
    vocab_size=51_865,
    is_encoder_decoder=True, n_encoder_layers=4, encoder_len=1500,
    activation="gelu", glu=False, norm="layernorm", qkv_bias=True,
    pos_emb="learned", tie_embeddings=True, frontend="audio_stub",
    family="audio", supports_long_context=False,
))
