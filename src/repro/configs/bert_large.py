"""bert-large (paper's own benchmark model): 24L d=1024 16H d_ff=4096
vocab=30522, encoder-only. [arXiv:1810.04805]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="bert-large",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=30_522,
    causal=False, activation="gelu", glu=False, norm="layernorm",
    qkv_bias=True, pos_emb="learned", family="encoder",
    supports_long_context=False,
))
