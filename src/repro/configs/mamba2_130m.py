"""mamba2-130m [ssm]: 24L d=768 attention-free, vocab=50280, ssm_state=128,
SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50_280,
    mixer_pattern=("mamba",), ffn_pattern=("none",),
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_groups=1, conv_width=4,
    ssm_chunk=128,  # L^2 intra-chunk term: H=24 cannot shard over model=16
    norm="rmsnorm", pos_emb="none", tie_embeddings=True,
    family="ssm", supports_long_context=True,  # O(1) decode state
))
