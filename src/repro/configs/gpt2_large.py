"""gpt2-large (paper's own benchmark model): 36L d=1280 20H d_ff=5120
vocab=50257, decoder-only, learned positions. [Radford et al. 2019]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gpt2-large",
    n_layers=36, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=50_257,
    activation="gelu", glu=False, norm="layernorm", qkv_bias=True,
    pos_emb="learned", tie_embeddings=True, family="dense",
    supports_long_context=False,
))
