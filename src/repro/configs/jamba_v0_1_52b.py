"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336, MoE 16e top-2,
Mamba:attention 7:1 interleave. [arXiv:2403.19887; hf]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65_536, head_dim=128,
    # one attention layer per 8 (position 4), mamba elsewhere; MoE every 2nd.
    mixer_pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe"),
    n_experts=16, top_k=2, expert_parallel=True,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_groups=1,
    activation="silu", glu=True, norm="rmsnorm", pos_emb="none",  # jamba: no RoPE
    fsdp=True, family="hybrid",
    supports_long_context=True,  # 28/32 layers are O(1)-state mamba
))
