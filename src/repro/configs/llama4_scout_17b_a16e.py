"""llama4-scout-17b-a16e [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192 vocab=202048,
MoE 16 experts top-1. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202_048, head_dim=128,
    head_pad_to=48,  # 40 heads don't divide model=16; pad+mask (see base.py)
    ffn_pattern=("moe",), n_experts=16, top_k=1, expert_parallel=True,
    activation="silu", glu=True, norm="rmsnorm", pos_emb="rope", rope_theta=5e5,
    fsdp=True, family="moe",
    supports_long_context=False,  # full attention; long_500k skipped (DESIGN §5)
))
