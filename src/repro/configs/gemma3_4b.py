"""gemma3-4b [dense]: 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global attention, 128k context. [hf:google/gemma-3; unverified]"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab_size=262_144,
    mixer_pattern=("attn_local",) * 5 + ("attn",), window=1024,  # 5:1 local:global
    activation="gelu", glu=True, norm="rmsnorm", pos_emb="rope", rope_theta=1e6,
    tie_embeddings=True, family="dense",
    supports_long_context=True,  # 5/6 of layers have bounded-window KV
))
