"""Serving engine: jit'd prefill/decode with KV caches + batched generation.

`GenerationEngine` serves one batch bucket end-to-end (prefill then greedy /
temperature sampling decode); `serve/batching.py` schedules request queues
onto buckets and `serve/continuous.py` runs slot-level continuous batching
over the same jitted entry points (per-slot caches via
`Model.init_slot_cache`, per-slot lengths via ``slot_lens`` on
`Model.decode_step`). Operator dispatch goes through the engine's resolved
`repro.exec.ExecPlan` (``engine.plan``, also ``engine.explain_plan()``) —
the engine itself contains no execution-mode branches.

With the serving default (``ExecConfig.serving()``), the plan resolves the
``attention_prefill`` slot to ``raceit_fused`` and ``attention_decode`` to
``raceit_gqa_rows`` for grouped-query configs (``n_kv_heads < n_heads``;
MHA configs take ``raceit_fused_rows``): both the jitted prefill and the jitted
per-token ``_decode`` step run the fused streaming Pallas kernel (one VMEM
pass over the Fig.-12 pipeline, no (Sq, Sk) intermediates in HBM), and the
GQA decode keeps the KV cache in its native (B, Smax, KV, hd) layout — the
rep queries sharing a KV head ride one kernel tile, so cache codes are
never repeated to H. The decode step attends the KV cache's valid prefix
via a traced ``kv_len`` — a scalar for buckets, a *per-request vector* for
slot pools (each row decodes at its own fill level) — over fixed buffer
shapes, so the decode executable compiles once and is reused for every
token; fully invalid key blocks are skipped via scalar-prefetched grid
bounds, per group tile when lengths are per-row. Every
``softmax_mode`` ("pot", "pot_fine", "uniform") is covered; configs the
kernels can't serve (``matmul_fidelity="acam"``) resolve to
``raceit_staged`` with the reason recorded on the plan (and a one-time
RuntimeWarning) — `repro.exec.resolve_plan` has the exact rules.

Mixed-length buckets (`serve.batching`) arrive left-padded with per-row
``pad_lens``; prefill and decode mask the pad slots and shift positions so
each row's tokens match serving it solo.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ExecConfig, ModelConfig
from repro.models import Model

__all__ = ["GenerationEngine"]


@dataclasses.dataclass
class GenerationEngine:
    cfg: ModelConfig
    params: dict
    exec_cfg: ExecConfig = ExecConfig()
    max_len: int = 512
    temperature: float = 0.0  # 0 => greedy
    mesh_ctx: object = None

    def __post_init__(self):
        # mesh-sharded serving: an ExecConfig.mesh (repro.dist.MeshSpec)
        # materializes here — once, at engine construction — into the
        # concrete Mesh and a MeshContext for the model stack, and the
        # parameter tree is device_put onto it under `param_specs` (TP
        # Megatron splits; ModelConfig.fsdp additionally hands the data
        # axes to the weight shards, so command-r-35B/mixtral-8x22B-class
        # trees load without ever fitting one device).
        spec = self.exec_cfg.mesh
        if spec is not None and getattr(spec, "n_devices", 1) > 1:
            from repro.dist.sharding import (ShardingPolicy,
                                             named_sharding_tree, param_specs)
            mesh = spec.build()
            if self.mesh_ctx is None:
                self.mesh_ctx = spec.context()
            policy = ShardingPolicy(mesh)
            if self.cfg.fsdp:
                amap = dict(policy.axis_map)
                dp = tuple(a for a in ("pod", "data")
                           if a in mesh.axis_names)
                for name in ("heads", "mlp", "vocab"):
                    amap[name] = tuple(amap.get(name, ())) + dp
                policy.axis_map = amap
            pspecs = param_specs(self.params, self.cfg, policy)
            self.params = jax.device_put(
                self.params, named_sharding_tree(pspecs, mesh))
        self.model = Model(self.cfg, self.exec_cfg, self.mesh_ctx)
        self.plan = self.model.plan  # resolved operator dispatch table
        # one jitted prefill serves both paths: encoder-decoder models pass
        # enc_feats as an extra traced arg (re-jitting per generate() call
        # recompiled the whole prefill graph every request).
        self._prefill = jax.jit(self.model.prefill)
        # one decode executable serves contiguous and block-paged slot
        # pools alike: page_size is static (it shapes the index math), the
        # block table is traced (tables change every step, the executable
        # must not)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(2,),
                               static_argnames=("page_size",))
        # the chunked-prefill step of paged continuous batching: one
        # pinned (n_slots, chunk) executable streams every admission's
        # prompt into its slot's pages (repro.serve.continuous)
        self._prefill_chunk = jax.jit(self.model.prefill_chunk,
                                      donate_argnums=(2,),
                                      static_argnames=("page_size",))

    def generate(self, prompts: jax.Array, n_new: int,
                 rng: Optional[jax.Array] = None,
                 enc_feats: Optional[jax.Array] = None,
                 pad_lens: Optional[np.ndarray] = None) -> np.ndarray:
        """prompts: (B, P) int32 -> (B, n_new) generated ids.

        ``pad_lens`` (B,) int32: per-row *left-pad* prefix lengths for
        mixed-length buckets (`repro.serve.batching` passes this). Pad
        columns are masked out of every attention step and real tokens keep
        their solo positions, so a row's generation matches serving the
        unpadded prompt alone.

        Every sampling step uses a fresh key split off the request ``rng``
        — including the first token (sampling it with the root key and then
        splitting that same key for later tokens would reuse the root as
        both a sampling key and a split source, the classic JAX key-reuse
        hazard).
        """
        B, P = prompts.shape
        assert P + n_new <= self.max_len
        if pad_lens is not None:
            pad_lens = jnp.asarray(pad_lens, jnp.int32)
        cache = self.model.init_cache(B, self.max_len)
        if self.cfg.is_encoder_decoder:
            logits, cache = self._prefill(self.params, prompts, cache,
                                          enc_feats=enc_feats,
                                          pad_lens=pad_lens)
        else:
            logits, cache = self._prefill(self.params, prompts, cache,
                                          pad_lens=pad_lens)
        out = []
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        rng, sub = jax.random.split(rng)
        tok = self._sample(logits[:, -1], sub)
        out.append(tok)
        pad_plen = jnp.int32(P) if pad_lens is not None else None
        for i in range(n_new - 1):
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         pad_lens, pad_plen)
            tok = self._sample(logits[:, -1], sub)
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)

    def explain_plan(self) -> str:
        """The resolved slot -> backend table this engine serves with."""
        return self.plan.explain()

    @staticmethod
    def nonfinite_rows(logits: jax.Array) -> np.ndarray:
        """(B,) bool host mask: rows whose logits contain NaN/Inf.

        The fail-safe serving check — a device-faulted row (e.g. the
        ``fault_rate`` knob of `repro.hw.noise.NoiseConfig` on the noisy
        attention backends) surfaces as non-finite logits; schedulers call
        this on the step's last-position logits and retire the affected
        rows with a structured `repro.serve.batching.RequestError` instead
        of sampling garbage (argmax over NaN logits returns token 0 with
        no error signal at all).
        """
        finite = jnp.isfinite(logits).all(
            axis=tuple(range(1, jnp.ndim(logits))))
        return np.asarray(~finite)

    def _sample(self, logits: jax.Array, rng) -> jax.Array:
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            rng, logits / self.temperature, axis=-1).astype(jnp.int32)
