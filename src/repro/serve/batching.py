"""Request batching for the serving engine.

Bucketed static batching: requests accumulate in a queue; `run_once` pops
up to ``bucket_size`` of them, *left*-pads their prompts to the bucket's
longest prompt, and prefills + decodes the whole bucket together. The
per-row pad lengths ride along to the engine, which masks the pad columns
out of every attention step and keeps real tokens at their solo positions
— a request's generated tokens are therefore identical whether it is
served alone or alongside bucket-mates (tests/test_serve_batching.py
asserts this). The guarantee is bitwise for digital-mode attention
mixers under greedy decoding; four documented softenings: sampling
(``temperature > 0``) draws categorical noise whose shape is the batch,
so a bucket's draws differ from a solo run's even with the same key;
raceit modes quantize whole activation tensors, so int8 scales couple
bucket rows exactly as they couple the heads of one request (masking is
still exact — pad slots sit at the oracle's masked-LOGIT minimum; only
quantizer granularity differs from a solo run); SSM layers scan through
pad tokens; and a local-attention ring window is partly occupied by pads
until they are overwritten (once a prompt overflows the ring, the
last-L prefill breaks the slot == column mapping and the decode pad mask
is dropped for that layer) — hybrid/local configs are near- rather than
bit-equal in mixed buckets. Each request's result is truncated to its own
``n_new``; the bucket decodes to the longest request.

Two structural costs are inherent to bucketing (and are what
`repro.serve.continuous.ContinuousBatcher` — the slot-pool scheduler —
removes): a request that finishes early idles its row until the bucket's
longest request drains, and every distinct (bucket, prompt-length, n_new)
shape jits *fresh* prefill/decode executables — `run_once` serves one
bucket per call, but the engine's compiled step is per-shape, not
per-scheduler. The slot pool pins both shapes once and retires/admits
mid-stream.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from .engine import GenerationEngine

__all__ = ["Request", "RequestError", "BatchScheduler"]


@dataclasses.dataclass(frozen=True)
class RequestError:
    """Structured per-request failure record (fail-safe serving).

    Attached to ``Request.error`` when the serving path retires a request
    without a result — e.g. the continuous batcher detecting non-finite
    logits on a device-faulted slot (`repro.serve.continuous`). ``stage``
    names where it died: "prefill" (admission prefill), "decode" (a decode
    step; ``step`` is the number of tokens already generated), or "admit"
    (never ran — every slot was quarantined).
    """

    rid: int
    stage: str   # "prefill" | "decode" | "admit"
    step: int    # tokens generated before the failure
    reason: str


@dataclasses.dataclass
class Request:
    """One generation request.

    ``tenant`` names the traffic class the admission router
    (`repro.serve.router.AdmissionRouter`) schedules by — weights,
    priorities and queue-depth caps are all keyed on it. The default
    tenant makes single-tenant callers (and every pre-router test)
    tenant-blind.
    """

    rid: int
    prompt: np.ndarray  # (P,) int32
    n_new: int
    tenant: str = "default"
    result: Optional[np.ndarray] = None
    error: Optional[RequestError] = None


class BatchScheduler:
    def __init__(self, engine: GenerationEngine, bucket_size: int = 4,
                 pad_id: int = 0):
        self.engine = engine
        self.bucket = bucket_size
        self.pad_id = pad_id
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}
        # occupancy accounting, comparable to ContinuousBatcher's: the
        # decode engine runs (max n_new - 1) steps per bucket and keeps
        # (n_new_r - 1) post-prefill tokens per request — early-finished
        # requests idle their row for the remaining steps, which is
        # exactly what decode_tokens / decode_steps measures
        self.model_calls = 0   # prefill + decode executions
        self.tokens_out = 0    # all kept tokens (incl. prefill's first)
        self.decode_steps = 0
        self.decode_tokens = 0

    def submit(self, req: Request):
        self.queue.append(req)

    def run_once(self) -> list[int]:
        """Serve one bucket to completion; returns completed request ids.

        One bucket per call, but NOT one compiled step per scheduler: the
        engine re-jits prefill/decode for every distinct (batch, prompt
        length, n_new) shape this produces. The slot-pool scheduler
        (`repro.serve.continuous`) is the pinned-shape path.
        """
        if not self.queue:
            return []
        batch = [self.queue.popleft()
                 for _ in range(min(self.bucket, len(self.queue)))]
        # right-align prompts to a common length; the pad prefix lengths go
        # to the engine so pads are masked out of attention and positions
        # stay per-request (without them, real tokens would causally attend
        # the pad prefix at shifted positions and a request's output would
        # depend on its bucket-mates)
        plen = max(len(r.prompt) for r in batch)
        n_new = max(r.n_new for r in batch)
        prompts = np.full((len(batch), plen), self.pad_id, np.int32)
        pad_lens = np.zeros(len(batch), np.int32)
        for i, r in enumerate(batch):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
            pad_lens[i] = plen - len(r.prompt)
        out = self.engine.generate(
            prompts, n_new, pad_lens=pad_lens if pad_lens.any() else None)
        self.model_calls += n_new  # 1 prefill + (n_new - 1) decode steps
        self.decode_steps += n_new - 1
        finished = []
        for i, r in enumerate(batch):
            r.result = out[i, : r.n_new]
            self.tokens_out += r.n_new
            self.decode_tokens += r.n_new - 1
            self.done[r.rid] = r
            finished.append(r.rid)
        return finished

    def run_all(self) -> dict[int, Request]:
        while self.queue:
            self.run_once()
        return self.done
