"""Request batching for the serving engine.

Bucketed static batching: requests accumulate in a queue; when a bucket
fills (or `max_wait_requests` arrive), the whole bucket prefills and decodes
together, right-padded to the bucket's prompt length. Per-request decode
lengths are honored by masking finished rows. (Slot-level continuous
batching — per-slot cache indices — is documented future work in DESIGN.md.)
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from .engine import GenerationEngine

__all__ = ["Request", "BatchScheduler"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (P,) int32
    n_new: int
    result: Optional[np.ndarray] = None


class BatchScheduler:
    def __init__(self, engine: GenerationEngine, bucket_size: int = 4,
                 pad_id: int = 0):
        self.engine = engine
        self.bucket = bucket_size
        self.pad_id = pad_id
        self.queue: deque[Request] = deque()
        self.done: dict[int, Request] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def run_once(self) -> list[int]:
        """Serve one bucket; returns completed request ids."""
        if not self.queue:
            return []
        batch = [self.queue.popleft()
                 for _ in range(min(self.bucket, len(self.queue)))]
        # right-align pad prompts to a common length
        plen = max(len(r.prompt) for r in batch)
        n_new = max(r.n_new for r in batch)
        prompts = np.full((len(batch), plen), self.pad_id, np.int32)
        for i, r in enumerate(batch):
            prompts[i, plen - len(r.prompt):] = r.prompt  # left-pad
        out = self.engine.generate(prompts, n_new)
        finished = []
        for i, r in enumerate(batch):
            r.result = out[i, : r.n_new]
            self.done[r.rid] = r
            finished.append(r.rid)
        return finished

    def run_all(self) -> dict[int, Request]:
        while self.queue:
            self.run_once()
        return self.done
