"""Slot-level continuous batching: retire-and-admit without draining.

`BatchScheduler` (serve/batching.py) is *bucketed*: it pops a bucket,
decodes the whole bucket to its longest request, and only then admits the
next one — every early-finishing request idles its row for the rest of the
bucket, and every distinct (bucket, prompt, n_new) shape compiles fresh
prefill/decode executables. `ContinuousBatcher` replaces the
drain-the-bucket loop with a fixed pool of **slots** over one slot-pool KV
cache (`Model.init_slot_cache`):

    admit    a queued request is prefilled *solo* at the pool's pinned
             prompt width (left-padded, the existing pad machinery) and its
             cache row is scattered into a free slot;
    decode   every step decodes the whole pool with one pinned-shape
             executable — per-slot lengths ride to the kernels as a
             ``kv_len`` vector (`slot_lens`), so each slot attends exactly
             its own fill level and empty slots are dead rows (kv_len 0,
             defined-zero output, no quantizer-scale pollution);
    retire   a finished request frees its slot mid-stream; the next queued
             request is admitted before the following step.

Shapes are pinned by construction — (1, prefill_len) for every admission
prefill, (n_slots, 1) for every decode step — so the engine compiles each
exactly once per run, however requests come and go (the bucketed
scheduler's per-bucket re-jit is gone; `tests/test_serve_continuous.py`
asserts the single-trace property).

Exactness contract: in digital greedy mode a request's tokens are
**bitwise identical** to serving it alone — admission prefill is the
proven left-pad path, and the per-row decode masks make neighbouring
slots' keys nonexistent (the extra masked columns contribute exact 0.0
weight). The softenings mirror bucketed batching (serve/batching.py):
sampling draws differ (per-pool step keys), raceit modes couple slots
through whole-tensor quantizer scales (per-row kv_len keeps every *stale*
tail out of the scale window — only live prefixes couple), SSM layers scan
through pads, and ring-window local layers are near-equal once a prompt
overflows the window.

**Paged mode** (the default whenever the model qualifies — decoder-only,
all-global-attention — and no explicit ``prefill_len`` pins the
contiguous path): the slot pool's KV cache becomes a block-paged page
pool (`Model.init_slot_cache(page_size=..., n_pages=...)`) and admission
prefill becomes *chunked prefill-into-slot*:

    admit    reserve every page the request can ever need (prompt +
             n_new - 1 tokens, `serve.paged.PageAllocator`) — all-or-
             nothing, so a running request never stalls on allocation
             and backpressure happens at admission, where the request
             just stays queued;
    chunk    one pinned (n_slots, prefill_chunk) `Model.prefill_chunk`
             call per step streams every mid-prompt slot's next chunk
             into its pages, interleaved with the pool's decode steps
             (Sarathi-style) — the pinned prompt-width cap is gone,
             prompts are bounded by table capacity (engine.max_len), not
             by a shared admission width;
    decode   the same single decode executable, now with the block table
             riding as a traced operand — the paged ``raceit_*_paged``
             backends follow the page indirection in-kernel.

Per-call block tables fence non-participants: a decode call zeroes the
rows of slots still mid-prompt (their pad-token decode writes route to
the trash page instead of corrupting freshly streamed pages), and a chunk
call zeroes the rows of decoding slots. Quarantined slots *leak* their
private pages (`PageAllocator.leak_slot`): a decode-fault map is static
per executable, so the slot row is dead for the run and returning its
pages to the free list would hand a live request pages a dead row still
addresses. In digital greedy mode paged serving keeps token-level solo
parity (tests/test_serve_paged.py fuzzes the lifecycle); raceit modes add
one softening to the list above — chunked prefill quantizes k/v per page
as it streams, while a solo prefill's quantizer sees the whole prompt at
once, so admission-path logits may differ in the last quantization step.

**The service layer on top** (PR 8): three host-side subsystems ride the
paged pool without touching a kernel —

* a **content-addressed prefix cache** (`serve.prefix.PrefixCache`, on by
  default in paged mode): paged admission walks the prompt's chained
  page hashes, maps every hit page straight into the slot's block table
  (refcounted, immutable) and starts chunk streaming at the first miss;
  miss requests promote their fully-streamed prompt pages back into the
  cache. Hit-path outputs stay bitwise equal to cold-path outputs in
  digital greedy mode: a shared page holds exactly the KV values the
  request would have computed (same tokens, same absolute positions,
  per-tensor RACE-IT scales), and the paged kernels are page-permutation
  invariant;
* a **tenant-aware admission router** (`serve.router.AdmissionRouter`)
  replaces the FIFO deque: fifo / priority / weighted-fair (deficit
  round-robin on a token budget) policies decide which queued request
  the next admission sees, with per-tenant depth caps rejecting at
  submit via structured ``RequestError(stage="admit")``;
* a **step-clock metrics recorder** (`serve.metrics.ServeMetrics`):
  TTFT and per-token-latency histograms in scheduler steps (no
  wall-clock anywhere near traced code), per-tenant service counts, and
  the allocator/prefix counters — all surfaced by `summary()` and the
  ``serve/`` bench rows.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .batching import Request, RequestError
from .engine import GenerationEngine
from .metrics import ServeMetrics
from .paged import PageAllocator
from .prefix import PrefixCache
from .router import AdmissionRouter

__all__ = ["ContinuousBatcher"]


def _scatter_row(pool, row, slot):
    """Write a batch-1 cache's row into a slot of the pool cache.

    Leaves agree on every dim except the batch axis (n_slots vs 1) —
    scan-stacked leaves carry it at axis 1, tail leaves at axis 0 — so the
    first differing axis *is* the batch axis and a dynamic_update_slice of
    the 1-sized row at ``slot`` along it is the whole scatter.
    """
    def put(p, r):
        if p.shape == r.shape:  # n_slots == 1: the row is the pool
            return r.astype(p.dtype)
        axis = next(i for i, (a, b) in enumerate(zip(p.shape, r.shape))
                    if a != b)
        start = tuple(slot if i == axis else 0 for i in range(p.ndim))
        return jax.lax.dynamic_update_slice(p, r.astype(p.dtype), start)
    return jax.tree.map(put, pool, row)


# donating the pool lets XLA update the slot row in place — without it
# every admission would copy the whole (n_slots, max_len, ...) cache per
# layer just to write one row, and admission cost would scale with pool
# size on exactly the high-churn traces the scheduler exists for. ``slot``
# is traced, so one executable serves every slot index.
_scatter_row_jit = jax.jit(_scatter_row, donate_argnums=(0,))


@dataclasses.dataclass
class _Slot:
    req: Request
    tokens: list          # generated so far (python ints)
    pad: int              # left-pad columns in this slot's cache
    length: int           # valid cache columns (pad + real, incl. generated)
    fed: int = 0          # prompt tokens streamed so far (paged mode; a
                          # slot with fed < len(prompt) is mid-prefill and
                          # joins chunk calls instead of decode calls).
                          # Starts at the prefix-cache hit length: hit
                          # pages are already resident, streaming begins
                          # at the first miss token.
    promoted: int = 0     # leading block-table pages that are shared
                          # (prefix-cache hits + own promotions) — the
                          # promotion walk's resume point
    chain: bytes = b""    # chain digest after page ``promoted - 1``
    promo_dead: bool = False  # promotion stopped (a concurrent request
                          # registered the same digest first; promoting
                          # past it would scramble the refs-then-owned
                          # block-row order)


class ContinuousBatcher:
    """Continuous batching over a fixed slot pool.

    Same submit/run_all surface as `BatchScheduler`. ``n_slots`` fixes the
    decode batch. The cache comes in two forms:

    * **paged** (the default when the model qualifies — see
      `pageable_reason`): a block-paged page pool; prompts stream into
      their slot across pinned-width `Model.prefill_chunk` calls
      (``prefill_chunk`` tokens per slot per step, default
      ``page_size``), so no shared admission width exists and a prompt is
      bounded only by ``engine.max_len``. ``page_size`` sets the page
      granularity and ``n_pages`` the pool size (default: full capacity,
      ``1 + n_slots * ceil(max_len / page_size)`` — shrink it to trade
      admission backpressure for memory).
    * **contiguous** (``paged=False``, or an explicit ``prefill_len``,
      or a non-qualifying model): admission is a solo left-padded
      prefill at the pinned ``prefill_len`` width scattered into the
      slot's cache row; when ``prefill_len`` is omitted it locks to the
      longest prompt queued at the first admission.

    Occupancy counters (`decode_steps`, `decode_tokens`, `prefills`,
    `chunk_calls`, `tokens_out`, `model_calls`) feed the
    ``serve/continuous_occupancy`` benchmark rows: decode tokens per
    decode step on a mixed-length trace is the metric the bucketed
    scheduler loses to slot idling (prefill is accounted separately — it
    is a different cost class; in paged mode ``prefills`` counts
    per-request prompt *completions* and ``chunk_calls`` the pinned-shape
    chunk executions that did the streaming).
    """

    def __init__(self, engine: GenerationEngine, n_slots: int = 4,
                 prefill_len: Optional[int] = None, pad_id: int = 0,
                 rng: Optional[jax.Array] = None,
                 paged: Optional[bool] = None, page_size: int = 64,
                 prefill_chunk: Optional[int] = None,
                 n_pages: Optional[int] = None,
                 router: Union[AdmissionRouter, str, None] = None,
                 tenant_weights: Optional[dict] = None,
                 tenant_cap: Optional[int] = None,
                 prefix_cache: Optional[bool] = None):
        self.engine = engine
        self.n = n_slots
        # mesh-sharded serving: the batcher never touches the mesh itself
        # (the TP attention backends shard the pool inside the jitted decode
        # step; block tables and slot lengths stay replicated host state),
        # it only surfaces the shape and the resolved decode backend in
        # summary() so a silently-degraded mesh (non-dividing KV heads) is
        # visible in the service report, not just in plan.explain()
        _mesh = getattr(engine.exec_cfg, "mesh", None)
        self.mesh_spec = (_mesh if _mesh is not None
                          and getattr(_mesh, "n_devices", 1) > 1 else None)
        self.prefill_len = prefill_len
        self.pad_id = pad_id
        self.rng = rng if rng is not None else jax.random.PRNGKey(0)
        why = self.pageable_reason(engine)
        if paged is None:
            # paged by default when the model qualifies; an explicit
            # prefill_len is the back-compat pin for the contiguous path
            paged = prefill_len is None and why is None
        elif paged:
            if why is not None:
                raise ValueError(f"paged serving unsupported: {why}")
            if prefill_len is not None:
                raise ValueError(
                    "prefill_len pins the contiguous admission path; paged "
                    "mode streams prompts in chunks — pass prefill_chunk "
                    "to size the chunk instead")
        self.paged = paged
        self.prefix: Optional[PrefixCache] = None
        if paged:
            self.page_size = int(page_size)
            self.prefill_chunk = int(prefill_chunk or page_size)
            if self.page_size < 1 or self.prefill_chunk < 1:
                raise ValueError("page_size and prefill_chunk must be >= 1")
            self.max_pages = -(-engine.max_len // self.page_size)
            self.n_pages = (int(n_pages) if n_pages is not None
                            else 1 + n_slots * self.max_pages)
            self.allocator = PageAllocator(self.n_pages)
            self.block_table = np.zeros((n_slots, self.max_pages), np.int32)
            # content-addressed prefix reuse is the paged default: shared
            # prompt pages cost nothing when no prefixes repeat (pure
            # host-side bookkeeping) and admission maps hits for free
            if prefix_cache is None or prefix_cache:
                self.prefix = PrefixCache(self.allocator, self.page_size)
        elif prefix_cache:
            raise ValueError(
                "the prefix cache shares immutable pages of the block-paged "
                "pool; contiguous slot caches have nothing to share — drop "
                "prefix_cache or serve paged")
        if isinstance(router, AdmissionRouter):
            if tenant_weights is not None or tenant_cap is not None:
                raise ValueError(
                    "pass tenant_weights/tenant_cap to the AdmissionRouter "
                    "you are constructing, not alongside an instance")
            self.queue = router
        else:
            self.queue = AdmissionRouter(policy=router or "fifo",
                                         weights=tenant_weights,
                                         max_queue_per_tenant=tenant_cap)
        self.metrics = ServeMetrics()
        self._rids: set[int] = set()  # every rid ever submitted
        self.done: dict[int, Request] = {}
        self.slots: list[Optional[_Slot]] = [None] * n_slots
        # slots quarantined by decode-step faults: the injected fault maps
        # are static per executable (see repro.hw.noise), so a slot row
        # that produced non-finite logits once will again — never re-admit
        # into it. Contiguous admission-prefill faults do NOT quarantine
        # (the solo (1, P) prefill executable is not tied to any slot
        # row); paged chunk-call faults DO (the chunk call shares the
        # pool's (n_slots,) row geometry).
        self.dead_slots: set[int] = set()
        self.cache = None  # slot-pool cache, built at first admission
        self.tok = np.full((n_slots, 1), pad_id, np.int32)
        self.decode_steps = 0
        self.decode_tokens = 0
        self.prefills = 0
        self.chunk_calls = 0
        self.tokens_out = 0

    # ------------------------------------------------------------ lifecycle
    @staticmethod
    def pageable_reason(engine: GenerationEngine) -> Optional[str]:
        """None when the model can serve block-paged, else the reason.

        Mirrors the capability-predicate convention of
        `repro.exec.registry.BackendSpec`. The *backend* never disqualifies
        a model — non-paged decode backends are served by the gather
        degrade in `repro.models.layers.attention` — only cache layouts
        with no paged form do (ring buffers, SSM state).
        """
        cfg = engine.cfg
        if cfg.is_encoder_decoder:
            return "encoder-decoder stacks serve bucketed, not slot-pooled"
        mixers = {cfg.layer_spec(i)[0] for i in range(cfg.n_layers)}
        if mixers != {"attn"}:
            return (f"mixers {sorted(mixers - {'attn'})} have no paged "
                    f"cache form (local ring buffers / SSM state)")
        return None

    @property
    def model_calls(self) -> int:
        """Prefill + decode executions — the occupancy denominator.

        Paged mode counts chunk *calls* (its prefill executions);
        ``prefills`` there counts prompt completions, not calls.
        """
        if self.paged:
            return self.decode_steps + self.chunk_calls
        return self.decode_steps + self.prefills

    def _pages_needed(self, req: Request) -> int:
        # every column the request can ever write: the prompt plus the
        # n_new - 1 decode-step writes (the last sampled token is never
        # written — the request retires first)
        return -(-(len(req.prompt) + req.n_new - 1) // self.page_size)

    def _head_starved(self) -> bool:
        """True when the policy head can NEVER be admitted from here.

        Called only with every slot empty (nothing running, so no retire
        will ever free a page). Prefix-cache hits reduce the head's
        private-page need, and every ref==0 cached page is reclaimable by
        eviction (with no live slots, *all* shared pages are at ref 0
        except the head's own pinned hit run, which it doesn't need to
        allocate anyway) — so starvation means: private need exceeds free
        plus evictable.
        """
        head = self.queue[0]
        need = self._pages_needed(head)
        headroom = self.allocator.n_free
        if self.prefix is not None:
            hits, _, _ = self.prefix.match(head.prompt)
            need -= len(hits)
            headroom += self.prefix.n_evictable(
                pinned=frozenset(page for _, page in hits))
        return need > headroom

    def submit(self, req: Request):
        if req.rid in self._rids:
            # a silent re-submit would overwrite the first request's entry
            # in ``done`` AND cross-wire allocator ownership (two live
            # slots keyed by one rid) — malformed traffic, so raise rather
            # than reject with a structured error
            raise ValueError(
                f"duplicate rid {req.rid}: a request with this rid was "
                f"already submitted to this batcher (done, running, or "
                f"queued) — rids key the result map and page ownership, "
                f"so reuse would silently drop the earlier request")
        if len(req.prompt) == 0:
            raise ValueError(
                f"request {req.rid}: empty prompt — the first token is "
                f"sampled from the prompt's last position, so there is "
                f"nothing to prefill")
        if self.paged:
            if len(req.prompt) + req.n_new > self.engine.max_len:
                raise ValueError(
                    f"prompt of {len(req.prompt)} tokens + "
                    f"n_new={req.n_new} exceeds the block table's capacity "
                    f"(engine max_len={self.engine.max_len})")
            if self._pages_needed(req) > self.n_pages - 1:
                raise ValueError(
                    f"request needs {self._pages_needed(req)} pages but "
                    f"the pool has {self.n_pages - 1} allocatable pages "
                    f"(n_pages={self.n_pages} incl. the trash page)")
            self._enqueue(req)
            return
        if self.prefill_len is not None and len(req.prompt) > self.prefill_len:
            raise ValueError(
                f"prompt of {len(req.prompt)} tokens exceeds the pool's "
                f"pinned prefill_len={self.prefill_len}")
        # the slot must hold the (possibly padded) prompt plus every
        # generated token; reject at submit time, before the request could
        # be popped mid-admission
        width = (self.prefill_len if self.prefill_len is not None
                 else len(req.prompt))
        if width + req.n_new > self.engine.max_len:
            raise ValueError(
                f"prompt width {width} + n_new={req.n_new} exceeds the "
                f"engine's max_len={self.engine.max_len}")
        self._enqueue(req)

    def _enqueue(self, req: Request):
        """Hand a validated request to the admission router; a depth-cap
        rejection retires it immediately with the router's structured
        ``RequestError(stage="admit")`` (overload is data, not an
        exception)."""
        self._rids.add(req.rid)
        err = self.queue.push(req)
        if err is not None:
            req.error = err
            self.done[req.rid] = req
            self.metrics.on_reject(req.rid)
        else:
            self.metrics.on_submit(req.rid, req.tenant)

    def _lock_prefill_len(self):
        if self.prefill_len is not None:
            return
        width = max(len(r.prompt) for r in self.queue)
        # joint feasibility before anything is admitted: every queued
        # request was individually accepted against its own prompt length,
        # but they must all fit slots of the SHARED width — fail fast here
        # (nothing is in flight yet and the queue is intact) rather than
        # mid-stream at some later admission
        worst = max(r.n_new for r in self.queue)
        if width + worst > self.engine.max_len:
            raise ValueError(
                f"queued requests are jointly infeasible: pool width would "
                f"lock to {width} (longest prompt) but a request with "
                f"n_new={worst} then exceeds max_len={self.engine.max_len};"
                f" pass an explicit prefill_len or split the traffic")
        self.prefill_len = width

    def _admit(self):
        """Fill free slots from the queue.

        Contiguous: solo prefill -> row scatter. Paged: reserve pages +
        map the block-table row; the prompt streams in over later
        `_chunk_step` calls.
        """
        if self.paged:
            self._admit_paged()
            return
        eng = self.engine
        for slot in range(self.n):
            if (slot in self.dead_slots or self.slots[slot] is not None
                    or not self.queue):
                continue
            self._lock_prefill_len()
            head = self.queue[0]  # validate before popping: a rejected
            P = len(head.prompt)  # request must not vanish mid-admission
            if P > self.prefill_len:
                raise ValueError(
                    f"prompt of {P} tokens exceeds the pool's pinned "
                    f"prefill_len={self.prefill_len}")
            if self.prefill_len + head.n_new > eng.max_len:
                # possible when the pool width locked to a longer prompt
                # than this request was submitted against
                raise ValueError(
                    f"pinned prefill_len={self.prefill_len} + "
                    f"n_new={head.n_new} exceeds the engine's "
                    f"max_len={eng.max_len}")
            req = self.queue.popleft()
            pad = self.prefill_len - P
            prompt = np.full((1, self.prefill_len), self.pad_id, np.int32)
            prompt[0, pad:] = req.prompt
            # one pinned (1, prefill_len) prefill executable serves every
            # admission; pad_lens always rides (0 included) so the trace
            # never forks on the pad structure
            row_cache = eng.model.init_cache(1, eng.max_len)
            logits, row_cache = eng._prefill(
                eng.params, jnp.asarray(prompt), row_cache,
                pad_lens=jnp.asarray([pad], jnp.int32))
            self.prefills += 1
            if bool(eng.nonfinite_rows(logits[:, -1])[0]):
                # fail-safe: retire the request with a structured error
                # before its row touches the pool cache; the slot stays
                # free (the solo prefill executable is not slot-bound, so
                # nothing is learned about this row)
                req.error = RequestError(
                    rid=req.rid, stage="prefill", step=0,
                    reason="non-finite logits from the admission prefill")
                self.done[req.rid] = req
                self.metrics.on_error(req.rid)
                continue
            if self.cache is None:
                self.cache = eng.model.init_slot_cache(self.n, eng.max_len)
            # the solo cache's scalar write indices become 1-vectors so the
            # scatter sees the same structure the pool carries
            from repro.models.model import map_cache_idx
            row_cache = map_cache_idx(
                row_cache, lambda a: jnp.asarray(a, jnp.int32)[..., None])
            self.cache = _scatter_row_jit(self.cache, row_cache,
                                          jnp.int32(slot))
            self.rng, sub = jax.random.split(self.rng)
            tok0 = int(np.asarray(eng._sample(logits[:, -1], sub))[0])
            # length counts cache columns: the prompt is in, the first
            # generated token is not — the next decode step writes it
            st = _Slot(req=req, tokens=[tok0], pad=pad,
                       length=self.prefill_len)
            self.tokens_out += 1
            self.metrics.on_first_token(req.rid, req.tenant)
            self.tok[slot, 0] = tok0
            self.slots[slot] = st
            self._retire_if_done(slot)

    def _admit_paged(self):
        """Reserve pages + block-table rows for queued requests.

        Whole-request, all-up-front reservation: a request is admitted
        only with every page it can ever write already owned, so running
        requests never stall on allocation. When the head doesn't fit,
        admission stops entirely (``break``, not skip) — serving a later,
        smaller request first would override the router's choice and can
        starve the chosen tenant indefinitely (the policy head blocks,
        whatever the policy).

        With the prefix cache on, admission first walks the prompt's
        chained page hashes: every hit page is mapped into the head of
        the slot's block-table row as an immutable shared reference
        (ref += 1, never written — the slot's first write lands at
        ``fed``, which starts past the hit), only the remaining pages are
        allocated privately, and chunk streaming begins at the first
        miss. Under allocation pressure, ref==0 cached pages are evicted
        LRU-first (the hit run itself is pinned) before giving up.
        """
        eng = self.engine
        for slot in range(self.n):
            if (slot in self.dead_slots or self.slots[slot] is not None
                    or not self.queue):
                continue
            head = self.queue[0]
            hits, digest, hit_tokens = [], b"", 0
            if self.prefix is not None:
                hits, digest, hit_tokens = self.prefix.match(head.prompt)
            hit_pages = [page for _, page in hits]
            need = self._pages_needed(head) - len(hit_pages)
            if need > self.allocator.n_free and self.prefix is not None:
                self.prefix.evict(need - self.allocator.n_free,
                                  pinned=frozenset(hit_pages))
            pages = self.allocator.alloc(slot, need)
            if pages is None:
                break  # backpressure: head stays queued, policy intact
            req = self.queue.popleft()
            for page in hit_pages:
                self.allocator.acquire(slot, page)
            if self.prefix is not None:
                self.prefix.commit(hits, len(head.prompt) // self.page_size)
            if self.cache is None:
                self.cache = eng.model.init_slot_cache(
                    self.n, eng.max_len, page_size=self.page_size,
                    n_pages=self.n_pages)
            self.block_table[slot, :] = 0
            self.block_table[slot, : len(hit_pages)] = hit_pages
            self.block_table[slot, len(hit_pages):
                             len(hit_pages) + len(pages)] = pages
            # no tokens yet: the slot is mid-prefill and joins chunk calls
            # until the rest of the prompt (everything past the hit) is
            # streamed in; promotion resumes the hash chain at the hit's
            # last digest
            self.slots[slot] = _Slot(req=req, tokens=[], pad=0, length=0,
                                     fed=hit_tokens,
                                     promoted=len(hit_pages), chain=digest)

    def _quarantine(self, slot: int):
        """Retire a faulted slot row for the rest of the run.

        The injected fault maps are static per executable (repro.hw.noise)
        so the row would fault every future call too. Paged slots *leak*
        their pages — see `PageAllocator.leak_slot` for why they never
        return to the free list.
        """
        self.slots[slot] = None
        self.tok[slot, 0] = self.pad_id
        self.dead_slots.add(slot)
        if self.paged:
            self.allocator.leak_slot(slot)
            self.block_table[slot, :] = 0

    def _retire_if_done(self, slot: int) -> bool:
        st = self.slots[slot]
        if st is None or len(st.tokens) < st.req.n_new:
            return st is None
        st.req.result = np.asarray(st.tokens[: st.req.n_new], np.int32)
        self.done[st.req.rid] = st.req
        self.slots[slot] = None
        self.tok[slot, 0] = self.pad_id
        if self.paged:
            self.allocator.free_slot(slot)
            self.block_table[slot, :] = 0
        return True

    def _chunk_step(self):
        """One pinned (n_slots, prefill_chunk) chunk call: stream every
        mid-prompt slot's next chunk into its pages.

        The per-call block table zeroes non-participating rows, fencing
        their (pad-token) writes to the trash page. A slot whose prompt
        completes here samples its first token from the chunk's
        last-position logits and joins the *same* step's decode call.
        """
        feeding = [i for i, s in enumerate(self.slots)
                   if s is not None and s.fed < len(s.req.prompt)]
        if not feeding:
            return
        eng = self.engine
        C = self.prefill_chunk
        toks = np.full((self.n, C), self.pad_id, np.int32)
        offs = np.zeros(self.n, np.int32)
        feeds = np.zeros(self.n, np.int32)
        bt = np.zeros_like(self.block_table)
        for i in feeding:
            st = self.slots[i]
            feed = min(C, len(st.req.prompt) - st.fed)
            toks[i, :feed] = st.req.prompt[st.fed: st.fed + feed]
            offs[i] = st.fed
            feeds[i] = feed
            bt[i] = self.block_table[i]
        logits, self.cache = eng._prefill_chunk(
            eng.params, jnp.asarray(toks), self.cache, jnp.asarray(offs),
            jnp.asarray(feeds), jnp.asarray(bt), self.page_size)
        self.chunk_calls += 1
        bad = eng.nonfinite_rows(logits[:, -1])
        self.rng, sub = jax.random.split(self.rng)
        sampled = np.asarray(eng._sample(logits[:, -1], sub))
        for i in feeding:
            st = self.slots[i]
            if bad[i]:
                # unlike the contiguous solo admission prefill, the chunk
                # call shares the pool's (n_slots,) row geometry — a
                # faulted row is dead for the run exactly like a decode
                # fault, so quarantine (and leak the pages)
                st.req.error = RequestError(
                    rid=st.req.rid, stage="prefill", step=st.fed,
                    reason="non-finite logits from a prefill chunk")
                self.done[st.req.rid] = st.req
                self.metrics.on_error(st.req.rid)
                self._quarantine(i)
                continue
            st.fed += int(feeds[i])
            self._promote_streamed(i)
            if st.fed == len(st.req.prompt):
                # prompt complete: the chunk's last fed position IS the
                # prompt's last position, so its logits seed generation
                self.prefills += 1
                tok0 = int(sampled[i])
                st.tokens.append(tok0)
                st.length = len(st.req.prompt)
                self.tokens_out += 1
                self.metrics.on_first_token(st.req.rid, st.req.tenant)
                self.tok[i, 0] = tok0
                self._retire_if_done(i)

    def _promote_streamed(self, slot: int):
        """Register this slot's fully-streamed prompt pages as shared.

        Runs after every chunk advance (a healthy chunk only — a faulted
        row quarantines before reaching here, so a page written by a
        faulting call is never promoted). Walks forward from
        ``st.promoted``: a page is promotable once the stream passed its
        end, and only full *prompt* pages qualify (a page that will also
        hold decode-step writes stays private — it is not immutable).
        Promotion stops for good at a digest some concurrent request
        registered first: its copy serves future lookups, ours stays
        private, and promoting past it would interleave shared and
        private pages in the block-table row (the allocator keeps rows
        as refs-then-owned).
        """
        st = self.slots[slot]
        if self.prefix is None or st.promo_dead:
            return
        ps = self.page_size
        prompt = st.req.prompt
        limit = min(st.fed, len(prompt)) // ps  # pages fully streamed
        while st.promoted < limit:
            lo = st.promoted * ps
            page = int(self.block_table[slot, st.promoted])
            ok, nxt = self.prefix.promote(slot, page, st.chain,
                                          prompt[lo: lo + ps])
            if not ok:
                st.promo_dead = True
                break
            st.chain = nxt
            st.promoted += 1

    # ---------------------------------------------------------------- steps
    def step(self) -> list[int]:
        """Admit into free slots, chunk mid-prompt slots (paged), then
        decode the pool once.

        Returns the rids retired by this step (admission / a completing
        chunk can retire n_new=1 requests without a decode).
        """
        self.metrics.tick()
        before = set(self.done)
        self._admit()
        if self.queue and len(self.dead_slots) >= self.n:
            # every slot is quarantined: fail the remaining queue with
            # structured errors rather than spinning forever (run_all
            # would otherwise loop on a queue no slot can serve)
            while self.queue:
                req = self.queue.popleft()
                req.error = RequestError(
                    rid=req.rid, stage="admit", step=0,
                    reason="all slots quarantined by decode-step faults")
                self.done[req.rid] = req
                self.metrics.on_error(req.rid)
        elif (self.paged and self.queue
              and all(s is None for s in self.slots)
              and self._head_starved()):
            # page-pool deadlock: nothing is running (so no retire will
            # ever free a page — quarantine leaks shrank the pool for
            # good) and the head can never be admitted, even counting its
            # prefix-cache hits and every evictable cached page. Fail it
            # with a structured error; smaller queued requests get their
            # chance next step, in policy order.
            req = self.queue.popleft()
            req.error = RequestError(
                rid=req.rid, stage="admit", step=0,
                reason=f"request needs {self._pages_needed(req)} pages but "
                       f"only {self.allocator.n_free} remain allocatable "
                       f"({self.allocator.n_leaked} leaked by quarantined "
                       f"slots)")
            self.done[req.rid] = req
            self.metrics.on_error(req.rid)
        if self.paged:
            self._chunk_step()
        # mid-prefill paged slots (fed < prompt) sit this decode out —
        # their rows ride as empty (kv_len 0, block-table row zeroed)
        active = [i for i, s in enumerate(self.slots)
                  if s is not None
                  and (not self.paged or s.fed == len(s.req.prompt))]
        if active:
            eng = self.engine
            # per-slot lengths INCLUDING this step's write; 0 = empty slot
            slot_lens = np.zeros(self.n, np.int32)
            for i in active:
                slot_lens[i] = self.slots[i].length + 1
            if self.paged:
                # per-call block table: only decoding rows keep their
                # pages; everyone else (empty, dead, mid-prefill) writes
                # to the trash page
                bt = np.zeros_like(self.block_table)
                for i in active:
                    bt[i] = self.block_table[i]
                logits, self.cache = eng._decode(
                    eng.params, jnp.asarray(self.tok), self.cache,
                    None, None, jnp.asarray(slot_lens), jnp.asarray(bt),
                    page_size=self.page_size)
            else:
                pad_lens = np.zeros(self.n, np.int32)
                for i in active:
                    pad_lens[i] = self.slots[i].pad
                logits, self.cache = eng._decode(
                    eng.params, jnp.asarray(self.tok), self.cache,
                    jnp.asarray(pad_lens), jnp.int32(self.prefill_len),
                    jnp.asarray(slot_lens))
            self.decode_steps += 1
            self.rng, sub = jax.random.split(self.rng)
            bad = eng.nonfinite_rows(logits[:, -1])
            toks = np.asarray(eng._sample(logits[:, -1], sub))
            for i in active:
                st = self.slots[i]
                if bad[i]:
                    # fail-safe: a non-finite decode row retires ONLY this
                    # request (the decode paths are row-independent — see
                    # the raceit_noisy_staged notes — so neighbours'
                    # logits are untouched) and quarantines the slot: the
                    # fault map is static per executable, so this row
                    # would fault every future step too
                    st.req.error = RequestError(
                        rid=st.req.rid, stage="decode", step=len(st.tokens),
                        reason="non-finite logits at the decode step")
                    self.done[st.req.rid] = st.req
                    self.metrics.on_error(st.req.rid)
                    self._quarantine(i)
                    continue
                st.length += 1
                st.tokens.append(int(toks[i]))
                self.tokens_out += 1
                self.decode_tokens += 1
                self.metrics.on_token(st.req.rid, st.req.tenant)
                self.tok[i, 0] = int(toks[i])
                self._retire_if_done(i)
        return sorted(set(self.done) - before)

    def run_all(self) -> dict[int, Request]:
        while self.queue or any(s is not None for s in self.slots):
            self.step()
        return self.done

    def summary(self) -> dict:
        """End-of-run service report: occupancy counters, step-clock
        latency percentiles (TTFT / per-token, in scheduler steps),
        per-tenant service + fairness, and — in paged mode — the page
        economy (in-use / shared / leaked / peak) and prefix-cache hit
        rates. Everything here is a deterministic host-side counter; the
        launcher prints it and the ``serve/`` bench rows gate on it.
        """
        s = {
            "requests_done": len(self.done),
            "prefills": self.prefills,
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "tokens_out": self.tokens_out,
            "model_calls": self.model_calls,
            "router_policy": self.queue.policy,
            "router_rejected": self.queue.rejected,
            "queue_depths": self.queue.depths(),
            "fairness_jain": self.metrics.fairness(self.queue.weights),
        }
        if self.mesh_spec is not None:
            s["mesh"] = self.mesh_spec.describe()
            s["decode_backend"] = self.engine.plan.backend("attention_decode")
        if self.paged:
            s["chunk_calls"] = self.chunk_calls
            a = self.allocator
            s.update(pages_allocatable=self.n_pages - 1,
                     pages_in_use=a.pages_in_use, pages_shared=a.n_shared,
                     pages_leaked=a.n_leaked, pages_free=a.n_free,
                     pages_peak_in_use=a.peak_in_use)
            if self.prefix is not None:
                s.update(self.prefix.stats())
        s.update(self.metrics.summary())
        return s
