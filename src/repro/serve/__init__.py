from .engine import GenerationEngine  # noqa: F401
from .batching import BatchScheduler, Request, RequestError  # noqa: F401
from .continuous import ContinuousBatcher  # noqa: F401
