from .engine import GenerationEngine  # noqa: F401
from .batching import BatchScheduler, Request, RequestError  # noqa: F401
from .continuous import ContinuousBatcher  # noqa: F401
from .metrics import Histogram, ServeMetrics, jain  # noqa: F401
from .prefix import PrefixCache, page_digest  # noqa: F401
from .router import AdmissionRouter  # noqa: F401
