"""Step-clock serving latency metrics: TTFT, per-token latency, fairness.

The recorder's clock is the **scheduler step counter** — `ServeMetrics
.tick()` once at the top of every `ContinuousBatcher.step()` — never
wall-clock: nothing here touches a traced value or a timer, so the
numbers are bit-deterministic across runs and machines and can gate CI
(`benchmarks/kernels_bench.py` emits them as ``serve/`` rows with zero
run-to-run noise). One step is one scheduler round (admissions + at most
one chunk call + at most one decode call), which is exactly the unit an
accelerator pays for: a request's step-TTFT counts the queue wait plus
every chunk call its prompt needed, so a prefix-cache hit that skips
chunk calls shows up directly.

Latency definitions (all in steps):

    TTFT        steps from ``on_submit`` to the request's first sampled
                token (``on_first_token``) — queue wait included, which
                is what the admission router redistributes;
    per-token   steps between consecutive sampled tokens of one request
                (``on_token``); 1 is a perfectly-occupied decode, >1
                means the slot sat out steps (pool mid-prefill, etc.).

`Histogram` keeps raw samples (these are scheduler counters, thousands
at most, not a hot path) and reports exact order-statistic percentiles —
p50/p99 by the nearest-rank rule — plus mean/max. `jain` is Jain's
fairness index over per-tenant weighted service, the bench's
``serve/router_fairness_jain`` row.
"""
from __future__ import annotations

from typing import Optional, Sequence

__all__ = ["Histogram", "ServeMetrics", "jain"]


def jain(xs: Sequence[float]) -> float:
    """Jain's fairness index: (Σx)² / (n·Σx²) ∈ (0, 1], 1 = equal.

    Callers pass *weight-normalized* service (tokens_served / weight per
    tenant), so 1.0 means every tenant got service exactly proportional
    to its weight.
    """
    xs = [float(x) for x in xs]
    if not xs:
        return 1.0
    sq = sum(x * x for x in xs)
    if sq == 0.0:
        return 1.0
    return (sum(xs) ** 2) / (len(xs) * sq)


class Histogram:
    """Exact percentiles over integer step counts (nearest-rank)."""

    def __init__(self):
        self.samples: list[float] = []

    def add(self, value: float) -> None:
        self.samples.append(float(value))

    def __len__(self) -> int:
        return len(self.samples)

    def percentile(self, p: float) -> Optional[float]:
        """Nearest-rank percentile; None when empty."""
        if not self.samples:
            return None
        if not 0 < p <= 100:
            raise ValueError(f"percentile p={p} must be in (0, 100]")
        ordered = sorted(self.samples)
        rank = max(1, -(-len(ordered) * p // 100))  # ceil(n*p/100)
        return ordered[int(rank) - 1]

    def summary(self) -> dict:
        if not self.samples:
            return {"n": 0, "p50": None, "p99": None, "mean": None,
                    "max": None}
        return {
            "n": len(self.samples),
            "p50": self.percentile(50),
            "p99": self.percentile(99),
            "mean": sum(self.samples) / len(self.samples),
            "max": max(self.samples),
        }


class ServeMetrics:
    """Per-request latency + per-tenant service accounting for a batcher.

    Host-side only. The batcher drives it:

        tick             top of every step()
        on_submit        submit() accepted the request into the queue
        on_reject        submit() retired it with a structured error
        on_first_token   the request sampled its first token
        on_token         every subsequent sampled token
        on_error         retired mid-flight by a fault

    ``tenant_tokens`` counts *all* sampled tokens per tenant — the
    service measure `jain` weighs for the fairness row.
    """

    def __init__(self):
        self.step = 0
        self.ttft = Histogram()
        self.tpl = Histogram()  # per-token (inter-token) latency
        self._submitted: dict[int, int] = {}   # rid -> submit step
        self._last_tok: dict[int, int] = {}    # rid -> last token step
        self.tenant_tokens: dict[str, int] = {}
        self.tenant_requests: dict[str, int] = {}
        self.rejected = 0
        self.errored = 0

    # ------------------------------------------------------------- events
    def tick(self) -> None:
        self.step += 1

    def on_submit(self, rid: int, tenant: str = "default") -> None:
        self._submitted[rid] = self.step
        self.tenant_requests[tenant] = self.tenant_requests.get(tenant, 0) + 1

    def on_reject(self, rid: int) -> None:
        self._submitted.pop(rid, None)
        self.rejected += 1

    def on_first_token(self, rid: int, tenant: str = "default") -> None:
        submitted = self._submitted.pop(rid, None)
        if submitted is not None:
            self.ttft.add(self.step - submitted)
        self._last_tok[rid] = self.step
        self.tenant_tokens[tenant] = self.tenant_tokens.get(tenant, 0) + 1

    def on_token(self, rid: int, tenant: str = "default") -> None:
        last = self._last_tok.get(rid)
        if last is not None:
            self.tpl.add(self.step - last)
        self._last_tok[rid] = self.step
        self.tenant_tokens[tenant] = self.tenant_tokens.get(tenant, 0) + 1

    def on_error(self, rid: int) -> None:
        self._submitted.pop(rid, None)
        self._last_tok.pop(rid, None)
        self.errored += 1

    # -------------------------------------------------------------- report
    def fairness(self, weights: Optional[dict] = None) -> float:
        """Jain index over tokens-served / weight across tenants seen."""
        weights = weights or {}
        if not self.tenant_tokens:
            return 1.0
        return jain([tok / float(weights.get(t, 1.0))
                     for t, tok in sorted(self.tenant_tokens.items())])

    def summary(self) -> dict:
        ttft, tpl = self.ttft.summary(), self.tpl.summary()
        return {
            "steps": self.step,
            "ttft_p50": ttft["p50"], "ttft_p99": ttft["p99"],
            "ttft_mean": ttft["mean"], "ttft_n": ttft["n"],
            "tpl_p50": tpl["p50"], "tpl_p99": tpl["p99"],
            "tpl_mean": tpl["mean"], "tpl_n": tpl["n"],
            "tenant_tokens": dict(sorted(self.tenant_tokens.items())),
            "tenant_requests": dict(sorted(self.tenant_requests.items())),
            "rejected": self.rejected,
            "errored": self.errored,
        }
