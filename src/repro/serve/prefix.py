"""Content-addressed prefix cache over the block-paged KV pool.

At multi-tenant scale most requests share system-prompt prefixes, and the
block-paged pool (serve/paged.py, PR 7) makes reuse a pure *allocator*
problem: a prompt page's KV content is a deterministic function of the
token prefix that produced it (causal stack, absolute positions fixed by
the page index), the paged kernels are invariant under page permutation,
and RACE-IT quantizer scales are per-tensor — so a cached int8 code page
is reusable **verbatim** by any request whose prompt starts with the same
tokens, with zero kernel edits.

**Chained page hashes.** Each *full* page of prompt tokens is keyed by

    h_0 = H(root | tokens[0:ps])
    h_i = H(h_{i-1} | tokens[i*ps:(i+1)*ps])

so a hit on ``h_i`` certifies the *entire* prefix up to and including
page ``i`` matches — lookups walk the chain and stop at the first miss,
and two prompts that diverge anywhere produce unrelated digests from the
divergent page onward (content addressing without storing any tokens).

**Lifecycle** (the allocator transitions live in
`repro.serve.paged.PageAllocator`; this module owns *which* pages are
shared and *when* they die):

    lookup   admission walks the prompt's chain; every hit page is
             ``acquire``d into the slot's block table (ref += 1) and the
             slot starts chunk-streaming at the first miss. Hits are
             capped at ``(P - 1) // page_size`` pages: the last prompt
             token is always recomputed, because its logits seed
             generation and a fully-cached prompt would otherwise never
             produce them.
    promote  as a miss request streams its prompt, each page that fills
             completely is promoted from private to shared (ref = 1, the
             streamer keeps its reference) and registered under its chain
             digest — the next request with this prefix hits it.
    release  retiring (or quarantining) a slot decrefs its referenced
             pages; ref==0 pages stay cached — they ARE the cache — in
             LRU order.
    evict    under allocation pressure, ref==0 pages are evicted
             least-recently-used back to the free list. Referenced pages
             are pinned (a running request maps them); evicting a
             mid-chain page merely truncates future lookups at that
             point — descendants keep their entries and become reachable
             again if the prefix is ever re-promoted.

Quarantine leaks only *private* pages (see `PageAllocator.leak_slot`):
shared pages are immutable and fully written before promotion, so a dead
row holding a reference is no more dangerous than a live one.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Sequence

from .paged import PageAllocator

__all__ = ["PrefixCache", "page_digest"]

_ROOT = b"raceit-prefix-root"


def page_digest(prev: bytes, tokens: Sequence[int]) -> bytes:
    """Chain digest of one page: H(prev | token bytes).

    Token values ride as their decimal repr joined with separators —
    unambiguous (no width assumptions on the vocab) and host-side only,
    so the cost is per admitted page, never per step.
    """
    h = hashlib.blake2b(prev, digest_size=16)
    h.update(",".join(str(int(t)) for t in tokens).encode())
    return h.digest()


class PrefixCache:
    """digest -> shared physical page, in LRU order, over ``allocator``.

    The cache never allocates pages itself: promotion re-labels pages a
    streaming request already owns, so cache capacity is bounded by the
    pool and eviction is only ever *back* to the free list. All state is
    host-side Python (the device sees only block tables).
    """

    def __init__(self, allocator: PageAllocator, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size={page_size} must be >= 1")
        self.allocator = allocator
        self.page_size = int(page_size)
        # LRU: most-recently-used at the end; hits and promotions both
        # refresh recency (move_to_end / append)
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()
        # counters for serve/metrics + the bench rows
        self.hit_pages = 0      # pages mapped from cache at admission
        self.miss_pages = 0     # full prompt pages that had to stream
        self.hit_requests = 0   # admissions with >= 1 hit page
        self.lookups = 0        # admissions consulted
        self.promotions = 0
        self.evictions = 0

    # -------------------------------------------------------------- queries
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, digest: bytes) -> bool:
        return digest in self._entries

    @property
    def pages_saved(self) -> int:
        """Prompt pages served from cache instead of streamed (running
        total — the bench's pages-saved counter)."""
        return self.hit_pages

    def n_evictable(self, pinned: frozenset = frozenset()) -> int:
        """Shared pages at ref==0 (minus ``pinned``) — the headroom the
        deadlock check adds to the free list."""
        return sum(1 for p in self._entries.values()
                   if p not in pinned and self.allocator.shared_ref(p) == 0)

    # -------------------------------------------------------------- lookup
    def match(self, prompt: Sequence[int]) -> tuple[list, bytes, int]:
        """Walk the prompt's hash chain; returns (hit entries, last
        digest, tokens covered) with hit entries as (digest, page) pairs.

        Pure: touches neither refcounts nor LRU order nor counters — an
        admission attempt can be retried under page-pool backpressure
        without skewing stats or recency. The caller ``commit``s the hit
        once its private-page allocation succeeded (and only then
        ``acquire``s the pages). The returned digest is the chain value
        *after* the last hit page — the streaming slot continues
        promotion from it.
        """
        ps = self.page_size
        max_hit = (len(prompt) - 1) // ps  # last token always recomputed
        hits: list[tuple[bytes, int]] = []
        digest = _ROOT
        for i in range(max_hit):
            nxt = page_digest(digest, prompt[i * ps:(i + 1) * ps])
            page = self._entries.get(nxt)
            if page is None:
                break
            hits.append((nxt, page))
            digest = nxt
        return hits, digest, len(hits) * ps

    def commit(self, hits: list, n_full_pages: int) -> None:
        """Record a committed admission: refresh the hit run's LRU
        recency and the hit/miss counters (``n_full_pages`` is the
        prompt's full-page count, so misses = full - hits)."""
        self.lookups += 1
        for digest, _ in hits:
            self._entries.move_to_end(digest)
        self.hit_pages += len(hits)
        self.miss_pages += n_full_pages - len(hits)
        self.hit_requests += bool(hits)

    # ----------------------------------------------------------- promotion
    def promote(self, slot: int, page: int, digest: bytes,
                tokens: Sequence[int]) -> tuple[bool, bytes]:
        """Register a fully-streamed prompt page under its chain digest.

        Returns (promoted, next digest). A digest that is already cached
        (a concurrent request streamed the same prefix first) is left
        alone and ``promoted`` is False — the caller's page stays private
        and its promotion walk must STOP there: promoting a *later* page
        would interleave private and shared pages in the block-table row,
        breaking the refs-then-owned row order the allocator maintains.
        """
        nxt = page_digest(digest, tokens)
        if nxt in self._entries:
            return False, nxt
        self.allocator.promote(slot, page)
        self._entries[nxt] = page
        self.promotions += 1
        return True, nxt

    # ------------------------------------------------------------ eviction
    def evict(self, n: int, pinned: frozenset = frozenset()) -> int:
        """Evict up to ``n`` ref==0 pages, least-recently-used first,
        back to the allocator's free list; returns the number evicted.
        ``pinned`` pages (an in-flight admission's hit run) are skipped.
        """
        if n <= 0:
            return 0
        victims = [d for d, p in self._entries.items()
                   if p not in pinned and self.allocator.shared_ref(p) == 0]
        evicted = 0
        for digest in victims[:n]:
            page = self._entries.pop(digest)
            self.allocator.evict_shared(page)
            evicted += 1
        self.evictions += evicted
        return evicted

    # --------------------------------------------------------------- stats
    def stats(self) -> dict:
        total = self.hit_pages + self.miss_pages
        return {
            "prefix_entries": len(self._entries),
            "prefix_lookups": self.lookups,
            "prefix_hit_requests": self.hit_requests,
            "prefix_hit_pages": self.hit_pages,
            "prefix_miss_pages": self.miss_pages,
            "prefix_hit_rate_pct": (100.0 * self.hit_pages / total
                                    if total else 0.0),
            "prefix_pages_saved": self.pages_saved,
            "prefix_promotions": self.promotions,
            "prefix_evictions": self.evictions,
        }
