"""Host-side page bookkeeping for block-paged KV serving.

`PageAllocator` owns the physical pages of the pool cache built by
`Model.init_slot_cache(page_size=..., n_pages=...)`. It is plain Python
over numpy — page assignment is a *scheduling* decision, made once per
admission on the host, so none of this touches a traced value: the device
only ever sees the resulting (n_slots, max_pages) block-table array.

Invariants (checked by `assert_invariants`, and asserted after every step
by the property suite in tests/test_serve_paged.py):

* page 0 is the trash page — never owned, never free, never issued;
* every physical page is in exactly one of three sets: the free list, one
  slot's owned list, or the leaked set;
* leaked pages (quarantined slots — see `ContinuousBatcher`) are never
  re-issued: a decode-fault map is static per executable, so a slot row
  that faulted once will fault every step, and handing its pages to a new
  request would couple the new request's cache to a dead row's writes.

Allocation is whole-request and up-front: `ContinuousBatcher` reserves
every page a request can ever need (prompt + n_new - 1 tokens) at
admission, so a running request can never stall mid-stream waiting for a
page — backpressure happens at admission time, where the request can
simply stay queued.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["PageAllocator"]


class PageAllocator:
    """Free-list allocator over physical pages [1, n_pages).

    ``alloc(slot, n)`` hands ``n`` pages to ``slot`` (returns None without
    side effects when fewer than ``n`` are free); ``free_slot`` returns a
    slot's pages to the free list (normal retire); ``leak_slot`` drops
    them permanently (quarantine).
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"n_pages={n_pages}: need at least the trash "
                             f"page plus one allocatable page")
        self.n_pages = n_pages
        # LIFO free list: recently-freed pages are re-issued first, which
        # maximizes page shuffling across a trace — exactly the property
        # the paged kernels' permutation-invariance tests feed on
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}
        self._leaked: set[int] = set()

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_leaked(self) -> int:
        return len(self._leaked)

    @property
    def pages_in_use(self) -> int:
        """Pages currently owned by live slots (excludes trash + leaked)."""
        return sum(len(p) for p in self._owned.values())

    def owned(self, slot: int) -> list[int]:
        return list(self._owned.get(slot, ()))

    # ------------------------------------------------------- state changes
    def alloc(self, slot: int, n: int) -> Optional[list[int]]:
        """Reserve ``n`` pages for ``slot``; None (no side effects) when
        the free list is short — the caller's backpressure signal."""
        if slot in self._owned:
            raise ValueError(f"slot {slot} already owns pages; free or "
                             f"leak it before re-admitting")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned[slot] = pages
        return list(pages)

    def free_slot(self, slot: int) -> None:
        """Normal retire: the slot's pages return to the free list."""
        self._free.extend(self._owned.pop(slot, ()))

    def leak_slot(self, slot: int) -> None:
        """Quarantine retire: the slot's pages leave the economy for good.
        The dead row keeps faulting every call; its writes are fenced to
        the trash page by per-call block tables, but re-issuing pages a
        dead row has addressed means one missed fence corrupts a live
        request — cheap insurance on an already-degraded pool."""
        self._leaked.update(self._owned.pop(slot, ()))

    # ---------------------------------------------------------- invariants
    def assert_invariants(self) -> None:
        """Every page in exactly one of {free, owned-by-one-slot, leaked};
        page 0 in none of them."""
        seen: dict[int, str] = {}

        def claim(page: int, owner: str) -> None:
            if page == 0:
                raise AssertionError(f"trash page 0 appears in {owner}")
            if not 0 < page < self.n_pages:
                raise AssertionError(f"page {page} out of range in {owner}")
            if page in seen:
                raise AssertionError(
                    f"page {page} double-held: {seen[page]} and {owner}")
            seen[page] = owner

        for p in self._free:
            claim(p, "free")
        for slot, pages in self._owned.items():
            for p in pages:
                claim(p, f"slot {slot}")
        for p in self._leaked:
            claim(p, "leaked")
        if len(seen) != self.n_pages - 1:
            missing = set(range(1, self.n_pages)) - set(seen)
            raise AssertionError(f"pages lost from the economy: {missing}")
