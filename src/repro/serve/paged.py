"""Host-side page bookkeeping for block-paged KV serving.

`PageAllocator` owns the physical pages of the pool cache built by
`Model.init_slot_cache(page_size=..., n_pages=...)`. It is plain Python
over numpy — page assignment is a *scheduling* decision, made once per
admission on the host, so none of this touches a traced value: the device
only ever sees the resulting (n_slots, max_pages) block-table array.

Pages live in exactly one of **four** states (the refcount-aware pool
partition, checked by `assert_invariants` and asserted after every step by
the property suite in tests/test_serve_paged.py):

* **free**    — on the free list, issuable;
* **private** — owned by exactly one slot, writable by that slot's row
  (``alloc`` hands them out, ``free_slot`` returns them);
* **shared**  — immutable, content-addressed prompt pages owned by the
  prefix cache (`repro.serve.prefix.PrefixCache`) and *referenced* by any
  number of slots through per-slot refcounts: ``promote`` turns a slot's
  fully-streamed private prompt page into a shared one (the promoting
  slot keeps a reference), ``acquire`` adds a reference on a prefix-cache
  hit, retiring a slot releases its references, and a ref==0 shared page
  is evictable back to the free list (``evict_shared``) but never freed
  implicitly — it *is* the prefix cache's storage;
* **leaked**  — dropped permanently by slot quarantine; never re-issued.

So: ``free + leaked + Σ private + shared = n_pages - 1`` (page 0 is the
trash page — never owned, never free, never issued).

Allocation is whole-request and up-front: `ContinuousBatcher` reserves
every page a request can ever need (prompt + n_new - 1 tokens, minus the
prefix-cache hit pages it only references) at admission, so a running
request can never stall mid-stream waiting for a page — backpressure
happens at admission time, where the request can simply stay queued.

Quarantine (``leak_slot``) leaks only *private* pages: a dead row may
still address them and a missed write fence would corrupt a re-issued
page. Shared pages are merely *released* (decref) — they are immutable,
every writer finished before promotion, and live readers keep them mapped
regardless, so leaking them would shrink the pool without protecting
anyone.
"""
from __future__ import annotations

from typing import Optional

__all__ = ["PageAllocator"]


class PageAllocator:
    """Free-list allocator over physical pages [1, n_pages).

    ``alloc(slot, n)`` hands ``n`` private pages to ``slot`` (returns None
    without side effects when fewer than ``n`` are free); ``free_slot``
    returns a slot's private pages to the free list and releases its
    shared references (normal retire); ``leak_slot`` drops the private
    pages permanently and releases the shared references (quarantine).
    ``promote``/``acquire``/``evict_shared`` are the prefix-cache
    transitions — see the module docstring for the page-state diagram.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"n_pages={n_pages}: need at least the trash "
                             f"page plus one allocatable page")
        self.n_pages = n_pages
        # LIFO free list: recently-freed pages are re-issued first, which
        # maximizes page shuffling across a trace — exactly the property
        # the paged kernels' permutation-invariance tests feed on
        self._free: list[int] = list(range(n_pages - 1, 0, -1))
        self._owned: dict[int, list[int]] = {}
        self._leaked: set[int] = set()
        # shared (immutable, prefix-cache-owned) pages: page -> refcount,
        # plus the per-slot reference lists that back free/leak release.
        # A page can be referenced at most once per slot (a block-table
        # row maps each logical page exactly once).
        self._shared: dict[int, int] = {}
        self._refs: dict[int, list[int]] = {}
        self.peak_in_use = 0  # max(private + shared) over the run

    # ------------------------------------------------------------- queries
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_leaked(self) -> int:
        return len(self._leaked)

    @property
    def n_shared(self) -> int:
        """Shared (prefix-cache-owned) pages, referenced or not."""
        return len(self._shared)

    @property
    def pages_in_use(self) -> int:
        """Pages currently owned by live slots (excludes trash, shared
        and leaked — the *private* term of the pool partition)."""
        return sum(len(p) for p in self._owned.values())

    def owned(self, slot: int) -> list[int]:
        return list(self._owned.get(slot, ()))

    def refs(self, slot: int) -> list[int]:
        """Shared pages referenced by ``slot``, in block-table order."""
        return list(self._refs.get(slot, ()))

    def shared_ref(self, page: int) -> int:
        """Refcount of a shared page (KeyError when not shared)."""
        return self._shared[page]

    def is_shared(self, page: int) -> bool:
        return page in self._shared

    # ------------------------------------------------------- state changes
    def _note_peak(self) -> None:
        in_use = self.pages_in_use + len(self._shared)
        if in_use > self.peak_in_use:
            self.peak_in_use = in_use

    def alloc(self, slot: int, n: int) -> Optional[list[int]]:
        """Reserve ``n`` private pages for ``slot``; None (no side
        effects) when the free list is short — the caller's backpressure
        signal. ``n == 0`` is a valid whole-request reservation (a full
        prefix-cache hit needs no private pages)."""
        if slot in self._owned or slot in self._refs:
            raise ValueError(f"slot {slot} already holds pages; free or "
                             f"leak it before re-admitting")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._owned[slot] = pages
        self._note_peak()
        return list(pages)

    def promote(self, slot: int, page: int) -> None:
        """Move one of ``slot``'s private pages into the shared set.

        The page becomes immutable prefix-cache storage; the promoting
        slot keeps using it, so it starts at refcount 1 and joins the
        slot's reference list. Prompt pages are promoted in prefix order,
        and hit pages always precede private pages in a block-table row,
        so a slot's row is always ``refs(slot) + owned(slot)``.
        """
        held = self._owned.get(slot, [])
        if not held or held[0] != page:
            raise ValueError(
                f"slot {slot} cannot promote page {page}: promotion walks "
                f"the block-table row in order, so the page must be the "
                f"slot's first private page (held: {held[:3]}...)")
        if page in self._shared:
            raise ValueError(f"page {page} is already shared")
        held.pop(0)
        if not held:
            del self._owned[slot]
        self._shared[page] = 1
        self._refs.setdefault(slot, []).append(page)

    def acquire(self, slot: int, page: int) -> None:
        """Add ``slot``'s reference to a shared page (prefix-cache hit)."""
        if page not in self._shared:
            raise ValueError(f"page {page} is not shared")
        self._shared[page] += 1
        self._refs.setdefault(slot, []).append(page)

    def release_refs(self, slot: int) -> None:
        """Drop every shared reference ``slot`` holds (the pages stay
        shared at their remaining refcount — possibly 0, i.e. evictable)."""
        for page in self._refs.pop(slot, ()):
            self._shared[page] -= 1

    def evict_shared(self, page: int) -> None:
        """Return a ref==0 shared page to the free list (prefix-cache
        LRU eviction). Refusing referenced pages keeps a running hit
        request's mapped pages pinned."""
        if self._shared.get(page, None) != 0:
            raise ValueError(f"page {page} is not an evictable shared page "
                             f"(ref={self._shared.get(page)!r})")
        del self._shared[page]
        self._free.append(page)

    def free_slot(self, slot: int) -> None:
        """Normal retire: private pages return to the free list, shared
        references are released."""
        self._free.extend(self._owned.pop(slot, ()))
        self.release_refs(slot)

    def leak_slot(self, slot: int) -> None:
        """Quarantine retire: the slot's *private* pages leave the economy
        for good. The dead row keeps faulting every call; its writes are
        fenced to the trash page by per-call block tables, but re-issuing
        pages a dead row has addressed means one missed fence corrupts a
        live request — cheap insurance on an already-degraded pool.
        Shared references are only released: those pages are immutable,
        fully written before promotion, and other live rows keep reading
        them either way (leaking them protects nobody)."""
        self._leaked.update(self._owned.pop(slot, ()))
        self.release_refs(slot)

    # ---------------------------------------------------------- invariants
    def assert_invariants(self) -> None:
        """Every page in exactly one of {free, private-owned-by-one-slot,
        shared, leaked}; page 0 in none of them; shared refcounts equal
        the per-slot reference lists exactly."""
        seen: dict[int, str] = {}

        def claim(page: int, owner: str) -> None:
            if page == 0:
                raise AssertionError(f"trash page 0 appears in {owner}")
            if not 0 < page < self.n_pages:
                raise AssertionError(f"page {page} out of range in {owner}")
            if page in seen:
                raise AssertionError(
                    f"page {page} double-held: {seen[page]} and {owner}")
            seen[page] = owner

        for p in self._free:
            claim(p, "free")
        for slot, pages in self._owned.items():
            for p in pages:
                claim(p, f"slot {slot}")
        for p in self._shared:
            claim(p, "shared")
        for p in self._leaked:
            claim(p, "leaked")
        if len(seen) != self.n_pages - 1:
            missing = set(range(1, self.n_pages)) - set(seen)
            raise AssertionError(f"pages lost from the economy: {missing}")
        counts: dict[int, int] = {}
        for slot, pages in self._refs.items():
            for p in pages:
                if p not in self._shared:
                    raise AssertionError(
                        f"slot {slot} references non-shared page {p}")
                if pages.count(p) != 1:
                    raise AssertionError(
                        f"slot {slot} references page {p} twice")
                counts[p] = counts.get(p, 0) + 1
        for p, ref in self._shared.items():
            if ref != counts.get(p, 0):
                raise AssertionError(
                    f"shared page {p} refcount {ref} != "
                    f"{counts.get(p, 0)} slot references")
            if ref < 0:
                raise AssertionError(f"shared page {p} refcount {ref} < 0")
