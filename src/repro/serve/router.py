"""Tenant-aware admission routing in front of the continuous batcher.

`AdmissionRouter` replaces `ContinuousBatcher`'s plain FIFO deque: it
holds the queued `Request`s per tenant and decides which one the next
admission (`_admit` / `_admit_paged`) sees. It exposes the same surface
the batcher already consumed — truthiness, ``len``, iteration,
``router[0]`` (peek) and ``popleft()`` — so every existing drain loop and
backpressure path works unchanged; only the *identity* of the head is now
policy-driven.

Policies (``policy=``):

    fifo       global arrival order, tenant-blind (the PR-7 behaviour);
    priority   strict priority by tenant weight (higher weight first),
               FIFO within a weight class — a starving low-priority
               tenant is the *documented* behaviour of this policy;
    wfq        weighted-fair queuing via deficit round-robin on a token
               budget: each tenant accrues ``quantum * weight`` tokens of
               deficit whenever the round-robin pointer passes it by, and
               is selected once its deficit covers its head request's
               cost (``len(prompt) + n_new`` tokens). Every pass over the
               ring tops up every waiting tenant, so no tenant starves,
               and long-run admitted tokens are proportional to weights
               while tenants stay backlogged.

The chosen head *blocks*: if the batcher cannot admit it (page-pool
backpressure), admission stops for the step and the same head is offered
next step. Skipping to a smaller request would silently starve the
chosen tenant — exactly what the policy exists to prevent — so the
backpressure semantics of PR 7's FIFO queue carry over per-policy.

Per-tenant queue-depth caps (``max_queue_per_tenant``) reject *at
submit*: ``push`` returns a structured
`RequestError(stage="admit")` instead of raising, and the batcher
retires the request with that error — operational overload is data, not
an exception (malformed requests still raise ValueError at ``submit``).
"""
from __future__ import annotations

from collections import deque
from typing import Iterator, Optional

from .batching import Request, RequestError

__all__ = ["AdmissionRouter", "POLICIES"]

POLICIES = ("fifo", "priority", "wfq")


def request_cost(req: Request) -> int:
    """Token budget a request admits: prompt plus every generated token.

    This is the deficit-round-robin currency — proportional to the page
    reservation (and so to KV footprint and decode-step occupancy), which
    is the resource tenants actually contend for.
    """
    return len(req.prompt) + req.n_new


class AdmissionRouter:
    """Policy-routed multi-tenant admission queue (deque-compatible).

    ``weights`` maps tenant name -> weight (default 1.0): wfq shares
    admitted tokens proportionally; priority treats the weight as a
    strict priority level. Unknown tenants get weight 1.0 — a tenant
    exists the moment a request names it.
    """

    def __init__(self, policy: str = "fifo",
                 weights: Optional[dict] = None,
                 max_queue_per_tenant: Optional[int] = None,
                 quantum: float = 32.0):
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {POLICIES}")
        if quantum <= 0:
            raise ValueError(f"quantum={quantum} must be > 0")
        if max_queue_per_tenant is not None and max_queue_per_tenant < 1:
            raise ValueError(f"max_queue_per_tenant={max_queue_per_tenant} "
                             f"must be >= 1 (or None for uncapped)")
        self.policy = policy
        self.weights = dict(weights or {})
        self.cap = max_queue_per_tenant
        self.quantum = float(quantum)
        self._queues: dict[str, deque] = {}
        self._ring: list[str] = []      # tenant round-robin ring (wfq)
        self._rr = 0                    # ring pointer
        self._topped = False            # pointer tenant got its per-visit
                                        # quantum already (DRR tops up once
                                        # per ARRIVAL of the pointer, not
                                        # once per reconsideration)
        self._deficit: dict[str, float] = {}
        self._seq = 0                   # global arrival counter
        self._choice: Optional[str] = None  # memoized chosen tenant
        self.rejected = 0               # depth-cap rejections (stats)

    # ------------------------------------------------------------ plumbing
    def weight(self, tenant: str) -> float:
        return float(self.weights.get(tenant, 1.0))

    def depths(self) -> dict[str, int]:
        """Queued requests per tenant (stats/reporting)."""
        return {t: len(q) for t, q in self._queues.items() if q}

    def __len__(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def __bool__(self) -> bool:
        return any(self._queues.values())

    def __iter__(self) -> Iterator[Request]:
        """All queued requests in arrival order (feasibility scans —
        `ContinuousBatcher._lock_prefill_len` — not service order)."""
        entries = [e for q in self._queues.values() for e in q]
        return iter(r for _, r in sorted(entries, key=lambda e: e[0]))

    def __getitem__(self, idx: int) -> Request:
        if idx != 0:
            raise IndexError("AdmissionRouter exposes only the policy head "
                             "([0]); iterate for the full queue")
        head = self.peek()
        if head is None:
            raise IndexError("peek from an empty router")
        return head

    # ------------------------------------------------------------- ingress
    def push(self, req: Request) -> Optional[RequestError]:
        """Enqueue; returns a structured rejection (None = accepted).

        Depth-cap rejections are operational backpressure, not errors in
        the program: the caller attaches the record to ``req.error`` and
        retires it, and the submitting tenant sees a typed admit-stage
        failure naming its own queue depth.
        """
        tenant = req.tenant
        q = self._queues.get(tenant)
        if q is None:
            q = self._queues[tenant] = deque()
            self._ring.append(tenant)
            self._deficit.setdefault(tenant, 0.0)
        if self.cap is not None and len(q) >= self.cap:
            self.rejected += 1
            return RequestError(
                rid=req.rid, stage="admit", step=0,
                reason=f"tenant {tenant!r} queue depth cap "
                       f"({self.cap}) reached")
        q.append((self._seq, req))
        self._seq += 1
        return None

    # ------------------------------------------------------------- egress
    def _select(self) -> Optional[str]:
        """Pick the tenant whose head serves next.

        fifo/priority are pure functions of the queues (a later
        high-priority arrival preempts an un-popped head); wfq memoizes
        its choice so peek and pop agree without double-charging deficits.
        """
        heads = {t: q[0] for t, q in self._queues.items() if q}
        if not heads:
            self._choice = None
            return None
        if self.policy == "fifo":
            return min(heads, key=lambda t: heads[t][0])
        if self.policy == "priority":
            # strict: highest weight wins, arrival order within a class
            return min(heads,
                       key=lambda t: (-self.weight(t), heads[t][0]))
        if self._choice is not None and self._queues.get(self._choice):
            return self._choice
        # wfq: deficit round-robin over the tenant ring. When the pointer
        # ARRIVES at a waiting tenant it receives one quantum * weight
        # top-up; it then serves requests (pointer parked, no further
        # top-up) until its deficit no longer covers its head's cost, at
        # which point the pointer moves on. Each full ring pass tops every
        # waiting tenant up once, so the loop terminates and nobody
        # starves, while long-run service tracks the weights.
        self._choice = None
        while self._choice is None:
            tenant = self._ring[self._rr % len(self._ring)]
            entry = heads.get(tenant)
            if entry is not None:
                if not self._topped:
                    self._deficit[tenant] += self.quantum * self.weight(tenant)
                    self._topped = True
                if self._deficit[tenant] >= request_cost(entry[1]):
                    self._choice = tenant
                    break
            self._rr = (self._rr + 1) % len(self._ring)
            self._topped = False
        return self._choice

    def peek(self) -> Optional[Request]:
        """The request the policy serves next (stable until popped)."""
        tenant = self._select()
        return self._queues[tenant][0][1] if tenant is not None else None

    def popleft(self) -> Request:
        """Commit the memoized head (the one ``peek``/``[0]`` showed)."""
        tenant = self._select()
        if tenant is None:
            raise IndexError("pop from an empty router")
        _, req = self._queues[tenant].popleft()
        if self.policy == "wfq":
            self._deficit[tenant] -= request_cost(req)
            if not self._queues[tenant]:
                # classic DRR: an emptied queue forfeits leftover deficit
                # (banking it would let an idle tenant burst later)
                self._deficit[tenant] = 0.0
        self._choice = None
        return req
