"""Pallas TPU kernel: bit-sliced crossbar MVM with Compute-ACAM ADCs.

The DPE lane (paper §II-A/IV-A) adapted to the TPU memory hierarchy:

* HBM -> VMEM tiling via BlockSpec: (bm x bk) x-tiles and (bk x bn) w-tiles,
  grid (M/bm, N/bn, K/bk) with an int32 VMEM accumulator revisited over k.
* Inside a tile, the ISAAC-style offset-encoded operands are spatially sliced
  (cell_bits-wide weight planes) and temporally sliced (dac_bits input
  pulses); every plane product is an int MXU matmul, digitized by the ADC
  transfer and consolidated with shift-&-add — bit-identical to the analog
  pipeline with an ideal converter.
* ``exact`` mode folds all planes into one int8xint8->int32 MXU matmul (the
  mathematically-equal fast path used by the serving stack); tests assert the
  sliced and exact paths agree and match the pure-jnp oracle (ref.py).

bk defaults to the crossbar height (128 rows) so one k-step == one crossbar.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.crossbar import CrossbarConfig

from .runtime import resolve_interpret


def _mvm_kernel(x_ref, w_ref, o_ref, acc_ref, *, cfg: CrossbarConfig,
                nsteps: int, k_real: int, bk: int):
    """One (bm x bk) @ (bk x bn) tile-product with bit-slicing + ADC."""
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ox = 1 << (cfg.input_bits - 1)
    ow = 1 << (cfg.weight_bits - 1)
    xu = x_ref[...].astype(jnp.int32) + ox  # offset encoding (ISAAC)
    wu = w_ref[...].astype(jnp.int32) + ow
    # zero out the offset on padded K rows so they contribute nothing
    kpos = k_step * bk + jax.lax.broadcasted_iota(jnp.int32, xu.shape, 1)
    xu = jnp.where(kpos < k_real, xu, 0)
    kposw = k_step * bk + jax.lax.broadcasted_iota(jnp.int32, wu.shape, 0)
    wu = jnp.where(kposw < k_real, wu, 0)

    dac_mask = (1 << cfg.dac_bits) - 1
    cell_mask = (1 << cfg.cell_bits) - 1
    p_max = cfg.rows * cell_mask * dac_mask
    levels = (1 << cfg.adc_bits) - 1

    acc = jnp.zeros(o_ref.shape, jnp.int32)
    if cfg.adc_mode == "quantize" and p_max > levels:
        step = p_max / levels
        for t in range(cfg.num_input_slices):      # temporal input slices
            x_t = (xu >> (t * cfg.dac_bits)) & dac_mask
            for s in range(cfg.num_weight_slices):  # spatial weight slices
                w_s = (wu >> (s * cfg.cell_bits)) & cell_mask
                p = jax.lax.dot(x_t, w_s, preferred_element_type=jnp.int32)
                q = jnp.round(jnp.round(p / step) * step).astype(jnp.int32)
                acc += q << (t * cfg.dac_bits + s * cfg.cell_bits)
    else:
        # exact ADC: the shift-&-add over planes telescopes to one int matmul
        acc = jax.lax.dot(xu, wu, preferred_element_type=jnp.int32)

    # digital offset corrections (ones-column row-sum / precomputed col-sum)
    rowsum = xu.sum(axis=1, keepdims=True)
    colsum = wu.sum(axis=0, keepdims=True)
    acc = acc - ow * rowsum - ox * colsum
    acc_ref[...] += acc

    @pl.when(k_step == nsteps - 1)
    def _finish():
        o_ref[...] = acc_ref[...] + k_real * ox * ow


@functools.partial(jax.jit, static_argnames=("cfg", "bm", "bn", "bk", "interpret"))
def acam_mvm(x: jax.Array, w: jax.Array, cfg: CrossbarConfig = CrossbarConfig(),
             bm: int = 256, bn: int = 256, bk: int | None = None,
             interpret: bool | None = None) -> jax.Array:
    """Bit-sliced crossbar matmul: x (M, K) int8 codes, w (K, N) int8 codes
    -> (M, N) int32, equal to x @ w under an ideal ADC."""
    interpret = resolve_interpret(interpret)
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bk = bk or cfg.rows
    bm = min(bm, max(8, M))
    bn = min(bn, max(128, N))
    pm, pn, pk = (-M) % bm, (-N) % bn, (-K) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(w, ((0, pk), (0, pn)))
    Mp, Kp = xp.shape
    Np = wp.shape[1]
    nsteps = Kp // bk

    out = pl.pallas_call(
        functools.partial(_mvm_kernel, cfg=cfg, nsteps=nsteps, k_real=K, bk=bk),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        grid=(Mp // bm, Np // bn, nsteps),
        interpret=interpret,
    )(xp, wp)
    return out[:M, :N]
