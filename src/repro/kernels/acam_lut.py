"""Pallas TPU kernel: Compute-ACAM 1-variable op as a vectorized 256-entry LUT.

Hardware adaptation (DESIGN.md §2): an ACAM array's OR-of-ranges per output
bit is provably equivalent to a 2^n-entry table, so the TPU-native form of an
8-bit Compute-ACAM op is a 256-entry lookup over int8 codes. The kernel biases
two's-complement codes to unsigned positions and gathers from a VMEM-resident
table; on TPU the gather vectorizes on the VPU (or lowers to a one-hot matmul
on the MXU for very wide tiles). Tiles are (block_rows x 128)-aligned so the
lane dimension matches the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .runtime import resolve_interpret

DEFAULT_BLOCK_ROWS = 256
LANES = 128


def _lut_kernel(x_ref, lut_ref, o_ref, *, bias: int):
    x = x_ref[...].astype(jnp.int32) + bias  # codes -> unsigned positions
    o_ref[...] = lut_ref[x].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bias", "block_rows", "interpret"))
def acam_lut_2d(x: jax.Array, lut: jax.Array, bias: int = 128,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool | None = None) -> jax.Array:
    """Apply an ACAM LUT to a 2-D int tensor of shape (R, C).

    x: int8/int32 codes in [-2^(n-1), 2^(n-1)); lut: (2^n,) output codes.
    Rows/cols are padded to tile boundaries and cropped after.
    """
    interpret = resolve_interpret(interpret)
    R, C = x.shape
    br = min(block_rows, max(8, R))
    pad_r = (-R) % br
    pad_c = (-C) % LANES
    xp = jnp.pad(x, ((0, pad_r), (0, pad_c)))
    Rp, Cp = xp.shape

    out = pl.pallas_call(
        functools.partial(_lut_kernel, bias=bias),
        out_shape=jax.ShapeDtypeStruct((Rp, Cp), jnp.int32),
        in_specs=[
            pl.BlockSpec((br, Cp), lambda i: (i, 0)),
            pl.BlockSpec((lut.shape[0],), lambda i: (0,)),  # table in VMEM
        ],
        out_specs=pl.BlockSpec((br, Cp), lambda i: (i, 0)),
        grid=(Rp // br,),
        interpret=interpret,
    )(xp, lut.astype(jnp.int32))
    return out[:R, :C]


def acam_lut(x: jax.Array, lut: jax.Array, bias: int = 128,
             interpret: bool | None = None) -> jax.Array:
    """N-D wrapper: flatten leading dims to rows."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1]) if x.ndim >= 2 else x.reshape(1, -1)
    y = acam_lut_2d(flat, lut, bias=bias, interpret=interpret)
    return y.reshape(shape)
