"""Pallas TPU kernel: the fused streaming RACE-IT attention pipeline (Fig. 12).

The staged reference (`repro.core.attention.raceit_attention`) runs the five
Fig.-12 stages as separate XLA ops, materializing the full (Sq, Sk) logit and
probability matrices in HBM and re-quantizing between every stage. This
kernel executes the whole pipeline per (head-block x row-block x key-block)
tile in VMEM, flash-attention style, so the (Sq, Sk) intermediates never
exist:

  matmul-1   int8 q . K^T, batched over the head block, on the MXU
  div-add    scale by s_q s_k / sqrt(d), additive mask -> LOGIT codes
  softmax    the Fig. 8 exp/log LUT dataflow, evaluated *online*: the PoT
             row-sum streams over key blocks, and LOG(S) is applied lazily
  matmul-2   PROB codes . V accumulated in an int32 VMEM scratch

The ACAM softmax has no running-max rescale (d_i = x_i - LOG(S) needs only
the final row sum), but the oracle's PROB re-quantization uses the *global*
probability max. The kernel therefore makes two passes over the key stream
(grid dim 0 is the pass):

  pass A  per row: accumulate S = sum EXP(x) and the row logit max; at the
          last key block fold them into LOG(S) and the row's max PROB code,
          reducing a global cmax in SMEM (the tensor-wide quantizer scale).
  pass B  recompute the tile's logit codes, finish d = x - LOG(S)<<1 ->
          PROB codes, re-quantize with the global cmax exactly like
          `quantize_tensor`, and accumulate codes . V on the MXU.

Pass A/B recompute matmul-1 twice — the same flops-for-memory trade as
flash attention's backward — except when the whole problem fits one tile,
where the kernel collapses to a single grid step with the logit codes live
in registers. Heads ride inside the block (bg of them per tile) because grid
steps, not flops, dominate interpret-mode latency; on a real TPU the same
knob bounds VMEM instead. Every arithmetic step replicates the oracle's
f32 op sequence, so outputs are bit-identical to the staged path up to
float summation order of the PoT row sum (asserted to <= 1 PROB ulp in
tests, and observed exact on every shape exercised there).

Two entry points share the kernel bodies:

* `acam_attention_codes`   — prefill/forward: (G, Sq, D) queries, optional
  mask / in-kernel causal offset;
* `acam_attention_decode_codes` — serving decode: Sq=1 queries against a
  fixed-shape KV cache whose valid prefix length ``kv_len`` is a *traced*
  scalar — or, for per-request serving, a *per-group vector* (one length
  per grid group) — ridden in as a scalar-prefetch operand: key blocks
  fully past the fill level are skipped outright (clamped index maps +
  gated compute), and only the partially valid boundary block is masked —
  instead of slicing the buffer (dynamic shapes) or sweeping it whole.
  With a vector ``kv_len`` the skip bound is per *group tile* (the max
  length of the ``bg`` groups riding the tile, prefetched as a second
  scalar operand), so a short request in a mixed batch stops streaming at
  its own fill level instead of the batch max;
* `acam_attention_decode_gqa_codes` — GQA-native serving decode: k/v stay
  in their native (B*KV, Smax, hd) cache layout and the ``rep = H/KV``
  query heads that share a KV head ride the *row* dimension of one tile,
  so the grid's group dimension iterates B*KV groups (not B*H) and each
  KV tile is fetched once per head group instead of once per query head —
  the ``jnp.repeat`` of int8 cache codes disappears from the decode hot
  loop along with rep x of its cache-read traffic. Same scalar-prefetched
  ``kv_len`` machinery (clamped index maps + `guard_live` gating).

Every entry additionally accepts a **block-paged** k/v layout: instead of
one contiguous (Smax,) stripe per group, keys live in a pool of fixed-size
pages — k/v arrive as ``(n_pages * groups_per_slot, page_size, D)`` and a
per-slot ``block_table`` maps each slot's logical page index to a physical
pool page. The table rides the same `PrefetchScalarGridSpec` as the kv_len
operands (a third scalar-prefetch arg consumed *only* by the k/v index
maps), so the kernel bodies are untouched: logical key coordinates —
`key_valid`, the per-row frontier clamp, `guard_live` skipping — all work
exactly as in the contiguous layout, and a shuffled block table is
bit-identical to the contiguous stripe because only the DMA source of each
tile moves, never its logical contents or the block visit order.

All entries accept every softmax configuration of the staged path: "pot",
"pot_fine", and the Fig.-14 "uniform" exp-quantization ablation — the LOG
stage always consumes a PoT-encoded row sum, so only the exp gather table
differs per mode (see `softmax_tables`).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import ops as acam_ops
from repro.core.ops import LOGIT_FMT, PROB_FMT
from repro.core.quant import PoTFormat

from .runtime import resolve_interpret

__all__ = ["acam_attention_codes", "acam_attention_decode_codes",
           "acam_attention_decode_gqa_codes", "softmax_tables",
           "FUSED_SOFTMAX_MODES", "DEFAULT_BLOCK_Q", "DEFAULT_BLOCK_K",
           "DEFAULT_BLOCK_G"]

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_G = 8
_LANES = 128

# every softmax configuration the staged acam_softmax accepts; the fused
# kernels cover all of them (core.attention.fused_attention_supported is the
# single dispatchability predicate built on this)
FUSED_SOFTMAX_MODES = ("pot", "pot_fine", "uniform")

_EXP_OPS = {"pot": "exp_pot", "pot_fine": "exp_pot_fine",
            "uniform": "exp_uniform"}
_LOG_OPS = {"pot": "log", "pot_fine": "log_fine", "uniform": "log"}


def softmax_tables(mode: str):
    """(exp_val, log_lut, prob_lut, e_min, octave_step, frac_shift) for a mode.

    ``exp_val`` is the exp LUT pre-composed with its output-format decode into
    one f32 gather table (256 entries), built with the *same jnp ops* as the
    format's ``decode`` so table entries are bit-identical to the staged
    ``acam_softmax``'s step-1 values. ``e_min``/``octave_step`` describe the
    log op's PoT *input* format (the row-sum re-quantization grid) — for
    "pot"/"pot_fine" that coincides with the exp output format; for "uniform"
    the exp output is a uniform `ScaledFormat` but the LOG stage still takes a
    PoT-encoded sum, exactly as in `core.softmax.acam_softmax`.
    """
    if mode not in FUSED_SOFTMAX_MODES:
        raise ValueError(
            f"fused attention softmax_mode must be one of {FUSED_SOFTMAX_MODES},"
            f" got {mode!r}")
    exp_op = acam_ops.get_op(_EXP_OPS[mode])
    log_op = acam_ops.get_op(_LOG_OPS[mode])
    prob_op = acam_ops.get_op("exp_prob")
    ec = jnp.asarray(exp_op._lut, jnp.int32)
    if isinstance(exp_op.out_fmt, PoTFormat):
        step, e0 = exp_op.out_fmt.octave_step, exp_op.out_fmt.e_min
        exp_val = jnp.where(
            ec == 0, 0.0,
            jnp.exp2(jnp.minimum((ec - 1).astype(jnp.float32) * step + e0,
                                 126.0)))
    else:  # uniform ScaledFormat: decode is a plain scale multiply
        exp_val = ec.astype(jnp.float32) * exp_op.out_fmt.scale
    pot_in = log_op.in_fmt
    frac_shift = LOGIT_FMT.frac_bits - log_op.out_fmt.frac_bits
    return (exp_val, log_op._lut, prob_op._lut,
            float(pot_in.e_min), float(pot_in.octave_step), frac_shift)


def _pot_encode_sum(S, e_min: float, octave_step: float):
    """PoT-encode the row sum exactly as `PoTFormat.encode` (f32 op order)."""
    safe = jnp.maximum(S, 2.0 ** (e_min - 1))
    e = jnp.clip(jnp.round((jnp.log2(safe) - e_min) / octave_step), 0, 254)
    codes = (e + 1).astype(jnp.int32)
    return jnp.where(S < 2.0 ** (e_min - octave_step / 2), 0, codes)


def requant_scale(cmax):
    """`quantize_tensor(probs, bits=8).scale` from the max PROB code.

    Probs live on the exact 2^-8 grid, so their tensor max is cmax * 2^-8
    with no rounding; this f32 op sequence is the bit-exactness contract
    with the oracle — it exists only here (kernels and wrappers share it).
    """
    amax = cmax.astype(jnp.float32) * PROB_FMT.scale
    return jnp.maximum(amax, 1e-12) / 127


def _requant_code_table(cmax, prob_lut_vals):
    """PROB-code -> re-quantized int8 code, composed per code (256 entries).

    Elementwise application of a value-wise function commutes with the
    table, so gathering this is bit-identical to quantizing the
    materialized probabilities with `quantize_tensor`.
    """
    p_tab = prob_lut_vals.astype(jnp.float32) * PROB_FMT.scale
    return jnp.clip(jnp.round(p_tab / requant_scale(cmax)),
                    -128, 127).astype(jnp.int32)


def _attn_kernel(kvlen_ref, kvmax_ref, s1_ref, qoff_ref, ecmax_ref, q_ref,
                 k_ref, v_ref, *rest,
                 nq: int, nk: int, bg: int, bq: int, bk: int,
                 g_real: int, sq_real: int, sk_real: int,
                 sqrt_d: Optional[float],
                 e_min: float, octave_step: float, frac_shift: int,
                 causal: bool, has_mask: bool, dyn_len: bool,
                 per_row: bool, skip_blocks: bool):
    if has_mask:
        mask_ref, exp_val_ref, log_lut_ref, prob_lut_ref = rest[:4]
        rest = rest[4:]
    else:
        mask_ref = None
        exp_val_ref, log_lut_ref, prob_lut_ref = rest[:3]
        rest = rest[3:]
    o_ref, cmax_out_ref, sum_ref, xmax_ref, acc_ref, cmax_ref = rest

    ph = pl.program_id(0)
    g = pl.program_id(1)
    i = pl.program_id(2)
    k = pl.program_id(3)
    rows = pl.dslice((g * nq + i) * bg * bq, bg * bq)  # per-row scratch slots
    # keys past the real/valid length carry no weight at all (they do not
    # exist in the oracle's input): static block padding, or — decode path —
    # the dynamic KV-cache fill level streamed in as a prefetched scalar
    mask_keys = (sk_real % bk != 0) or dyn_len
    def guard_live(body):
        """Run ``body`` only for key blocks intersecting the valid prefix.

        Scalar-prefetch decode grids (``skip_blocks``: dynamic length AND
        more than one key block): fully-invalid blocks (k*bk >= kv_len)
        are skipped outright — their accumulation work is gated off here,
        and the k/v BlockSpec index maps clamp them to the last valid
        block so no fresh tile is ever fetched for them (grid bounds
        instead of masked sweeps over the whole cache buffer). The bound
        is ``kvmax_ref[g]`` — the max valid length across the ``bg``
        groups riding this tile: equal to the lone kv_len for a scalar
        fill, and the *tile's own* fill frontier for a per-group vector
        (a short request in a mixed batch stops streaming at its own
        level, not the batch max). kv_len is an SMEM scalar load, safe to
        branch on. Every other grid keeps the unconditional body: static
        (prefill) grids have nothing to skip, and an nk==1 dynamic grid's
        only block always intersects the prefix — gating there would
        predicate control flow on a VMEM-resident scalar for a condition
        that is always true.
        """
        if skip_blocks:
            pl.when((k * bk) < kvmax_ref[g])(body)
        else:
            body()

    def row_lens():
        """Per-group valid lengths of this tile's rows: (bg, 1, 1)."""
        return kvlen_ref[pl.dslice(g * bg, bg)].reshape(bg, 1, 1)

    def key_valid():
        kpos = k * bk + jax.lax.broadcasted_iota(jnp.int32, (bg, bq, bk), 2)
        if per_row:  # each group row attends its own request's prefix
            return kpos < row_lens()
        return kpos < kvlen_ref[0]

    def tile_logit_codes():
        """matmul-1 + div-add: (bg, bq, bk) LOGIT codes."""
        r = jax.lax.dot_general(
            q_ref[...].astype(jnp.int32), k_ref[...].astype(jnp.int32),
            (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.int32)
        logits = r.astype(jnp.float32) * s1_ref[0, 0]
        if sqrt_d is not None:
            logits = logits / sqrt_d
        xc = jnp.clip(jnp.round(logits / LOGIT_FMT.scale),
                      LOGIT_FMT.code_min, LOGIT_FMT.code_max).astype(jnp.int32)
        if has_mask:
            msk = mask_ref[...] != 0
        elif causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bg, bq, bk), 1)
            kpos = k * bk + jax.lax.broadcasted_iota(jnp.int32, (bg, bq, bk), 2)
            msk = kpos <= qpos + qoff_ref[0, 0]
        else:
            msk = None
        if msk is not None:  # masked keys sit at the LOGIT minimum (div-add)
            xc = jnp.where(msk, xc, LOGIT_FMT.code_min)
        return xc

    def load_row_sums():
        return sum_ref[rows, :].reshape(bg, bq, 1)

    # ---------------- pass A: streaming PoT row sum + global PROB max ------
    @pl.when(ph == 0)
    def _pass_a():
        @pl.when((g == 0) & (i == 0) & (k == 0))
        def _init_global():
            # the running global PROB max starts at the external floor
            # (0 for single-device calls): tensor-parallel shards seed it
            # with the cross-shard pmax so every shard requantizes with
            # the same — true global — scale. max(floor, local) needs no
            # extra op: the floor is just the accumulator's initial value.
            cmax_ref[0, 0] = ecmax_ref[0, 0]

        @pl.when(k == 0)
        def _init_rows():
            sum_ref[rows, :] = jnp.zeros((bg * bq, 1), jnp.float32)
            xmax_ref[...] = jnp.full((bg, bq, 1), LOGIT_FMT.code_min, jnp.int32)

        @guard_live
        def _accumulate():
            xc = tile_logit_codes()
            # exp_val_ref folds the exp LUT with its decode: one f32 gather
            e_vals = exp_val_ref[xc + 128]
            xmax_tile = xc
            if mask_keys:
                valid = key_valid()
                e_vals = jnp.where(valid, e_vals, 0.0)
                xmax_tile = jnp.where(valid, xc, LOGIT_FMT.code_min)
            sum_ref[rows, :] += jnp.sum(e_vals, axis=-1, keepdims=True
                                        ).reshape(bg * bq, 1)
            xmax_ref[...] = jnp.maximum(
                xmax_ref[...], jnp.max(xmax_tile, axis=-1, keepdims=True))

        @pl.when(k == nk - 1)
        def _row_finish():
            L = log_lut_ref[_pot_encode_sum(load_row_sums(), e_min,
                                            octave_step)]
            dmax = jnp.clip(xmax_ref[...] - (L << frac_shift),
                            LOGIT_FMT.code_min, LOGIT_FMT.code_max)
            c_row = prob_lut_ref[dmax + 128]
            rpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bg, bq, 1), 1)
            gpos = g * bg + jax.lax.broadcasted_iota(jnp.int32, (bg, bq, 1), 0)
            c_row = jnp.where((rpos < sq_real) & (gpos < g_real), c_row, 0)
            if per_row:
                # a zero-length group has NO keys: its row sum is 0 and its
                # xmax sits at the LOGIT minimum, which LOG(0) could still
                # lift into a nonzero PROB code — such rows are defined as
                # all-zero output and must not pollute the global cmax
                c_row = jnp.where(row_lens() > 0, c_row, 0)
            cmax_ref[0, 0] = jnp.maximum(cmax_ref[0, 0], jnp.max(c_row))

    # ---------------- pass B: PROB codes . V with the exact oracle scale ---
    @pl.when(ph == 1)
    def _pass_b():
        @pl.when(k == 0)
        def _init_acc():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        @guard_live
        def _accumulate():
            xc = tile_logit_codes()
            L = log_lut_ref[_pot_encode_sum(load_row_sums(), e_min,
                                            octave_step)]
            d = jnp.clip(xc - (L << frac_shift),
                         LOGIT_FMT.code_min, LOGIT_FMT.code_max)
            pc = _requant_code_table(cmax_ref[0, 0], prob_lut_ref[...])[d + 128]
            if mask_keys:  # padded/invalid keys: PROB code 0 -> requant code 0
                pc = jnp.where(key_valid(), pc, 0)
            acc_ref[...] += jax.lax.dot_general(
                pc, v_ref[...].astype(jnp.int32),
                (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.int32)

        @pl.when(k == nk - 1)
        def _write():
            o_ref[...] = acc_ref[...]
            cmax_out_ref[0, 0] = cmax_ref[0, 0]


def _attn_kernel_single(kvlen_ref, kvmax_ref, s1_ref, qoff_ref, ecmax_ref,
                        q_ref, k_ref, v_ref, *rest, bg: int, bq: int, bk: int,
                        g_real: int, sq_real: int, sk_real: int,
                        sqrt_d: Optional[float],
                        e_min: float, octave_step: float, frac_shift: int,
                        causal: bool, has_mask: bool, dyn_len: bool,
                        per_row: bool):
    """One-tile fast path: the whole pipeline in a single grid step.

    When (heads, Sq, Sk) fit one VMEM tile the two-pass structure degenerates
    — the logit codes stay live in registers between the row-sum and the
    PROB matmul, so there is no second key sweep and no scratch traffic.
    Numerics are identical to the streaming kernel.
    """
    if has_mask:
        mask_ref, exp_val_ref, log_lut_ref, prob_lut_ref, o_ref, cmax_out_ref \
            = rest
    else:
        mask_ref = None
        exp_val_ref, log_lut_ref, prob_lut_ref, o_ref, cmax_out_ref = rest
    mask_keys = (sk_real % bk != 0) or dyn_len

    r = jax.lax.dot_general(
        q_ref[...].astype(jnp.int32), k_ref[...].astype(jnp.int32),
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.int32)
    logits = r.astype(jnp.float32) * s1_ref[0, 0]
    if sqrt_d is not None:
        logits = logits / sqrt_d
    xc = jnp.clip(jnp.round(logits / LOGIT_FMT.scale),
                  LOGIT_FMT.code_min, LOGIT_FMT.code_max).astype(jnp.int32)
    if has_mask:
        xc = jnp.where(mask_ref[...] != 0, xc, LOGIT_FMT.code_min)
    elif causal:
        qpos = jax.lax.broadcasted_iota(jnp.int32, (bg, bq, bk), 1)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (bg, bq, bk), 2)
        xc = jnp.where(kpos <= qpos + qoff_ref[0, 0], xc, LOGIT_FMT.code_min)

    e_vals = exp_val_ref[xc + 128]
    xmax_tile = xc
    if mask_keys:
        kpos = jax.lax.broadcasted_iota(jnp.int32, (bg, bq, bk), 2)
        if per_row:
            lens = kvlen_ref[pl.dslice(0, bg)].reshape(bg, 1, 1)
            valid = kpos < lens
        else:
            valid = kpos < kvlen_ref[0]
        e_vals = jnp.where(valid, e_vals, 0.0)
        xmax_tile = jnp.where(valid, xc, LOGIT_FMT.code_min)
    S = jnp.sum(e_vals, axis=-1, keepdims=True)
    L = log_lut_ref[_pot_encode_sum(S, e_min, octave_step)]

    dmax = jnp.clip(jnp.max(xmax_tile, axis=-1, keepdims=True)
                    - (L << frac_shift),
                    LOGIT_FMT.code_min, LOGIT_FMT.code_max)
    c_row = prob_lut_ref[dmax + 128]
    rpos = jax.lax.broadcasted_iota(jnp.int32, (bg, bq, 1), 1)
    gpos = jax.lax.broadcasted_iota(jnp.int32, (bg, bq, 1), 0)
    c_row = jnp.where((rpos < sq_real) & (gpos < g_real), c_row, 0)
    if per_row:  # zero-length groups: all-zero rows, no cmax pollution
        c_row = jnp.where(lens > 0, c_row, 0)
    cmax = jnp.maximum(jnp.max(c_row), ecmax_ref[0, 0])

    d = jnp.clip(xc - (L << frac_shift),
                 LOGIT_FMT.code_min, LOGIT_FMT.code_max)
    pc = _requant_code_table(cmax, prob_lut_ref[...])[d + 128]
    if mask_keys:
        pc = jnp.where(valid, pc, 0)
    o_ref[...] = jax.lax.dot_general(
        pc, v_ref[...].astype(jnp.int32),
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.int32)
    cmax_out_ref[0, 0] = cmax


@functools.partial(
    jax.jit, static_argnames=("mode", "scale_by_sqrt_d", "causal",
                              "block_q", "block_k", "block_g", "interpret",
                              "page_size", "groups_per_slot"))
def acam_attention_codes(
    q_codes: jax.Array,   # (G, Sq, D) int8 — G folds batch x heads
    k_codes: jax.Array,   # (G, Sk, D) int8 — or the paged pool, see below
    v_codes: jax.Array,   # (G, Sk, D) int8
    logit_scale: jax.Array,          # () f32: s_q * s_k (div-add numerator)
    mask: Optional[jax.Array] = None,  # (G, Sq, Sk) bool; None => causal/full
    q_offset: jax.Array | int = 0,     # causal decode offset (cache index)
    kv_len: Optional[jax.Array] = None,  # () or (G,): valid key prefix(es)
    mode: str = "pot",
    scale_by_sqrt_d: Optional[int] = None,  # d to fold 1/sqrt(d); None = folded
    causal: bool = False,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: Optional[bool] = None,
    block_table: Optional[jax.Array] = None,  # (n_slots, max_pages) int32
    page_size: Optional[int] = None,          # static: rows per pool page
    groups_per_slot: Optional[int] = None,    # static: grid groups per slot
    cmax_floor: Optional[jax.Array] = None,   # () int32: external PROB-max seed
) -> tuple[jax.Array, jax.Array]:
    """Fused Fig.-12 attention on int8 codes.

    Returns (out, cmax): out (G, Sq, D) int32 — the matmul-2 accumulator over
    re-quantized PROB codes — and cmax () int32, the tensor-wide max PROB
    code, from which the caller rebuilds the oracle's probability scale
    ``max(cmax/256, 1e-12)/127``. Never materializes an (Sq, Sk) array.

    ``kv_len`` (traced int32 scalar) marks only the first ``kv_len`` keys as
    existing — keys past it contribute nothing to the row sum, the global
    PROB max, or matmul-2, exactly as if k/v had been sliced to that length
    (the KV-cache decode path: a fixed-shape cache buffer, dynamic fill).
    A *(G,) vector* ``kv_len`` gives every grid group its own valid prefix
    (per-request serving decode): each group's keys past its own length do
    not exist for that group, zero-length groups output all-zero rows (and
    contribute nothing to the global PROB max), and block skipping clamps
    to the per-tile max length, so short requests in a mixed batch stop
    streaming at their own fill level. ``mode`` accepts every staged
    softmax config: "pot", "pot_fine", "uniform" (the Fig.-14 ablation's
    uniform exp quantization).

    **Paged k/v** (``block_table`` given): k/v are a page *pool* of shape
    ``(n_pages * groups_per_slot, page_size, D)`` — physical page ``p``
    stores the ``groups_per_slot`` group stripes of one logical page at
    rows ``[p*gps, (p+1)*gps)`` — and ``block_table[slot, j]`` names the
    physical page backing slot ``slot``'s logical page ``j``. The logical
    key extent is ``max_pages * page_size``; ``kv_len`` must be a (G,)
    per-group vector. The table rides as a third scalar-prefetch operand
    consumed only by the k/v index maps; physical page 0 is the
    conventional trash page dead/unmapped entries resolve to (its tiles
    are fetched but fully masked/skipped). Output is bit-identical to the
    contiguous layout holding the same logical contents — pages move the
    DMA source of each key tile, never its logical coordinates or the
    block visit order.

    ``cmax_floor`` (traced int32 scalar, default 0) seeds the global PROB
    max: the returned cmax and the requant scale use
    ``max(cmax_floor, local max)``. Since PROB codes are non-negative, 0 is
    the exact identity. Tensor-parallel shards use this to agree on the
    global scale: each shard runs a probe call, ``lax.pmax``es the local
    cmax over the mesh axis, and re-runs with the floor set to the global
    — every shard then requantizes with the same table and the sharded
    output is bit-identical to the unsharded call (integer max is
    order-free, so the floored local reduction equals the global one).
    """
    interpret = resolve_interpret(interpret)
    exp_val, log_lut, prob_lut, e_min, octave_step, frac_shift = \
        softmax_tables(mode)

    paged = block_table is not None
    G, Sq, D = q_codes.shape
    if paged:
        if page_size is None or groups_per_slot is None:
            raise ValueError("paged attention needs static page_size and "
                             "groups_per_slot alongside block_table")
        if kv_len is None or jnp.ndim(kv_len) != 1:
            raise ValueError("paged attention requires a per-group (G,) "
                             "kv_len vector")
        gps = groups_per_slot
        n_slots, max_pages = block_table.shape
        if G != n_slots * gps:
            raise ValueError(f"paged G={G} != n_slots*groups_per_slot = "
                             f"{n_slots}*{gps}")
        if k_codes.shape[1] != page_size or k_codes.shape[0] % gps:
            raise ValueError(f"paged k/v pool must be (n_pages*{gps}, "
                             f"{page_size}, D), got {k_codes.shape}")
        Sk = max_pages * page_size         # logical key extent
        # group tiles must never straddle a slot (all bg groups share one
        # block-table row), and key blocks must never straddle a page
        bg = max(d for d in range(1, min(block_g, gps) + 1) if gps % d == 0)
        bk = math.gcd(page_size, min(block_k, page_size))
    else:
        Sk = k_codes.shape[1]
        bg = min(block_g, G)
        bk = min(block_k, max(_LANES, Sk))
    bq = min(block_q, max(8, Sq))
    pad_g, pad_q, pad_k = (-G) % bg, (-Sq) % bq, (-Sk) % bk
    # lane-align the head dim only when compiling for real hardware; in
    # interpret mode the padding would just double the MXU work
    pad_d = 0 if interpret else (-D) % _LANES
    pad3 = lambda a: jnp.pad(a, ((0, pad_g), (0, 0), (0, 0)))
    qp = pad3(jnp.pad(q_codes, ((0, 0), (0, pad_q), (0, pad_d))))
    if paged:  # pool rows are physical pages — only the head dim pads
        kp = jnp.pad(k_codes, ((0, 0), (0, 0), (0, pad_d)))
        vp = jnp.pad(v_codes, ((0, 0), (0, 0), (0, pad_d)))
    else:
        kp = pad3(jnp.pad(k_codes, ((0, 0), (0, pad_k), (0, pad_d))))
        vp = pad3(jnp.pad(v_codes, ((0, 0), (0, pad_k), (0, pad_d))))
    Gp, Sqp, Skp, Dp = G + pad_g, Sq + pad_q, Sk + pad_k, D + pad_d
    ng, nq, nk = Gp // bg, Sqp // bq, Skp // bk
    # whole problem fits a single VMEM tile (paged always streams: even one
    # key block needs the block-table indirection in its index map)
    one_tile = ng == nq == nk == 1 and not paged

    sqrt_d = float(np.sqrt(np.float32(scale_by_sqrt_d), dtype=np.float32)) \
        if scale_by_sqrt_d is not None else None
    logit_scale = jnp.asarray(logit_scale, jnp.float32)
    if sqrt_d is not None and (float(np.log2(sqrt_d)) % 1.0 == 0.0):
        # power-of-two scaling commutes with f32 rounding, so folding the
        # exact /sqrt(d) into the scalar is bit-identical to the oracle's
        # multiply-then-divide and saves a full-tile division per pass
        logit_scale = logit_scale / sqrt_d
        sqrt_d = None

    dyn_len = kv_len is not None
    per_row = dyn_len and jnp.ndim(kv_len) == 1
    if per_row:
        kvv = jnp.asarray(kv_len, jnp.int32)
        if kvv.shape[0] != G:
            raise ValueError(f"per-group kv_len must have one entry per "
                             f"group: got {kvv.shape} for G={G}")
        # padded groups carry length 0: no keys exist for them, their rows
        # are all-zero and they never contribute to the global PROB max
        kv_len_val = jnp.pad(jnp.minimum(kvv, Sk), (0, pad_g))
        # per group-tile fill frontier: the skip bound for each tile's key
        # stream (max over the bg groups riding the tile)
        kv_blockmax = jnp.max(kv_len_val.reshape(ng, bg), axis=1)
    else:
        kv_len_val = (jnp.minimum(jnp.asarray(kv_len, jnp.int32), Sk)
                      if dyn_len else jnp.asarray(Sk, jnp.int32)).reshape(1)
        kv_blockmax = jnp.broadcast_to(kv_len_val, (ng,))

    # When the decode grid streams multiple key blocks, kv_len rides as a
    # *scalar-prefetch* operand: it is available before each grid step, so
    # the k/v BlockSpec index maps can clamp fully-invalid key blocks to
    # the last valid block — the grid keeps a static shape, but blocks past
    # the fill level never DMA a fresh tile and their compute is gated off
    # in-kernel (`guard_live`). A second prefetched operand carries the
    # per-group-tile max lengths, so the clamp/skip bound is one scalar
    # load (``kvmax[g]``) for scalar and per-group fills alike. Static
    # grids (prefill, and single-tile decode, where there is no whole
    # block to skip) keep both as plain operands and pay none of the
    # prefetch machinery; the kernels see identical refs either way.
    use_prefetch = (dyn_len and nk > 1) or paged

    def _im(f):
        """Index map with the right arity: scalar-prefetch index maps
        receive the prefetched refs as trailing arguments (the paged grid
        prefetches a third operand, the block table)."""
        if use_prefetch:
            if paged:
                return lambda p, g, i, k, kvl, kvm, bt: f(p, g, i, k, kvl, kvm)
            return lambda p, g, i, k, kvl, kvm: f(p, g, i, k, kvl, kvm)
        return lambda p, g, i, k: f(p, g, i, k, None, None)

    spec_scalar = pl.BlockSpec((1, 1), _im(lambda p, g, i, k, kvl, kvm: (0, 0)))
    spec_lut = pl.BlockSpec((256,), _im(lambda p, g, i, k, kvl, kvm: (0,)))

    if paged:
        spb = page_size // bk  # key blocks per page

        def kv_index(p, g, i, k, kvl, kvm, bt):
            # same per-tile frontier clamp as the contiguous prefetch path,
            # then translate the logical key block through the slot's
            # block-table row: logical page kc//spb -> physical pool page,
            # whose bg-group stripe for this tile starts at row
            # page*gps + (g*bg) % gps (bg divides gps, so it is block-aligned)
            last_live = jnp.maximum((kvm[g] + bk - 1) // bk - 1, 0)
            kc = jnp.minimum(k, last_live)
            page = bt[(g * bg) // gps, kc // spb]
            return ((page * gps + (g * bg) % gps) // bg, kc % spb, 0)
    elif use_prefetch:
        def kv_index(p, g, i, k, kvl, kvm):
            last_live = jnp.maximum((kvm[g] + bk - 1) // bk - 1, 0)
            return (g, jnp.minimum(k, last_live), 0)
    else:
        kv_index = _im(lambda p, g, i, k, kvl, kvm: (g, k, 0))

    in_specs = [
        spec_scalar,                                                # logit scale
        spec_scalar,                                                # q offset
        spec_scalar,                                                # cmax floor
        pl.BlockSpec((bg, bq, Dp), _im(lambda p, g, i, k, kvl, kvm: (g, i, 0))),
        pl.BlockSpec((bg, bk, Dp), kv_index),                       # k
        pl.BlockSpec((bg, bk, Dp), kv_index),                       # v
    ]
    operands = [
        kv_len_val,    # leading: scalar-prefetch args / plain operands
        kv_blockmax,
    ]
    if paged:      # third prefetched scalar: the block table (index-map only)
        operands.append(jnp.asarray(block_table, jnp.int32))
    operands += [
        logit_scale.reshape(1, 1),
        jnp.asarray(q_offset, jnp.int32).reshape(1, 1),
        jnp.asarray(0 if cmax_floor is None else cmax_floor,
                    jnp.int32).reshape(1, 1),
        qp, kp, vp,
    ]
    if mask is not None:
        mp = pad3(jnp.pad(mask.astype(jnp.int8),
                          ((0, 0), (0, pad_q), (0, pad_k))))
        in_specs.append(pl.BlockSpec(
            (bg, bq, bk), _im(lambda p, g, i, k, kvl, kvm: (g, i, k))))
        operands.append(mp)
    in_specs += [spec_lut, spec_lut, spec_lut]
    operands += [exp_val, jnp.asarray(log_lut, jnp.int32),
                 jnp.asarray(prob_lut, jnp.int32)]

    if one_tile:  # single grid step, no scratch, no second key sweep
        kernel = functools.partial(
            _attn_kernel_single, bg=bg, bq=bq, bk=bk,
            g_real=G, sq_real=Sq, sk_real=Sk,
            sqrt_d=sqrt_d, e_min=e_min, octave_step=octave_step,
            frac_shift=frac_shift, causal=causal, has_mask=mask is not None,
            dyn_len=dyn_len, per_row=per_row)
        scratch = []
        grid = (1, 1, 1, 1)
    else:
        kernel = functools.partial(
            _attn_kernel, nq=nq, nk=nk, bg=bg, bq=bq, bk=bk,
            g_real=G, sq_real=Sq, sk_real=Sk,
            sqrt_d=sqrt_d, e_min=e_min, octave_step=octave_step,
            frac_shift=frac_shift, causal=causal, has_mask=mask is not None,
            dyn_len=dyn_len, per_row=per_row, skip_blocks=use_prefetch)
        scratch = [
            pltpu.VMEM((Gp * Sqp, 1), jnp.float32),  # streaming PoT row sums
            pltpu.VMEM((bg, bq, 1), jnp.int32),      # row logit max (pass A)
            pltpu.VMEM((bg, bq, Dp), jnp.int32),     # matmul-2 accumulator
            pltpu.SMEM((1, 1), jnp.int32),           # global PROB code max
        ]
        grid = (2, ng, nq, nk)

    out_shape = (jax.ShapeDtypeStruct((Gp, Sqp, Dp), jnp.int32),
                 jax.ShapeDtypeStruct((1, 1), jnp.int32))
    out_specs = (pl.BlockSpec((bg, bq, Dp),
                              _im(lambda p, g, i, k, kvl, kvm: (g, i, 0))),
                 spec_scalar)
    if use_prefetch:
        if paged:
            # the kernel bodies never read the block table (it exists for
            # the k/v index maps alone) — drop its ref before dispatch
            inner = kernel
            kernel = lambda kvl, kvm, bt, *rest: inner(kvl, kvm, *rest)
        call = pl.pallas_call(
            kernel, out_shape=out_shape,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=3 if paged else 2, grid=grid,
                in_specs=in_specs, out_specs=out_specs,
                scratch_shapes=scratch),
            interpret=interpret)
    else:
        kvlen_spec = pl.BlockSpec(
            kv_len_val.shape, _im(lambda p, g, i, k, kvl, kvm: (0,)))
        kvmax_spec = pl.BlockSpec(
            (ng,), _im(lambda p, g, i, k, kvl, kvm: (0,)))
        call = pl.pallas_call(
            kernel, out_shape=out_shape, grid=grid,
            in_specs=[kvlen_spec, kvmax_spec] + in_specs,
            out_specs=out_specs,
            scratch_shapes=scratch, interpret=interpret)
    out, cmax = call(*operands)
    return out[:G, :Sq, :D], cmax[0, 0]


def acam_attention_decode_codes(
    q_codes: jax.Array,   # (G, 1, D) int8 — one new token per folded B x H
    k_codes: jax.Array,   # (G, Smax, D) int8 — fixed-shape KV cache buffer
    v_codes: jax.Array,   # (G, Smax, D) int8
    logit_scale: jax.Array,          # () f32: s_q * s_k
    kv_len: jax.Array,               # () int32 (>= 1) or (G,) per-group
    mask: Optional[jax.Array] = None,  # (G, 1, Smax) bool/int8, 0 => mask out
    mode: str = "pot",
    scale_by_sqrt_d: Optional[int] = None,
    block_k: int = DEFAULT_BLOCK_K,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: Optional[bool] = None,
    block_table: Optional[jax.Array] = None,
    page_size: Optional[int] = None,
    groups_per_slot: Optional[int] = None,
    cmax_floor: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """Decode-mode fused attention: Sq=1 queries against a KV cache.

    The same streaming pipeline as `acam_attention_codes`, specialized to the
    serving decode step: a single new query per (batch x head) group attends
    the first ``kv_len`` entries of a fixed-shape cache buffer. Keys past
    ``kv_len`` do not exist for the kernel — no exp weight, no PROB max
    contribution, no matmul-2 term — so (out, cmax) are exactly what
    `acam_attention_codes` returns on the sliced cache ``k[:, :kv_len]``,
    with no dynamic shapes anywhere: the grid keeps a static shape, but
    ``kv_len`` is scalar-prefetched, so fully-invalid key blocks are
    *skipped* (index maps clamp to the last valid block — no fresh tile
    fetch — and `guard_live` gates off their compute), while the partially
    valid boundary block is masked.

    No mask array or causal offset is needed for solo serving: decode
    causality is precisely "attend the valid prefix", which ``kv_len``
    already encodes. ``mask`` exists for *batched* serving with left-padded
    buckets: per-group key validity (pad slots masked to the LOGIT minimum,
    exactly like the staged oracle's additive mask) on top of the prefix
    rule.

    ``kv_len`` may also be a *(G,)* vector — one valid prefix per group
    (per-request serving: slot-level continuous batching hands every slot
    its own fill level). Each group then attends exactly its own prefix,
    zero-length groups are defined as all-zero output rows (a drained or
    never-filled slot), and the dead-block skip clamps per group tile, so
    a short request stops streaming where *its* cache ends, not at the
    batch max.

    With ``block_table``/``page_size``, k/v are the paged pool
    ``(n_pages * groups_per_slot, page_size, D)`` — ``groups_per_slot``
    defaults to G // n_slots (the flat layout folds every query head of a
    slot into its group stripe). See `acam_attention_codes` for the paged
    contract; decode is its hot consumer (slot-level continuous batching
    hands each slot a block-table row instead of a contiguous cache
    stripe).
    """
    if q_codes.shape[1] != 1:
        raise ValueError(f"decode path expects Sq=1, got {q_codes.shape[1]}")
    if block_table is not None and groups_per_slot is None:
        groups_per_slot = q_codes.shape[0] // block_table.shape[0]
    return acam_attention_codes(
        q_codes, k_codes, v_codes, logit_scale, mask, kv_len=kv_len,
        mode=mode, scale_by_sqrt_d=scale_by_sqrt_d,
        block_k=block_k, block_g=block_g, interpret=interpret,
        block_table=block_table, page_size=page_size,
        groups_per_slot=groups_per_slot, cmax_floor=cmax_floor)


def acam_attention_decode_gqa_codes(
    q_codes: jax.Array,   # (B*KV, rep, D) int8 — the rep queries of a group
    k_codes: jax.Array,   # (B*KV, Smax, D) int8 — native-layout cache buffer
    v_codes: jax.Array,   # (B*KV, Smax, D) int8
    logit_scale: jax.Array,          # () f32: s_q * s_k
    kv_len: jax.Array,               # () int32 (>= 1) or (B*KV,) per-group
    mask: Optional[jax.Array] = None,  # (B*KV, rep, Smax), 0 => mask out
    mode: str = "pot",
    scale_by_sqrt_d: Optional[int] = None,
    block_k: int = DEFAULT_BLOCK_K,
    block_g: int = DEFAULT_BLOCK_G,
    interpret: Optional[bool] = None,
    block_table: Optional[jax.Array] = None,
    page_size: Optional[int] = None,
    groups_per_slot: Optional[int] = None,
    cmax_floor: Optional[jax.Array] = None,
) -> tuple[jax.Array, jax.Array]:
    """GQA-native decode: k/v in their (B*KV, Smax, D) cache layout.

    The flat decode entry above folds batch x *query* heads into the group
    dimension, which forces GQA callers to `jnp.repeat` the KV cache codes
    to H groups first — rep x the cache bytes the grouped-query layout was
    designed to avoid. This entry keeps the cache native: the grid's group
    dimension iterates the B*KV *KV-head* groups, and the ``rep`` query
    heads that share each KV head ride the row (``bq``) dimension of the
    tile — the same slot the prefill grid uses for query positions. Decode
    queries all sit at the same position (causality == "attend the valid
    prefix", encoded by ``kv_len``), so rows are interchangeable and the
    kernel bodies, the scalar-prefetched ``kv_len`` skip machinery, and the
    global-cmax reduction apply unchanged.

    Per key block the tile now loads one k/v tile for ``bg`` *groups*
    instead of ``bg`` query heads: 1/rep of the grid steps and 1/rep of the
    KV bytes of the flat entry, with bit-identical (out, cmax) — same
    logits per (head, key), same per-row PoT sums in the same block order,
    same integer cmax reduction (order-free), same requant scale.

    A *(B*KV,)* vector ``kv_len`` gives every KV-head group its own valid
    prefix — all ``rep`` query rows riding a group's tile share that
    group's length, which is exactly the per-request semantics (a
    request's heads all see the same cache fill). See
    `acam_attention_decode_codes` for the per-row contract.

    With ``block_table``/``page_size``, k/v are the paged pool
    ``(n_pages * groups_per_slot, page_size, D)`` with
    ``groups_per_slot = KV`` (each pool page holds one logical page for
    every KV head of its slot); the pool's group-dim divisibility replaces
    the contiguous entry's shared-group-dim check.
    """
    if block_table is not None:
        if groups_per_slot is None:
            raise ValueError("GQA paged decode needs groups_per_slot (=KV)")
    elif k_codes.shape[0] != q_codes.shape[0]:
        raise ValueError(
            f"GQA decode expects q and k/v to share the group dim "
            f"(B*KV): got q {q_codes.shape} vs k {k_codes.shape}")
    return acam_attention_codes(
        q_codes, k_codes, v_codes, logit_scale, mask, kv_len=kv_len,
        mode=mode, scale_by_sqrt_d=scale_by_sqrt_d,
        block_k=block_k, block_g=block_g, interpret=interpret,
        block_table=block_table, page_size=page_size,
        groups_per_slot=groups_per_slot, cmax_floor=cmax_floor)
