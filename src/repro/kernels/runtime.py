"""Kernel runtime policy helpers shared by all Pallas wrappers."""
from __future__ import annotations

import jax

__all__ = ["default_interpret", "resolve_interpret"]


def default_interpret() -> bool:
    """Interpret Pallas kernels everywhere except on real TPU backends.

    Interpret mode executes kernel bodies as traced jax ops — bit-exact and
    debuggable on CPU/GPU containers; on TPU the same wrappers compile to
    Mosaic so the serving stack runs the real kernels with no code change.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret) -> bool:
    """None -> backend default; bool passes through (explicit override)."""
    return default_interpret() if interpret is None else bool(interpret)
