from .runtime import default_interpret, resolve_interpret  # noqa: F401
from .ops import (  # noqa: F401
    acam_attention_codes, acam_lut, acam_lut_2d, acam_mvm,
    acam_softmax_codes, acam_softmax_kernel, acam_activation,
    prob_requant_scale, raceit_attention_fused, raceit_linear,
)
