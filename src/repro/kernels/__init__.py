from .ops import (  # noqa: F401
    acam_lut, acam_lut_2d, acam_mvm, acam_softmax_codes, acam_softmax_kernel,
    acam_activation, raceit_linear,
)
