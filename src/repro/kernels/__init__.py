from .runtime import default_interpret, resolve_interpret  # noqa: F401
from .ops import (  # noqa: F401
    FUSED_SOFTMAX_MODES, acam_attention_codes, acam_attention_decode_codes,
    acam_lut, acam_lut_2d, acam_mvm,
    acam_softmax_codes, acam_softmax_kernel, acam_activation,
    masked_prefix_quantize, prob_requant_scale,
    raceit_attention_decode_fused, raceit_attention_fused, raceit_linear,
)
