"""Pallas TPU kernel: the fused Compute-ACAM Softmax dataflow (paper Fig. 8).

One VMEM pass per row-block executes all five stages —
exp-LUT (PoT) -> adder-lane row sum -> log-LUT -> subtract -> exp-LUT —
so the intermediate exponent codes never touch HBM (the XLA baseline spills
them; see EXPERIMENTS.md §Perf). Tables are compiled by core.compiler and
passed in as int32 operands resident in VMEM.

Inputs are LOGIT_FMT (1-4-3) codes; output is PROB_FMT (0-0-8) codes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import ops as acam_ops
from repro.core.ops import LOGIT_FMT

from .runtime import resolve_interpret

LANES = 128


def _softmax_kernel(x_ref, exp_lut_ref, log_lut_ref, prob_lut_ref, o_ref, *,
                    e_min: float, octave_step: float, frac_shift: int,
                    valid_cols: int):
    xc = x_ref[...].astype(jnp.int32)  # LOGIT codes, two's complement
    cols = jax.lax.broadcasted_iota(jnp.int32, xc.shape, 1)
    valid = cols < valid_cols

    # step 1: e = EXP(x) as PoT codes (bias to unsigned position first)
    e_codes = exp_lut_ref[xc + 128]
    # adder lane works on decoded PoT values (code 0 == exactly 0)
    e_vals = jnp.where(e_codes == 0, 0.0,
                       jnp.exp2((e_codes - 1).astype(jnp.float32) * octave_step
                                + e_min))
    e_vals = jnp.where(valid, e_vals, 0.0)
    # step 2: S = sum (padded cols contribute zero)
    S = jnp.sum(e_vals, axis=-1, keepdims=True)
    # step 3: L = LOG(S); PoT-encode S to index the log table
    safe = jnp.maximum(S, 2.0 ** (e_min - 1))
    s_codes = jnp.clip(jnp.round((jnp.log2(safe) - e_min) / octave_step),
                       0, 254).astype(jnp.int32) + 1
    s_codes = jnp.where(S < 2.0 ** (e_min - octave_step / 2), 0, s_codes)
    L = log_lut_ref[s_codes]  # LOG_OUT (1-5-2) codes
    # step 4: d = x - L in the logit grid (adder lane subtract)
    d = jnp.clip(xc - (L << frac_shift), -128, 127)
    # step 5: p = EXP(d) -> PROB codes
    o_ref[...] = prob_lut_ref[d + 128].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "block_rows", "interpret"))
def acam_softmax_codes(x_codes: jax.Array, mode: str = "pot",
                       block_rows: int = 128,
                       interpret: bool | None = None) -> jax.Array:
    """x_codes: (R, L) int LOGIT_FMT codes -> (R, L) PROB_FMT codes (int32).

    Masked positions must already be LOGIT_FMT.code_min (the div-add stage
    writes the mask before softmax, paper Fig. 12).
    """
    interpret = resolve_interpret(interpret)
    exp_op = acam_ops.get_op("exp_pot" if mode == "pot" else "exp_pot_fine")
    log_op = acam_ops.get_op("log" if mode == "pot" else "log_fine")
    prob_op = acam_ops.get_op("exp_prob")
    pot = exp_op.out_fmt
    frac_shift = LOGIT_FMT.frac_bits - log_op.out_fmt.frac_bits

    R, L = x_codes.shape
    br = min(block_rows, max(8, R))
    pad_r = (-R) % br
    pad_c = (-L) % LANES
    xp = jnp.pad(x_codes, ((0, pad_r), (0, pad_c)),
                 constant_values=LOGIT_FMT.code_min)
    Rp, Lp = xp.shape

    out = pl.pallas_call(
        functools.partial(_softmax_kernel, e_min=float(pot.e_min),
                          octave_step=float(pot.octave_step),
                          frac_shift=frac_shift, valid_cols=L),
        out_shape=jax.ShapeDtypeStruct((Rp, Lp), jnp.int32),
        in_specs=[
            pl.BlockSpec((br, Lp), lambda i: (i, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, Lp), lambda i: (i, 0)),
        grid=(Rp // br,),
        interpret=interpret,
    )(xp, jnp.asarray(exp_op._lut, jnp.int32), jnp.asarray(log_op._lut, jnp.int32),
      jnp.asarray(prob_op._lut, jnp.int32))
    return out[:R, :L]


def acam_softmax_kernel(x: jax.Array, mode: str = "pot",
                        interpret: bool | None = None) -> jax.Array:
    """Float logits -> float probs through the fused kernel (N-D wrapper)."""
    prob_op = acam_ops.get_op("exp_prob")
    shape = x.shape
    codes = LOGIT_FMT.encode(x).reshape(-1, shape[-1])
    p = acam_softmax_codes(codes, mode=mode, interpret=interpret)
    return prob_op.out_fmt.decode(p).reshape(shape)
