"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ops as acam_ops
from repro.core.crossbar import CrossbarConfig, bit_sliced_matmul
from repro.core.ops import LOGIT_FMT
from repro.core.softmax import acam_softmax as _core_acam_softmax


def lut_ref(x: jax.Array, lut: jax.Array, bias: int = 128) -> jax.Array:
    """Oracle for kernels.acam_lut: plain gather."""
    return jnp.take(lut.astype(jnp.int32), x.astype(jnp.int32) + bias, axis=0)


def mvm_ref(x: jax.Array, w: jax.Array,
            cfg: CrossbarConfig = CrossbarConfig()) -> jax.Array:
    """Oracle for kernels.acam_mvm: core.crossbar bit-sliced matmul."""
    return bit_sliced_matmul(x.astype(jnp.int32), w.astype(jnp.int32), cfg)


def mvm_exact_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return x.astype(jnp.int32) @ w.astype(jnp.int32)


def softmax_codes_ref(x_codes: jax.Array, mode: str = "pot") -> jax.Array:
    """Oracle for kernels.acam_softmax: the core Fig.-8 dataflow on codes."""
    prob_op = acam_ops.get_op("exp_prob")
    x = LOGIT_FMT.decode(x_codes)
    p = _core_acam_softmax(x, axis=-1, mode=mode)
    return prob_op.out_fmt.encode(p)


def softmax_ref(x: jax.Array, mode: str = "pot") -> jax.Array:
    return _core_acam_softmax(x, axis=-1, mode=mode)
