"""jit'd public wrappers over the Pallas kernels.

These are the entry points the serving stack uses on TPU; ``interpret=None``
resolves via `runtime.default_interpret` — kernel bodies execute as traced
jax ops on CPU containers (bit-exact validation against ref.py) and compile
to Mosaic on real TPU backends with no code change.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ops as acam_ops
from repro.core.crossbar import CrossbarConfig
from repro.core.quant import quantize_tensor

from .acam_attention import (  # noqa: F401
    FUSED_SOFTMAX_MODES, acam_attention_codes, acam_attention_decode_codes,
    acam_attention_decode_gqa_codes)
from .acam_lut import acam_lut, acam_lut_2d  # noqa: F401
from .acam_mvm import acam_mvm  # noqa: F401
from .acam_softmax import acam_softmax_codes, acam_softmax_kernel  # noqa: F401
from .runtime import default_interpret  # noqa: F401


def acam_activation(x: jax.Array, name: str = "gelu",
                    interpret: Optional[bool] = None) -> jax.Array:
    """Float tensor through a named Compute-ACAM activation (kernelized)."""
    op = acam_ops.get_op(name)
    codes = op.in_fmt.encode(x)
    out = acam_lut(codes, jnp.asarray(op._lut), bias=1 << (op.in_fmt.bits - 1),
                   interpret=interpret)
    return op.out_fmt.decode(out)


def raceit_linear(x: jax.Array, w: jax.Array,
                  cfg: CrossbarConfig = CrossbarConfig(),
                  interpret: Optional[bool] = None) -> jax.Array:
    """Float linear layer on the kernelized crossbar DPE lane."""
    xq = quantize_tensor(x.astype(jnp.float32), bits=cfg.input_bits)
    wq = quantize_tensor(w.astype(jnp.float32), bits=cfg.weight_bits, axis=1)
    lead = x.shape[:-1]
    y = acam_mvm(xq.codes.reshape(-1, x.shape[-1]), wq.codes, cfg,
                 interpret=interpret)
    return (y.astype(jnp.float32) * (xq.scale * wq.scale)).reshape(*lead, -1)


def prob_requant_scale(cmax: jax.Array) -> jax.Array:
    """The oracle's PROB re-quantization scale (see acam_attention.requant_scale)."""
    from .acam_attention import requant_scale
    return requant_scale(cmax).astype(jnp.float32)


@partial(jax.jit, static_argnames=("softmax_mode", "fold_scale", "causal",
                                   "block_q", "block_k", "interpret"))
def raceit_attention_fused(
    q: jax.Array,  # (B, H, Sq, D) float
    k: jax.Array,  # (B, H, Sk, D) float
    v: jax.Array,  # (B, H, Sk, D) float
    mask: Optional[jax.Array] = None,  # broadcastable to (B, H, Sq, Sk), bool
    softmax_mode: str = "pot",
    q_offset: jax.Array | int = 0,
    fold_scale: bool = False,  # True: 1/sqrt(d) already folded into q
    causal: bool = False,      # in-kernel causal mask (no mask array at all)
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused Fig.-12 attention, float in/out — drop-in for `raceit_attention`.

    Streams over key blocks in one Pallas kernel; the (Sq, Sk) logit and
    probability matrices never exist (pass an in-kernel ``causal`` mask, or
    no mask, to avoid materializing a mask array too). ``softmax_mode``
    accepts "pot", "pot_fine", and "uniform" — every mode the staged path
    takes. Matches the staged `repro.core.attention.raceit_attention` oracle
    to <=1 PROB_FMT ulp (bit-exact on every shape in
    tests/test_attention_fused.py). For the Sq=1 KV-cache serving step use
    `raceit_attention_decode_fused`.
    """
    from .acam_attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    qq = quantize_tensor(q, bits=8)
    kq = quantize_tensor(k, bits=8)
    vq = quantize_tensor(v, bits=8)
    if mask is not None:
        mask = jnp.broadcast_to(mask, (B, H, Sq, Sk)).reshape(B * H, Sq, Sk)
    out32, cmax = acam_attention_codes(
        qq.codes.reshape(B * H, Sq, D), kq.codes.reshape(B * H, Sk, D),
        vq.codes.reshape(B * H, Sk, D), qq.scale * kq.scale, mask,
        q_offset=q_offset, mode=softmax_mode,
        scale_by_sqrt_d=None if fold_scale else D, causal=causal,
        block_q=block_q or DEFAULT_BLOCK_Q, block_k=block_k or DEFAULT_BLOCK_K,
        interpret=interpret)
    p_scale = prob_requant_scale(cmax)
    return (out32.astype(jnp.float32) * (p_scale * vq.scale)
            ).reshape(B, H, Sq, D)


def masked_prefix_quantize(x: jax.Array, kv_len: jax.Array, axis: int = 2):
    """`quantize_tensor(x_sliced_to_kv_len, bits=8)` without slicing.

    Replicates quantize_tensor's exact f32 op sequence on the valid prefix:
    |x| >= 0, so the max over {valid entries} U {zeros} equals the max over
    the slice, and round(x/scale) is elementwise — codes on valid entries are
    bit-identical to quantizing the dynamic slice, while invalid entries are
    zeroed (the kernel masks them out anyway; zeroing keeps the buffer
    contents irrelevant). Returns (codes int8, scale f32) with static shapes.

    ``kv_len`` may be a scalar (one prefix for the whole tensor) or a
    *(B,)* vector of per-row prefixes along the leading batch dim
    (per-request serving): the scale then reduces over the *union* of the
    rows' valid prefixes — one tensor-wide scale, exactly the quantizer
    granularity batched raceit serving already has — and each row's stale
    tail is zeroed/excluded at its own fill level.
    """
    idx = jnp.reshape(jnp.arange(x.shape[axis]),
                      tuple(x.shape[axis] if d == axis else 1
                            for d in range(x.ndim)))
    kvl = jnp.asarray(kv_len, jnp.int32)
    if kvl.ndim == 1:  # per-row prefixes along the leading batch dim
        kvl = kvl.reshape((-1,) + (1,) * (x.ndim - 1))
    valid = idx < kvl
    amax = jnp.max(jnp.where(valid, jnp.abs(x), 0.0))
    scale = (jnp.maximum(amax, 1e-12) / 127).astype(jnp.float32)
    codes = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return jnp.where(valid, codes, 0), scale


def page_valid_lengths(block_table: jax.Array, kv_len: jax.Array,
                       n_pages: int, page_size: int) -> jax.Array:
    """Per-physical-page valid entry counts for a paged KV pool.

    Slot ``b``'s logical page ``j`` holds ``clip(kv_len[b] - j*page_size,
    0, page_size)`` live entries; scatter-maxing those through the block
    table yields, for every physical page, how many of its rows hold live
    cache data. Unmapped pages (never named by any table row with a live
    extent) come out 0, and physical page 0 — the conventional trash page
    dead/unmapped table entries resolve to — is forced to 0 so garbage
    routed there can never look valid.
    """
    bt = jnp.asarray(block_table, jnp.int32)
    kvl = jnp.asarray(kv_len, jnp.int32)
    live = jnp.clip(kvl[:, None] - jnp.arange(bt.shape[1], dtype=jnp.int32)
                    * page_size, 0, page_size)
    pv = jnp.zeros((n_pages,), jnp.int32).at[bt].max(live)
    return pv.at[0].set(0)


def masked_page_quantize(x: jax.Array, page_valid: jax.Array):
    """`masked_prefix_quantize` for a page pool: (n_pages, page_size, ...).

    Same f32 op sequence (max of |x| over valid entries padded with zeros,
    ``max(amax, 1e-12)/127``, elementwise round/clip) with validity given
    per page row by ``page_valid`` (`page_valid_lengths`). Because the pool
    holds exactly the live prefixes' values — scattered into pages — and
    f32 max is order-free, the scale is *bit-identical* to what
    `masked_prefix_quantize` computes on the contiguous layout of the same
    logical contents, and so are the codes on every valid entry. Invalid
    entries (stale pages, tails past each slot's fill, the trash page) are
    zeroed and can never perturb the quantizer.
    """
    idx = jnp.reshape(jnp.arange(x.shape[1]), (1, -1) + (1,) * (x.ndim - 2))
    valid = idx < jnp.reshape(page_valid, (-1,) + (1,) * (x.ndim - 1))
    amax = jnp.max(jnp.where(valid, jnp.abs(x), 0.0))
    scale = (jnp.maximum(amax, 1e-12) / 127).astype(jnp.float32)
    codes = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return jnp.where(valid, codes, 0), scale


def expand_row_lens(kv_len: jax.Array, rep: int) -> jax.Array:
    """Per-request lengths (B,) -> per-group lengths (B*rep,), b-major.

    The single point of truth for how per-request ``kv_len`` vectors map
    onto kernel grid groups: every one of a request's ``rep`` groups (its
    query heads on the flat decode entry, its KV heads on the GQA entry)
    shares the request's fill level. Scalars pass through untouched.
    """
    kvl = jnp.asarray(kv_len, jnp.int32)
    return jnp.repeat(kvl, rep) if kvl.ndim == 1 else kvl


def _decode_quantize_operands(q, k, v, kv_len):
    """Shared decode-wrapper prolog: q whole-tensor int8, k/v valid-prefix
    int8 (the single point of truth for both the flat and GQA wrappers —
    their bit-identical contract starts with identical codes and scales)."""
    return (quantize_tensor(q, bits=8), masked_prefix_quantize(k, kv_len),
            masked_prefix_quantize(v, kv_len))


@partial(jax.jit, static_argnames=("softmax_mode", "fold_scale",
                                   "block_k", "block_g", "interpret"))
def raceit_attention_decode_fused(
    q: jax.Array,   # (B, H, 1, D) float — the new token's query
    k: jax.Array,   # (B, H, Smax, D) float — KV cache buffer (fixed shape)
    v: jax.Array,   # (B, H, Smax, D) float
    kv_len: jax.Array,              # () int32 (>= 1) or (B,) per-request
    softmax_mode: str = "pot",
    fold_scale: bool = False,       # True: 1/sqrt(d) already folded into q
    block_k: int | None = None,
    block_g: int | None = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused decode-step attention over a KV cache, float in/out.

    Bit-exact (to the same <=1 PROB ulp contract as the prefill path) vs the
    staged oracle evaluated on the cache *slice*::

        raceit_attention(q, k[:, :, :kv_len], v[:, :, :kv_len])

    k/v are quantized with `masked_prefix_quantize`, so the tensor scale is
    computed over the valid prefix only — entries past ``kv_len`` (stale or
    zero-initialized cache rows) cannot perturb the quantizer. Partially
    valid key blocks are masked out of the softmax and matmul-2; *fully*
    invalid blocks are skipped outright via scalar-prefetched grid bounds
    (kv_len rides as a `pltpu.PrefetchScalarGridSpec` operand, so their
    k/v tiles are never fetched and their compute is gated off — see
    `acam_attention_codes`).

    This wrapper is what the ExecPlan's ``attention_decode`` slot resolves
    to as the ``raceit_fused`` backend (via `models.layers`); it remains
    directly callable for kernel-level tests and benchmarks.

    A *(B,)* vector ``kv_len`` gives every batch row its own valid prefix
    (per-request serving decode): all H head groups of a row share its
    length, k/v quantizer scales reduce over the union of the rows' valid
    prefixes (one tensor-wide scale, the batched-raceit granularity), and
    zero-length rows output zeros. The ``raceit_fused_rows`` backend is
    this path.
    """
    from .acam_attention import DEFAULT_BLOCK_G, DEFAULT_BLOCK_K
    B, H, Sq, D = q.shape
    Smax = k.shape[2]
    qq, (k_codes, k_scale), (v_codes, v_scale) = \
        _decode_quantize_operands(q, k, v, kv_len)
    kvl = expand_row_lens(kv_len, H)
    out32, cmax = acam_attention_decode_codes(
        qq.codes.reshape(B * H, Sq, D), k_codes.reshape(B * H, Smax, D),
        v_codes.reshape(B * H, Smax, D), qq.scale * k_scale,
        kvl, mode=softmax_mode,
        scale_by_sqrt_d=None if fold_scale else D,
        block_k=block_k or DEFAULT_BLOCK_K, block_g=block_g or DEFAULT_BLOCK_G,
        interpret=interpret)
    p_scale = prob_requant_scale(cmax)
    return (out32.astype(jnp.float32) * (p_scale * v_scale)
            ).reshape(B, H, Sq, D)


def _paged_quantize_operands(q, k_pool, v_pool, block_table, kv_len):
    """Paged decode-wrapper prolog: q whole-tensor int8, pooled k/v per-page
    int8 with scales over the union of live page entries — bit-identical to
    `_decode_quantize_operands` on the contiguous gather of the same table
    (the paged wrappers' parity contract starts here)."""
    pv = page_valid_lengths(block_table, kv_len,
                            k_pool.shape[0], k_pool.shape[1])
    return (quantize_tensor(q, bits=8), masked_page_quantize(k_pool, pv),
            masked_page_quantize(v_pool, pv))


@partial(jax.jit, static_argnames=("softmax_mode", "fold_scale",
                                   "block_k", "block_g", "interpret"))
def raceit_attention_decode_paged(
    q: jax.Array,       # (B, H, Sq, D) float — Sq=1 decode or Sq=C chunk
    k_pool: jax.Array,  # (n_pages, page_size, KV, D) float — the page pool
    v_pool: jax.Array,  # (n_pages, page_size, KV, D) float
    kv_len: jax.Array,              # (B,) int32 per-slot fill levels
    block_table: jax.Array,         # (B, max_pages) int32; 0 = trash page
    mask: Optional[jax.Array] = None,  # (B, Sq, max_pages*page_size) bool
    softmax_mode: str = "pot",
    fold_scale: bool = False,
    block_k: int | None = None,
    block_g: int | None = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused attention over a block-paged KV pool, float in/out.

    The paged twin of `raceit_attention_decode_fused`: instead of one
    contiguous ``(B, H, Smax, D)`` cache buffer, k/v live in a shared page
    *pool* — ``n_pages`` pages of ``page_size`` cache rows each, stored
    once per KV head — and ``block_table[b, j]`` names the physical page
    backing slot ``b``'s logical page ``j``. The kernel reads tiles
    through the table (a third scalar-prefetch operand consumed only by
    the k/v index maps), so the logical key extent is
    ``max_pages * page_size`` and memory scales with pages *allocated*,
    not ``Smax x slots``. Bit-identical to the contiguous wrapper on the
    gathered layout of the same table: per-page quantizer scales reduce
    over the same union of live prefixes (`masked_page_quantize`), and
    page indirection moves only the DMA source of each key tile.

    ``Sq > 1`` is the *chunked-prefill* call: the ``Sq`` queries of a
    prompt chunk attend the slot's pages through the same executable, with
    ``mask`` carrying the intra-chunk causal rule (query row ``j`` sees
    columns ``< chunk_off + j + 1``); rows masked to nothing output zeros.
    KV heads are repeated to H in int8 codes (the flat grid layout); the
    decode hot loop should prefer `raceit_attention_decode_gqa_paged` when
    ``n_kv_heads < n_heads``.
    """
    from .acam_attention import DEFAULT_BLOCK_G, DEFAULT_BLOCK_K
    B, H, Sq, D = q.shape
    n_pages, ps, KV, hd = k_pool.shape
    rep = H // KV
    qq, (k_codes, k_scale), (v_codes, v_scale) = \
        _paged_quantize_operands(q, k_pool, v_pool, block_table, kv_len)
    # flat grid layout: groups are query heads, so each physical page's
    # stripe row page*H + h holds KV head h//rep (codes repeated, pool not)
    to_rows = lambda c: jnp.repeat(c, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(n_pages * H, ps, hd)
    if mask is not None:
        Sk = block_table.shape[1] * ps
        mask = jnp.broadcast_to(mask[:, None], (B, H, Sq, Sk)) \
            .reshape(B * H, Sq, Sk)
    out32, cmax = acam_attention_codes(
        qq.codes.reshape(B * H, Sq, D), to_rows(k_codes), to_rows(v_codes),
        qq.scale * k_scale, mask, kv_len=expand_row_lens(kv_len, H),
        mode=softmax_mode, scale_by_sqrt_d=None if fold_scale else D,
        block_k=block_k or DEFAULT_BLOCK_K, block_g=block_g or DEFAULT_BLOCK_G,
        interpret=interpret, block_table=block_table, page_size=ps,
        groups_per_slot=H)
    p_scale = prob_requant_scale(cmax)
    return (out32.astype(jnp.float32) * (p_scale * v_scale)
            ).reshape(B, H, Sq, D)


@partial(jax.jit, static_argnames=("softmax_mode", "fold_scale",
                                   "block_k", "block_g", "interpret"))
def raceit_attention_decode_gqa_paged(
    q: jax.Array,       # (B, H, 1, D) float — the new token's queries
    k_pool: jax.Array,  # (n_pages, page_size, KV, D) float — the page pool
    v_pool: jax.Array,  # (n_pages, page_size, KV, D) float
    kv_len: jax.Array,              # (B,) int32 per-slot fill levels
    block_table: jax.Array,         # (B, max_pages) int32; 0 = trash page
    mask: Optional[jax.Array] = None,  # (B, 1, max_pages*page_size) bool
    softmax_mode: str = "pot",
    fold_scale: bool = False,
    block_k: int | None = None,
    block_g: int | None = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """GQA-native fused decode over a block-paged KV pool, float in/out.

    `raceit_attention_decode_gqa` with page-table indirection: the pool
    keeps KV heads native (never repeated, as floats or codes — each
    physical page's stripe row ``page*KV + kvh`` is KV head ``kvh``), the
    grid's group dimension iterates B*KV KV-head groups with the ``rep``
    sharing queries riding the tile's row dim, and the block table routes
    each logical key tile to its physical page. Bit-identical to
    `raceit_attention_decode_paged` on the same pool (repeat commutes with
    everything after quantization) and hence to the contiguous wrappers on
    the gathered layout. Decode-only (Sq=1): chunk calls take the flat
    paged entry, whose row dim is free for chunk positions.
    """
    from .acam_attention import DEFAULT_BLOCK_G, DEFAULT_BLOCK_K
    B, H, Sq, D = q.shape
    n_pages, ps, KV, hd = k_pool.shape
    if Sq != 1:
        raise ValueError(f"decode path expects Sq=1, got {Sq}")
    if H % KV:
        raise ValueError(f"n_heads={H} not a multiple of n_kv_heads={KV}")
    rep = H // KV
    qq, (k_codes, k_scale), (v_codes, v_scale) = \
        _paged_quantize_operands(q, k_pool, v_pool, block_table, kv_len)
    to_rows = lambda c: c.transpose(0, 2, 1, 3).reshape(n_pages * KV, ps, hd)
    if mask is not None:
        Sk = block_table.shape[1] * ps
        mask = jnp.broadcast_to(mask[:, None], (B, KV, rep, Sk)) \
            .reshape(B * KV, rep, Sk)
    out32, cmax = acam_attention_decode_gqa_codes(
        qq.codes.reshape(B, KV, rep, D).reshape(B * KV, rep, D),
        to_rows(k_codes), to_rows(v_codes), qq.scale * k_scale,
        expand_row_lens(kv_len, KV), mask=mask,
        mode=softmax_mode, scale_by_sqrt_d=None if fold_scale else D,
        block_k=block_k or DEFAULT_BLOCK_K, block_g=block_g or DEFAULT_BLOCK_G,
        interpret=interpret, block_table=block_table, page_size=ps,
        groups_per_slot=KV)
    p_scale = prob_requant_scale(cmax)
    return (out32.astype(jnp.float32) * (p_scale * v_scale)
            ).reshape(B, H, Sq, D)


# ---------------------------------------------------------------------------
# tensor-parallel quantizer twins (used inside repro.dist.shard_map bodies
# by the exec/sharded.py backends)
# ---------------------------------------------------------------------------
# Each is the same f32 op sequence as its single-device twin above with one
# change: the local |x| max is `jax.lax.pmax`-ed over the mesh axis before
# the shared scale formula. f32 max is order-free, so the globalized amax —
# and therefore the scale and every code — is bit-identical to what the
# unsharded twin computes on the gathered tensor.

def tp_quantize_tensor(x: jax.Array, axis_name: str):
    """`quantize_tensor(x, bits=8)` inside a shard_map body, scale global."""
    from repro.core.quant import QuantizedTensor
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127
    codes = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return QuantizedTensor(codes, scale.astype(jnp.float32), 8)


def tp_masked_prefix_quantize(x: jax.Array, kv_len: jax.Array,
                              axis_name: str, axis: int = 2):
    """`masked_prefix_quantize` with the amax pmax-ed over the mesh axis."""
    idx = jnp.reshape(jnp.arange(x.shape[axis]),
                      tuple(x.shape[axis] if d == axis else 1
                            for d in range(x.ndim)))
    kvl = jnp.asarray(kv_len, jnp.int32)
    if kvl.ndim == 1:
        kvl = kvl.reshape((-1,) + (1,) * (x.ndim - 1))
    valid = idx < kvl
    amax = jax.lax.pmax(jnp.max(jnp.where(valid, jnp.abs(x), 0.0)), axis_name)
    scale = (jnp.maximum(amax, 1e-12) / 127).astype(jnp.float32)
    codes = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return jnp.where(valid, codes, 0), scale


def tp_masked_page_quantize(x: jax.Array, page_valid: jax.Array,
                            axis_name: str):
    """`masked_page_quantize` with the amax pmax-ed over the mesh axis."""
    idx = jnp.reshape(jnp.arange(x.shape[1]), (1, -1) + (1,) * (x.ndim - 2))
    valid = idx < jnp.reshape(page_valid, (-1,) + (1,) * (x.ndim - 1))
    amax = jax.lax.pmax(jnp.max(jnp.where(valid, jnp.abs(x), 0.0)), axis_name)
    scale = (jnp.maximum(amax, 1e-12) / 127).astype(jnp.float32)
    codes = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return jnp.where(valid, codes, 0), scale


def tp_exact_call(call, axis_name: str):
    """The probe -> pmax -> exact protocol for a tensor-parallel kernel call.

    ``call(cmax_floor)`` must run one of the ``acam_attention*_codes``
    entries on this shard's groups and return its (out32, cmax). The probe
    call (floor 0 — the exact-identity seed) yields the shard's local max
    PROB code; `jax.lax.pmax` over the mesh axis turns it into the global
    one (integer max is order-free); and the second call re-runs the shard
    with the global floor, so every shard re-quantizes PROB with the same
    table the unsharded kernel would have used — the returned cmax *is*
    the global cmax on every shard, and the sharded output is bit-identical
    to the single-device call on the gathered operands.
    """
    _, local_cmax = call(jnp.zeros((), jnp.int32))
    return call(jax.lax.pmax(local_cmax, axis_name))


@partial(jax.jit, static_argnames=("softmax_mode", "fold_scale",
                                   "block_k", "block_g", "interpret"))
def raceit_attention_decode_gqa(
    q: jax.Array,   # (B, H, 1, D) float — the new token's queries, all heads
    k: jax.Array,   # (B, KV, Smax, D) float — native-layout KV cache buffer
    v: jax.Array,   # (B, KV, Smax, D) float
    kv_len: jax.Array,              # () int32 (>= 1) or (B,) per-request
    softmax_mode: str = "pot",
    fold_scale: bool = False,       # True: 1/sqrt(d) already folded into q
    block_k: int | None = None,
    block_g: int | None = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """GQA-native fused decode attention, float in/out.

    Takes the KV cache in its *native* grouped layout — KV heads are never
    repeated to H, neither as floats nor as int8 codes — and hands the
    kernel (`acam_attention_decode_gqa_codes`) one group per KV head with
    the ``rep = H/KV`` sharing queries riding the tile's row dimension, so
    each KV tile is fetched once per group. Bit-identical to
    `raceit_attention_decode_fused` on ``jnp.repeat(k, rep, axis=1)`` (and
    hence bit-exact vs the staged oracle on the cache slice, to the same
    <=1 PROB ulp contract): the repeated tensor has the same max-abs as the
    native one, so quantizer scales, codes, per-row PoT sums, and the
    global cmax are all unchanged — only the dataflow is.

    At rep=1 (MHA) the two entries coincide; the ExecPlan only resolves
    ``raceit_gqa_native`` when ``n_kv_heads < n_heads``.

    A *(B,)* vector ``kv_len`` gives every batch row its own valid prefix
    (per-request serving decode, the ``raceit_gqa_rows`` backend): all KV
    groups of a row share its length, scales reduce over the union of
    valid prefixes, zero-length rows output zeros.
    """
    from .acam_attention import DEFAULT_BLOCK_G, DEFAULT_BLOCK_K
    B, H, Sq, D = q.shape
    KV, Smax = k.shape[1], k.shape[2]
    if Sq != 1:
        raise ValueError(f"decode path expects Sq=1, got {Sq}")
    if H % KV:
        raise ValueError(f"n_heads={H} not a multiple of n_kv_heads={KV}")
    rep = H // KV
    qq, (k_codes, k_scale), (v_codes, v_scale) = \
        _decode_quantize_operands(q, k, v, kv_len)
    kvl = expand_row_lens(kv_len, KV)
    out32, cmax = acam_attention_decode_gqa_codes(
        qq.codes.reshape(B, KV, rep, D).reshape(B * KV, rep, D),
        k_codes.reshape(B * KV, Smax, D), v_codes.reshape(B * KV, Smax, D),
        qq.scale * k_scale, kvl,
        mode=softmax_mode, scale_by_sqrt_d=None if fold_scale else D,
        block_k=block_k or DEFAULT_BLOCK_K, block_g=block_g or DEFAULT_BLOCK_G,
        interpret=interpret)
    p_scale = prob_requant_scale(cmax)
    return (out32.astype(jnp.float32) * (p_scale * v_scale)
            ).reshape(B, H, Sq, D)
