"""jit'd public wrappers over the Pallas kernels.

These are the entry points the serving stack uses on TPU; ``interpret=None``
resolves via `runtime.default_interpret` — kernel bodies execute as traced
jax ops on CPU containers (bit-exact validation against ref.py) and compile
to Mosaic on real TPU backends with no code change.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ops as acam_ops
from repro.core.crossbar import CrossbarConfig
from repro.core.quant import quantize_tensor

from .acam_attention import acam_attention_codes  # noqa: F401
from .acam_lut import acam_lut, acam_lut_2d  # noqa: F401
from .acam_mvm import acam_mvm  # noqa: F401
from .acam_softmax import acam_softmax_codes, acam_softmax_kernel  # noqa: F401
from .runtime import default_interpret  # noqa: F401


def acam_activation(x: jax.Array, name: str = "gelu",
                    interpret: Optional[bool] = None) -> jax.Array:
    """Float tensor through a named Compute-ACAM activation (kernelized)."""
    op = acam_ops.get_op(name)
    codes = op.in_fmt.encode(x)
    out = acam_lut(codes, jnp.asarray(op._lut), bias=1 << (op.in_fmt.bits - 1),
                   interpret=interpret)
    return op.out_fmt.decode(out)


def raceit_linear(x: jax.Array, w: jax.Array,
                  cfg: CrossbarConfig = CrossbarConfig(),
                  interpret: Optional[bool] = None) -> jax.Array:
    """Float linear layer on the kernelized crossbar DPE lane."""
    xq = quantize_tensor(x.astype(jnp.float32), bits=cfg.input_bits)
    wq = quantize_tensor(w.astype(jnp.float32), bits=cfg.weight_bits, axis=1)
    lead = x.shape[:-1]
    y = acam_mvm(xq.codes.reshape(-1, x.shape[-1]), wq.codes, cfg,
                 interpret=interpret)
    return (y.astype(jnp.float32) * (xq.scale * wq.scale)).reshape(*lead, -1)


def prob_requant_scale(cmax: jax.Array) -> jax.Array:
    """The oracle's PROB re-quantization scale (see acam_attention.requant_scale)."""
    from .acam_attention import requant_scale
    return requant_scale(cmax).astype(jnp.float32)


@partial(jax.jit, static_argnames=("softmax_mode", "fold_scale", "causal",
                                   "block_q", "block_k", "interpret"))
def raceit_attention_fused(
    q: jax.Array,  # (B, H, Sq, D) float
    k: jax.Array,  # (B, H, Sk, D) float
    v: jax.Array,  # (B, H, Sk, D) float
    mask: Optional[jax.Array] = None,  # broadcastable to (B, H, Sq, Sk), bool
    softmax_mode: str = "pot",
    q_offset: jax.Array | int = 0,
    fold_scale: bool = False,  # True: 1/sqrt(d) already folded into q
    causal: bool = False,      # in-kernel causal mask (no mask array at all)
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused Fig.-12 attention, float in/out — drop-in for `raceit_attention`.

    Streams over key blocks in one Pallas kernel; the (Sq, Sk) logit and
    probability matrices never exist (pass an in-kernel ``causal`` mask, or
    no mask, to avoid materializing a mask array too). Matches the staged
    `repro.core.attention.raceit_attention` oracle to <=1 PROB_FMT ulp
    (bit-exact on every shape in tests/test_attention_fused.py).
    """
    from .acam_attention import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    qq = quantize_tensor(q, bits=8)
    kq = quantize_tensor(k, bits=8)
    vq = quantize_tensor(v, bits=8)
    if mask is not None:
        mask = jnp.broadcast_to(mask, (B, H, Sq, Sk)).reshape(B * H, Sq, Sk)
    out32, cmax = acam_attention_codes(
        qq.codes.reshape(B * H, Sq, D), kq.codes.reshape(B * H, Sk, D),
        vq.codes.reshape(B * H, Sk, D), qq.scale * kq.scale, mask,
        q_offset=q_offset, mode=softmax_mode,
        scale_by_sqrt_d=None if fold_scale else D, causal=causal,
        block_q=block_q or DEFAULT_BLOCK_Q, block_k=block_k or DEFAULT_BLOCK_K,
        interpret=interpret)
    p_scale = prob_requant_scale(cmax)
    return (out32.astype(jnp.float32) * (p_scale * vq.scale)
            ).reshape(B, H, Sq, D)
