"""jit'd public wrappers over the Pallas kernels.

These are the entry points the serving stack uses on TPU; `interpret=True`
(the default in this CPU container) executes the kernel bodies in Python for
bit-exact validation against ref.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import ops as acam_ops
from repro.core.crossbar import CrossbarConfig
from repro.core.quant import quantize_tensor

from .acam_lut import acam_lut, acam_lut_2d  # noqa: F401
from .acam_mvm import acam_mvm  # noqa: F401
from .acam_softmax import acam_softmax_codes, acam_softmax_kernel  # noqa: F401


def acam_activation(x: jax.Array, name: str = "gelu",
                    interpret: bool = True) -> jax.Array:
    """Float tensor through a named Compute-ACAM activation (kernelized)."""
    op = acam_ops.get_op(name)
    codes = op.in_fmt.encode(x)
    out = acam_lut(codes, jnp.asarray(op._lut), bias=1 << (op.in_fmt.bits - 1),
                   interpret=interpret)
    return op.out_fmt.decode(out)


def raceit_linear(x: jax.Array, w: jax.Array,
                  cfg: CrossbarConfig = CrossbarConfig(),
                  interpret: bool = True) -> jax.Array:
    """Float linear layer on the kernelized crossbar DPE lane."""
    xq = quantize_tensor(x.astype(jnp.float32), bits=cfg.input_bits)
    wq = quantize_tensor(w.astype(jnp.float32), bits=cfg.weight_bits, axis=1)
    lead = x.shape[:-1]
    y = acam_mvm(xq.codes.reshape(-1, x.shape[-1]), wq.codes, cfg,
                 interpret=interpret)
    return (y.astype(jnp.float32) * (xq.scale * wq.scale)).reshape(*lead, -1)
