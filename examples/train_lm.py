"""Distributed training driver with the fault-tolerant loop.

CPU demo (default): a few-M-param model, a few hundred steps, checkpointing +
auto-resume exercised for real. `--preset cluster` selects the ~100M-param
configuration this driver runs on a real pod (same code path; the 40-cell
dry-run proves the sharded train_step compiles at 512 chips).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
Kill it mid-run and re-run: it resumes from the latest checkpoint.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import Model
from repro.train import TrainLoopConfig, optim, run_training, trainer

PRESETS = {
    # ~3M params: CPU-friendly "few hundred steps" demo
    "cpu": dict(n_layers=4, d_model=192, n_heads=6, n_kv_heads=6, d_ff=768,
                vocab_size=512, seq=128, batch=8),
    # ~100M params: the e2e config for real hardware (also dry-run-proven)
    "cluster": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                    d_ff=3072, vocab_size=32_768, seq=1024, batch=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", choices=PRESETS, default="cpu")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = get_config("gpt2-large").replace(
        name=f"train-lm-{args.preset}", n_layers=p["n_layers"],
        d_model=p["d_model"], n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab_size"], pos_emb="rope",
        norm="rmsnorm", glu=True, qkv_bias=False, tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32", remat="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n/1e6:.1f}M params | preset={args.preset}")

    data = SyntheticLM(vocab_size=p["vocab_size"], seq_len=p["seq"],
                       global_batch=p["batch"], seed=11)
    opt_cfg = optim.AdamWConfig(
        lr=3e-4, schedule=optim.warmup_cosine(50, args.steps))
    step = jax.jit(trainer.make_train_step(model, opt_cfg))
    opt_state = optim.adamw_init(params)

    loop_cfg = TrainLoopConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
        log_every=10, metrics_path=f"{args.ckpt_dir}/metrics.csv")
    params, opt_state, out = run_training(
        step, params, opt_state, data, loop_cfg,
        make_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()})
    hist = out["history"]
    if hist:
        print(f"done: step {out['final_step']}  loss "
              f"{hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}  "
              f"stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()
