"""End-to-end driver (the paper is inference acceleration): train a small LM,
then SERVE batched requests through the RACE-IT analog-faithful path and
compare against the digital baseline.

Run:  PYTHONPATH=src python examples/raceit_serve.py [--steps 300]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.data import SyntheticLM
from repro.launch.serve import parse_exec_plan
from repro.models import Model
from repro.serve import BatchScheduler, GenerationEngine, Request
from repro.train import optim, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--exec-plan", nargs="*", default=[], metavar="SLOT=BACKEND",
                    help="pin raceit op slots to named backends, e.g. "
                         "--exec-plan attention_decode=raceit_staged "
                         "(see repro.exec.registry.OP_SLOTS)")
    ap.add_argument("--noise", default=None, metavar="PRESET|SIGMA",
                    help="run the raceit arm on device-varied arrays: a "
                         "repro.hw.noise preset (clean/nominal/worst_case) "
                         "or a float scale of the nominal profile")
    ap.add_argument("--mesh", default=None, metavar="AXES",
                    help="run the raceit arm tensor-parallel on a device "
                         "mesh, e.g. --mesh model=4 (needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=4 on CPU); "
                         "decode resolves to the raceit_*_tp backends and "
                         "stays token-identical to the single-device arm")
    args = ap.parse_args()
    overrides = parse_exec_plan(args.exec_plan)
    noise = None
    if args.noise is not None:
        from repro.hw.noise import NoiseConfig
        noise = NoiseConfig.parse(args.noise)
    mesh = None
    if args.mesh is not None:
        from repro.dist import MeshSpec
        mesh = MeshSpec.parse(args.mesh)

    cfg = get_config("gpt2-large").replace(
        name="serve-demo", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=128, pos_emb="rope", norm="rmsnorm", glu=False,
        qkv_bias=False, param_dtype="float32", compute_dtype="float32",
        remat="none", tie_embeddings=True)
    data = SyntheticLM(vocab_size=128, seq_len=64, global_batch=16, seed=3)

    print(f"[1/3] training a {sum(p.size for p in jax.tree.leaves(Model(cfg).init(jax.random.PRNGKey(0))))/1e6:.2f}M-param LM "
          f"for {args.steps} steps ...")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(trainer.make_train_step(
        model, optim.AdamWConfig(lr=1e-3,
                                 schedule=optim.warmup_cosine(20, args.steps))))
    opt_state = optim.adamw_init(params)
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, b)
    print(f"      final loss {float(m['loss']):.3f}")

    print("[2/3] serving batched requests (digital vs RACE-IT)...")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, rng.integers(4, 9)).astype(np.int32)
               for _ in range(args.requests)]
    outs = {}
    # ExecConfig.serving: the serving default resolves the attention slots
    # to the fused streaming kernel on both prefill and the per-token
    # decode steps; --exec-plan pins slots to other named backends
    for mode, ec in (("digital", ExecConfig()),
                     ("raceit", ExecConfig.serving(softmax_mode="pot",
                                                   op_overrides=overrides,
                                                   noise=noise, mesh=mesh))):
        eng = GenerationEngine(cfg, params, exec_cfg=ec, max_len=64)
        print(f"      {mode} plan: " + "; ".join(
            f"{op.slot}={op.backend}" for op in eng.plan.ops
            if op.slot.startswith("attention") or op.slot == "lm_head"))
        sched = BatchScheduler(eng, bucket_size=4)
        for rid, p in enumerate(prompts):
            sched.submit(Request(rid, p, n_new=8))
        t0 = time.perf_counter()
        done = sched.run_all()
        dt = time.perf_counter() - t0
        outs[mode] = done
        total_toks = sum(len(r.result) for r in done.values())
        print(f"      {mode:8s}: {total_toks} tokens in {dt:.2f}s "
              f"({total_toks/dt:.1f} tok/s on 1 CPU core)")

    print("[3/3] digital vs RACE-IT generations:")
    agree = 0
    for rid in sorted(outs["digital"]):
        d = outs["digital"][rid].result
        r = outs["raceit"][rid].result
        agree += int((d == r).sum())
        print(f"   req{rid}: digital {d.tolist()}  raceit {r.tolist()}")
    n = sum(len(outs['digital'][r].result) for r in outs['digital'])
    print(f"   token agreement: {agree}/{n} "
          f"(quantized analog path vs fp32; paper reports ~0.2% task-level drop)")


if __name__ == "__main__":
    main()
