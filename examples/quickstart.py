"""Quickstart: compile arbitrary functions onto Compute-ACAM and use them.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import (AcamFunction, FixedPointFormat, acam_softmax,
                        bit_sliced_matmul, mult8_codes, softmax_reference)
from repro.core.acam import Acam2VarFunction


def main():
    # 1. Compile GeLU onto an ACAM array (paper Fig. 4): ranges per output bit
    fmt = FixedPointFormat(int_bits=0, frac_bits=3)  # the paper's 1-0-3
    gelu = AcamFunction.compile(
        "gelu", lambda x: 0.5 * x * (1 + np.tanh(0.7978845608 *
                                                 (x + 0.044715 * x ** 3))),
        fmt, fmt, encode=False)
    print("4-bit GeLU ranges per output bit (MSB first):")
    for i, ranges in enumerate(gelu.program.ranges):
        print(f"  bit{3 - i}: {ranges}")
    print(f"  -> {gelu.cost.num_cells} cells, {gelu.program.rows_needed()} "
          f"ML rows (vs 2^4 entries in a look-up memory)")

    # 2. The reconfigurability claim: ANY scalar op is one compile away
    swish_beta2 = AcamFunction.compile(
        "swish_b2", lambda x: x / (1 + np.exp(-2 * x)),
        FixedPointFormat(int_bits=2, frac_bits=5),
        FixedPointFormat(int_bits=2, frac_bits=5))
    x = jnp.linspace(-3, 3, 7)
    print("\nfuture-operator demo  swish(beta=2):", np.round(swish_beta2(x), 3))

    # 3. 8-bit multiply from four 4-bit nibble tables (paper §IV-B)
    a, b = jnp.asarray([[-37]]), jnp.asarray([[91]])
    print(f"\nACAM 8-bit multiply: -37 * 91 = {int(mult8_codes(a, b)[0, 0])}")

    # 4. Bit-sliced crossbar MVM == integer matmul (ideal ADC)
    rng = np.random.default_rng(0)
    xq = jnp.asarray(rng.integers(-128, 128, (2, 300)), jnp.int32)
    wq = jnp.asarray(rng.integers(-128, 128, (300, 4)), jnp.int32)
    assert (np.asarray(bit_sliced_matmul(xq, wq)) ==
            np.asarray(xq) @ np.asarray(wq)).all()
    print("bit-sliced crossbar MVM: exact ✓")

    # 5. The Fig. 8 softmax dataflow (exp -> sum -> log -> sub -> exp)
    logits = jnp.asarray(rng.normal(0, 2, (2, 8)), jnp.float32)
    print("\nACAM softmax (PoT)   :", np.round(acam_softmax(logits)[0], 3))
    print("float softmax        :", np.round(softmax_reference(logits)[0], 3))
    print("ACAM softmax (uniform-exp ablation, collapses):",
          np.round(acam_softmax(logits, mode='uniform')[0], 3))


if __name__ == "__main__":
    main()
