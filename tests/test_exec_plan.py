"""RaceOp registry + ExecPlan resolution: the single dispatch API.

Covers the plan-resolution contract:

* every (mode x softmax_mode x fidelity x fused) combo resolves
  deterministically and **never raises** — unsupported combos degrade with
  a structured reason on the plan;
* per-op overrides (``ExecConfig.op_overrides`` / ``with_ops``) are
  honored, including degrade-on-unknown-backend;
* plan-dispatched layer outputs are bit-identical to calling the
  underlying staged/fused implementations directly (the pre-plan code
  paths, which now live as the registered backends);
* the lm head routes through the plan (act_bits honored for resident
  weights — the old code rebuilt a bare ``ExecConfig(mode="raceit")``).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ExecConfig, ModelConfig
from repro.exec import (OP_SLOTS, ExecPlan, as_plan, list_backends,
                        reset_plan_cache, resolve_plan)
from repro.models import layers

MODES = ("digital", "raceit")
SOFTMAX_MODES = ("pot", "pot_fine", "uniform")
FIDELITIES = ("int", "acam")


def _cfg(**kw):
    base = dict(name="t", n_layers=1, d_model=32, n_heads=4, n_kv_heads=2,
                d_ff=64, vocab_size=64, param_dtype="float32",
                compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# resolution matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [False, True])
@pytest.mark.parametrize("fidelity", FIDELITIES)
@pytest.mark.parametrize("softmax_mode", SOFTMAX_MODES)
@pytest.mark.parametrize("mode", MODES)
def test_every_combo_resolves_deterministically(mode, softmax_mode, fidelity,
                                                fused):
    ec = ExecConfig(mode=mode, softmax_mode=softmax_mode,
                    matmul_fidelity=fidelity, fused_attention=fused)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # fused degrades may warn once
        plan = resolve_plan(_cfg(), ec)
        again = resolve_plan(_cfg(), ec)
    assert isinstance(plan, ExecPlan)
    assert plan is again  # cached => trivially deterministic
    assert [op.slot for op in plan.ops] == list(OP_SLOTS)
    chosen = {op.slot: op.backend for op in plan.ops}
    if mode == "digital":
        assert chosen["attention_prefill"] == "digital"
        assert chosen["matmul"] == "digital"
        assert chosen["dd_matmul"] == "int"
    else:
        assert chosen["matmul"] == "raceit_int"
        assert chosen["activation"] == "raceit_lut"
        assert chosen["softmax"] == "raceit_acam"
        assert chosen["dd_matmul"] == fidelity
        want_attn = ("raceit_fused" if fused and fidelity == "int"
                     else "raceit_staged")
        assert chosen["attention_prefill"] == want_attn
        # _cfg() is a GQA config (n_kv_heads=2 < n_heads=4): a supported
        # fused decode resolves to the block-paged GQA-native kernel
        # (paged backends also serve contiguous caches — block_table=None
        # falls through to the per-row path)
        want_dec = ("raceit_gqa_paged" if fused and fidelity == "int"
                    else want_attn)
        assert chosen["attention_decode"] == want_dec
    # explain() renders every slot and never raises
    text = plan.explain()
    for slot in OP_SLOTS:
        assert slot in text


def test_unsupported_fused_degrades_with_structured_reason():
    reset_plan_cache()
    ec = ExecConfig(mode="raceit", fused_attention=True,
                    matmul_fidelity="acam")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        plan = resolve_plan(_cfg(), ec)
        resolve_plan(_cfg(), ec)  # cached: no second warning
    op = plan.op("attention_decode")
    assert op.backend == "raceit_staged"
    # decode's preference head is the paged GQA-native kernel; the whole
    # fused family is rejected by the same fidelity reason
    assert op.requested == "raceit_gqa_paged"
    assert "acam" in op.reason
    for name in ("raceit_gqa_paged", "raceit_gqa_rows", "raceit_gqa_native",
                 "raceit_fused_paged", "raceit_fused_rows", "raceit_fused"):
        assert any(d.slot == "attention_decode" and d.requested == name
                   and d.chosen == "raceit_staged" for d in plan.degrades)
    msgs = [x for x in w if issubclass(x.category, RuntimeWarning)
            and "fused_attention" in str(x.message)]
    assert len(msgs) == 1, [str(x.message) for x in w]
    assert "acam" in plan.explain()


def test_unknown_mode_degrades_to_digital():
    plan = resolve_plan(_cfg(), ExecConfig(mode="analog_dreams"))
    assert all(op.backend in ("digital", "int") for op in plan.ops)
    assert any("unknown mode" in d.reason for d in plan.degrades)


# ---------------------------------------------------------------------------
# per-op overrides
# ---------------------------------------------------------------------------

def test_op_overrides_pin_backends():
    ec = ExecConfig(mode="raceit", fused_attention=True).with_ops(
        attention_decode="raceit_staged", lm_head="raceit_q8")
    plan = resolve_plan(_cfg(), ec)
    assert plan.backend("attention_decode") == "raceit_staged"
    assert plan.backend("attention_prefill") == "raceit_fused"  # untouched
    assert plan.backend("lm_head") == "raceit_q8"


def test_with_ops_later_pins_win():
    ec = ExecConfig(mode="raceit").with_ops(lm_head="raceit_q8")
    ec = ec.with_ops(lm_head="digital")
    assert resolve_plan(_cfg(), ec).backend("lm_head") == "digital"


def test_unknown_backend_override_degrades_not_raises():
    ec = ExecConfig(mode="raceit").with_ops(attention_decode="warp_drive")
    plan = resolve_plan(_cfg(), ec)
    op = plan.op("attention_decode")
    assert op.backend == "raceit_staged"  # fell through to the default chain
    assert op.requested == "warp_drive"
    assert "no backend" in op.reason


def test_unknown_slot_override_recorded_not_raised():
    ec = ExecConfig(mode="raceit",
                    op_overrides=(("flux_capacitor", "digital"),))
    plan = resolve_plan(_cfg(), ec)
    assert any(d.slot == "flux_capacitor" and "unknown op slot" in d.reason
               for d in plan.degrades)
    # a typo'd --exec-plan slot must be *visible* in the startup table, not
    # silently ignored (the CLI help promises "the plan table says why")
    assert "flux_capacitor" in plan.explain()
    assert "unknown op slot" in plan.explain()


def test_registry_lists_expected_backends():
    resolve_plan(_cfg(), ExecConfig())  # force backend registration import
    names = {slot: set(b) for slot, b in list_backends().items()}
    assert {"digital", "raceit_int"} <= names["matmul"]
    assert {"digital", "raceit_staged", "raceit_fused"} <= names[
        "attention_prefill"]
    assert {"digital", "raceit_staged", "raceit_fused",
            "raceit_fused_rows", "raceit_gqa_rows",
            "raceit_fused_paged", "raceit_gqa_paged"} <= names[
        "attention_decode"]
    assert {"int", "acam"} <= names["dd_matmul"]
    assert {"digital", "raceit_q8"} <= names["lm_head"]


# ---------------------------------------------------------------------------
# dispatch parity: plan methods == the underlying implementations
# ---------------------------------------------------------------------------

def _attn_inputs(rng, cfg, B=2, S=24):
    p = layers.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    return p, x, pos


@pytest.mark.parametrize("mode", MODES)
def test_layer_attention_accepts_config_or_plan(rng, mode):
    """layers.attention(plan=ExecConfig) == layers.attention(plan=ExecPlan)."""
    cfg = _cfg()
    p, x, pos = _attn_inputs(rng, cfg)
    ec = ExecConfig(mode=mode)
    a, _ = layers.attention(p, x, cfg=cfg, plan=ec, positions=pos)
    b, _ = layers.attention(p, x, cfg=cfg, plan=as_plan(cfg, ec),
                            positions=pos)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_staged_prefill_parity_with_direct_oracle_call(rng):
    """Plan-dispatched staged attention == _raceit_staged_attention direct."""
    cfg = _cfg()
    p, x, pos = _attn_inputs(rng, cfg)
    plan = resolve_plan(cfg, ExecConfig(mode="raceit"))
    got, _ = layers.attention(p, x, cfg=cfg, plan=plan, positions=pos)

    # rebuild the projections exactly as the layer does, then call the
    # staged implementation directly with the causal mask
    q = plan.matmul(x, p["wq"])
    k = plan.matmul(x, p["wk"])
    v = plan.matmul(x, p["wv"])
    q, k = layers.apply_rope(q, pos, cfg), layers.apply_rope(k, pos, cfg)
    S = x.shape[1]
    mask = jnp.broadcast_to(
        jnp.arange(S)[None, :] <= jnp.arange(S)[:, None], (2, S, S))
    import math
    o = layers._raceit_staged_attention(q, k, v, mask,
                                        1.0 / math.sqrt(cfg.resolved_head_dim),
                                        plan)
    want = jnp.einsum("bshd,hdm->bsm", o.astype(x.dtype),
                      p["wo"].astype(x.dtype))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_acam_fidelity_staged_layer_matches_int(rng):
    """dd_matmul slot: 'acam' nibble-table matmuls are bit-identical to
    'int' through the whole staged layer path (the paper's §IV-B claim at
    the model layer)."""
    cfg = _cfg()
    p, x, pos = _attn_inputs(rng, cfg, S=12)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        a, _ = layers.attention(p, x, cfg=cfg, positions=pos,
                                plan=ExecConfig(mode="raceit"))
        b, _ = layers.attention(p, x, cfg=cfg, positions=pos,
                                plan=ExecConfig(mode="raceit",
                                                matmul_fidelity="acam"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# lm head through the plan (the old bare-ExecConfig bug)
# ---------------------------------------------------------------------------

def _resident_unembed(rng, cfg):
    w = jnp.asarray(rng.normal(0, 0.1, (cfg.d_model, cfg.vocab_size)),
                    jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(w / scale), -128, 127).astype(jnp.int8)
    return layers.QuantizedWeight(codes, scale.astype(jnp.float32),
                                  (cfg.vocab_size,))


@pytest.mark.parametrize("act_bits", [8, 5])
def test_lm_head_resident_weight_honors_plan_act_bits(rng, act_bits):
    """Resident int8 unembeddings quantize activations with the *plan's*
    act_bits — the old path rebuilt ExecConfig() and always used 8."""
    from repro.core.quant import quantize_tensor
    cfg = _cfg()
    qw = _resident_unembed(rng, cfg)
    x = jnp.asarray(rng.normal(0, 1, (2, 3, cfg.d_model)), jnp.float32)
    params = {"unembed": qw, "tok_emb": jnp.zeros((cfg.vocab_size,
                                                   cfg.d_model))}
    plan = resolve_plan(cfg, ExecConfig(mode="raceit", act_bits=act_bits))
    got = layers.unembed(params, x, cfg, plan)
    xq = quantize_tensor(x, bits=act_bits)
    want = (jnp.einsum("bsk,kv->bsv", xq.codes.astype(jnp.int32),
                       qw.codes.astype(jnp.int32)).astype(jnp.float32)
            * (xq.scale * qw.scale))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    if act_bits != 8:  # and it actually differs from the old always-8 path
        xq8 = quantize_tensor(x, bits=8)
        old = (jnp.einsum("bsk,kv->bsv", xq8.codes.astype(jnp.int32),
                          qw.codes.astype(jnp.int32)).astype(jnp.float32)
               * (xq8.scale * qw.scale))
        assert not np.array_equal(np.asarray(got), np.asarray(old))


def test_lm_head_raceit_q8_override_quantizes_float_weights(rng):
    cfg = _cfg()
    x = jnp.asarray(rng.normal(0, 1, (1, 4, cfg.d_model)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (cfg.d_model, cfg.vocab_size)),
                    jnp.float32)
    params = {"unembed": w, "tok_emb": jnp.zeros((cfg.vocab_size,
                                                  cfg.d_model))}
    full = layers.unembed(params, x, cfg,
                          resolve_plan(cfg, ExecConfig(mode="raceit")))
    q8 = layers.unembed(params, x, cfg, resolve_plan(
        cfg, ExecConfig(mode="raceit").with_ops(lm_head="raceit_q8")))
    # default stays the full-precision einsum; the q8 override quantizes
    assert not np.array_equal(np.asarray(full), np.asarray(q8))
    np.testing.assert_allclose(np.asarray(full), np.asarray(q8),
                               atol=0.05 * float(jnp.abs(full).max()))


# ---------------------------------------------------------------------------
# end-to-end: model forward identical through config-sugar and explicit plan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_model_forward_same_via_config_and_plan(key, mode):
    from repro.models import Model
    cfg = _cfg(n_layers=2)
    ec = ExecConfig(mode=mode)
    m1 = Model(cfg, ec)
    m2 = Model(cfg, resolve_plan(cfg, ec))
    params = m1.init(key)
    batch = {"tokens": jnp.arange(32).reshape(2, 16) % cfg.vocab_size}
    a = m1.forward(params, batch, use_remat=False)
    b = m2.forward(params, batch, use_remat=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
