"""Substrate: checkpointing, fault-tolerant loop, data pipeline, serving,
gradient compression, hw simulator sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticLM
from repro.dist.compress import ef_compress_update
from repro.models import Model
from repro.serve import BatchScheduler, GenerationEngine, Request
from repro.train import TrainLoopConfig, optim, run_training, trainer

from conftest import tiny_config


# ------------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip(tmp_path, key):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(5.0), "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
    mgr.save(10, tree, extra={"step": 10})
    restored, extra = mgr.restore(tree)
    assert extra["step"] == 10
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_retention_and_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, block=False)
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_loop_resume_exact(tmp_path, key):
    """Kill the loop mid-run; resuming reproduces the uninterrupted run."""
    cfg = tiny_config(get_config("olmo-1b"))
    model = Model(cfg)
    params0 = model.init(key)
    opt0 = optim.adamw_init(params0)
    step = jax.jit(trainer.make_train_step(model, optim.AdamWConfig(lr=1e-3)))

    def train_to(steps, ckpt_dir, params, opt):
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=2, seed=7)
        lc = TrainLoopConfig(steps=steps, ckpt_dir=str(ckpt_dir),
                             ckpt_every=5, log_every=100)
        return run_training(
            step, params, opt, data, lc,
            make_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
            log=lambda *a: None)

    # uninterrupted 10 steps
    p_full, _, out_full = train_to(10, tmp_path / "full", params0, opt0)
    # interrupted: 5 steps, then resume to 10 in a fresh call
    p_half, o_half, _ = train_to(5, tmp_path / "resume", params0, opt0)
    p_res, _, out_res = train_to(10, tmp_path / "resume", params0, opt0)
    assert out_res["final_step"] == 10
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_elastic_restore_resharding(tmp_path):
    """Restore under a different sharding layout (elastic restart)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(tmp_path)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, tree)
    from repro.dist.sharding import compat_make_mesh
    mesh = compat_make_mesh((1,), ("model",))
    shard = {"w": NamedSharding(mesh, P("model", None))}
    restored, _ = mgr.restore(tree, shardings=shard)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding.is_equivalent_to(shard["w"], 2)


# -------------------------------------------------------------------- data
def test_data_deterministic_and_skippable():
    d1 = SyntheticLM(seq_len=8, global_batch=4, seed=1)
    d2 = SyntheticLM(seq_len=8, global_batch=4, seed=1)
    a = d1.next_batch()
    b = d1.next_batch()
    d2.skip(1)
    b2 = d2.next_batch()
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_data_sharding_partitions_batch():
    shards = [SyntheticLM(seq_len=8, global_batch=4, seed=1, shard_index=i,
                          shard_count=2) for i in range(2)]
    b0, b1 = (s.next_batch()["tokens"] for s in shards)
    assert b0.shape == (2, 8)
    assert not np.array_equal(b0, b1)


# ----------------------------------------------------------------- serving
def test_generation_engine_and_batching(key):
    cfg = tiny_config(get_config("gpt2-large"))
    model = Model(cfg)
    params = model.init(key)
    eng = GenerationEngine(cfg, params, max_len=64)
    sched = BatchScheduler(eng, bucket_size=2)
    rng = np.random.default_rng(0)
    for rid in range(3):
        sched.submit(Request(rid, rng.integers(0, 255, 5).astype(np.int32),
                             n_new=4))
    done = sched.run_all()
    assert sorted(done) == [0, 1, 2]
    for r in done.values():
        assert r.result.shape == (4,)
        assert (r.result >= 0).all() and (r.result < cfg.vocab_size).all()


def test_generation_matches_decode_path(key):
    """Greedy generate == manual argmax rollout through forward()."""
    cfg = tiny_config(get_config("olmo-1b"))
    model = Model(cfg)
    params = model.init(key)
    eng = GenerationEngine(cfg, params, max_len=32)
    prompt = jax.random.randint(key, (1, 6), 0, cfg.vocab_size)
    gen = eng.generate(prompt, n_new=5)
    toks = np.asarray(prompt)
    for t in range(5):
        logits = model.forward(params, {"tokens": jnp.asarray(toks)},
                               use_remat=False)
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == int(gen[0, t]), (t, nxt, gen)
        toks = np.concatenate([toks, [[nxt]]], axis=1)


# ------------------------------------------------------------- compression
def test_error_feedback_compression_converges():
    g = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64,)), jnp.float32)
    residual = None
    acc = jnp.zeros_like(g)
    for _ in range(50):
        _, restored, residual = ef_compress_update({"g": g}, residual, "int8")
        acc = acc + restored["g"]
    # time-averaged compressed gradient converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g),
                               atol=2e-2)


# ------------------------------------------------------------- hw simulator
def test_simulator_reproduces_paper_ordering():
    from repro.hw.simulator import Workload, simulate
    w = Workload.from_config(get_config("bert-base"))
    r = {a: simulate(w, a) for a in ("raceit", "puma", "retransformer")}
    assert (r["raceit"]["tokens_per_s"] > r["retransformer"]["tokens_per_s"]
            > r["puma"]["tokens_per_s"])
    sp = r["raceit"]["tokens_per_s"] / r["puma"]["tokens_per_s"]
    assert 4.5 < sp < 7.5  # paper: 5.9x
    en = (r["puma"]["energy_per_token_uj"]
          / r["raceit"]["energy_per_token_uj"])
    assert 3.0 < en < 5.0  # paper: 3.9x
    assert abs(r["raceit"]["tops"] - 110.11) / 110.11 < 0.05  # Table V


def test_k_sweep_plateau_contains_paper_choice():
    from repro.hw.gce import k_sweep, optimal_k_range
    rows = k_sweep(get_config("bert-base"), seq_len=384)
    lo, hi = optimal_k_range(rows, 0.15)
    assert lo <= 28.3 <= hi  # the paper's design point is inside our plateau
