"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.crossbar import CrossbarConfig
from repro.core.ops import LOGIT_FMT
from repro.kernels import ops as kops
from repro.kernels import ref


@pytest.mark.parametrize("shape", [(1, 1), (7, 130), (256, 128), (3, 5, 64),
                                   (33, 257)])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int32])
def test_acam_lut_shapes_dtypes(rng, shape, dtype):
    x = jnp.asarray(rng.integers(-128, 128, shape), dtype)
    lut = jnp.asarray(rng.integers(-128, 128, 256), jnp.int32)
    got = kops.acam_lut(x, lut)
    want = ref.lut_ref(x, lut)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 70), st.integers(1, 300),
       st.integers(1, 140))
def test_acam_mvm_property(seed, m, k, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    got = kops.acam_mvm(x, w, bm=32, bn=128, bk=64)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.mvm_exact_ref(x, w)))


@pytest.mark.parametrize("mkn", [(4, 100, 8), (16, 128, 128), (33, 300, 65),
                                 (128, 512, 256)])
def test_acam_mvm_exact_shapes(rng, mkn):
    m, k, n = mkn
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
    np.testing.assert_array_equal(np.asarray(kops.acam_mvm(x, w)),
                                  np.asarray(ref.mvm_exact_ref(x, w)))


def test_acam_mvm_quantized_adc_matches_oracle(rng):
    cfg = CrossbarConfig(adc_mode="quantize", adc_bits=6)
    x = jnp.asarray(rng.integers(-128, 128, (8, 256)), jnp.int8)
    w = jnp.asarray(rng.integers(-128, 128, (256, 32)), jnp.int8)
    np.testing.assert_array_equal(np.asarray(kops.acam_mvm(x, w, cfg)),
                                  np.asarray(ref.mvm_ref(x, w, cfg)))


@pytest.mark.parametrize("shape", [(4, 64), (3, 130), (8, 1024), (1, 16)])
@pytest.mark.parametrize("mode", ["pot", "pot_fine"])
def test_acam_softmax_kernel_vs_core(rng, shape, mode):
    x = jnp.asarray(rng.normal(0, 3, shape), jnp.float32)
    codes = LOGIT_FMT.encode(x)
    got = kops.acam_softmax_codes(codes, mode=mode)
    want = ref.softmax_codes_ref(codes, mode=mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_raceit_linear_kernel(rng):
    x = jnp.asarray(rng.normal(0, 1, (4, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (96, 48)), jnp.float32)
    y = kops.raceit_linear(x, w)
    rel = float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.05


def test_acam_activation_kernel(rng):
    import jax
    x = jnp.asarray(rng.normal(0, 1, (16, 64)), jnp.float32)
    y = kops.acam_activation(x, "gelu")
    ref_y = jax.nn.gelu(x)
    assert float(jnp.abs(y - ref_y).max()) < 0.15  # 8-bit table resolution
