"""Distribution: sharding rules, MoE shard_map on a real (1-device) mesh,
dry-run machinery on a small forced-device-count subprocess."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.dist.sharding import (MeshContext, ShardingPolicy, param_specs,
                                 use_policy)
from repro.models import Model

from conftest import tiny_config

ROOT = Path(__file__).resolve().parent.parent


def _mesh11():
    from repro.dist.sharding import compat_make_mesh
    return compat_make_mesh((1, 1), ("data", "model"))


def test_param_specs_rules(key):
    cfg = get_config("mixtral-8x22b")
    mesh = _mesh11()
    policy = ShardingPolicy(mesh)
    model = Model(cfg)
    shapes = jax.eval_shape(model.init, key)
    specs = param_specs(shapes, cfg, policy)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    as_dict = {"/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp): s for kp, s in flat}
    # moe expert weights: TP over d_ff (mixtral E=8 can't divide model)
    moe_w1 = [v for p, v in as_dict.items() if "moe" in p and p.endswith("w1")]
    assert all(v[-1] == "model" or v[-1] is None for v in moe_w1)
    # attention wq sharded over heads on the "model" axis
    wq = [v for p, v in as_dict.items() if p.endswith("wq")]
    assert all(len(v) == 4 for v in wq)  # stacked scan + 3 dims


def test_moe_shard_map_matches_local(key):
    """shard_map MoE on a 1x1 mesh == meshless local MoE."""
    cfg = tiny_config(get_config("mixtral-8x22b"))
    mesh = _mesh11()
    policy = ShardingPolicy(mesh)
    mctx = MeshContext(mesh)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)

    m_local = Model(cfg, mesh_ctx=None)
    params = m_local.init(key)
    out_local = m_local.forward(params, {"tokens": tokens}, use_remat=False)

    m_dist = Model(cfg, mesh_ctx=mctx)
    with use_policy(policy, mctx):
        out_dist = m_dist.forward(params, {"tokens": tokens}, use_remat=False)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_dist),
                               rtol=1e-4, atol=1e-4)


def test_ep_moe_shard_map_matches_local(key):
    cfg = tiny_config(get_config("llama4-scout-17b-a16e"))
    mesh = _mesh11()
    mctx = MeshContext(mesh)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    m_local = Model(cfg, mesh_ctx=None)
    params = m_local.init(key)
    out_local = m_local.forward(params, {"tokens": tokens}, use_remat=False)
    m_dist = Model(cfg, mesh_ctx=mctx)
    with use_policy(ShardingPolicy(mesh), mctx):
        out_dist = m_dist.forward(params, {"tokens": tokens}, use_remat=False)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_dist),
                               rtol=1e-4, atol=1e-4)


def test_policy_drops_nondivisible_axes():
    from types import SimpleNamespace
    mesh = SimpleNamespace(axis_names=("data", "model"),
                           shape={"data": 4, "model": 16})
    pol = ShardingPolicy(mesh)
    spec = pol.spec_for((7, 64), ("batch", "heads"))
    assert spec[0] is None          # 7 % 4 != 0 -> dropped
    assert spec[1] == "model"       # 64 % 16 == 0 -> sharded
    spec2 = pol.spec_for((24, 64), ("heads", "heads"))
    # 24 doesn't divide -> dropped; 64 takes the axis; never used twice
    assert spec2[0] is None and spec2[1] == "model"
    spec3 = pol.spec_for((32, 64), ("heads", "heads"))
    assert spec3[0] == "model" and spec3[1] is None


@pytest.mark.slow
def test_dryrun_subprocess_small_mesh():
    """End-to-end dry-run machinery on a forced 8-device CPU mesh."""
    env = {"DRYRUN_XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']=os.environ['DRYRUN_XLA_FLAGS'];"
        "import repro.launch.dryrun as dr, repro.launch.mesh as lm, jax;"
        "lm.make_production_mesh = (lambda *, multi_pod=False: "
        "jax.make_mesh((2,2,2),('pod','data','model')) if multi_pod else "
        "jax.make_mesh((4,2),('data','model')));"
        "r = dr.run_cell('olmo-1b','train_4k','single',"
        "overrides={'n_layers':2,'d_model':128,'n_heads':4,'n_kv_heads':4,"
        "'d_ff':256,'vocab_size':512});"
        "assert r['status']=='ok', r;"
        "assert r['hlo']['flops'] > 0 and r['hlo']['collective_bytes'] > 0;"
        "r2 = dr.run_cell('olmo-1b','decode_32k','multi',"
        "overrides={'n_layers':2,'d_model':128,'n_heads':4,'n_kv_heads':4,"
        "'d_ff':256,'vocab_size':512});"
        "assert r2['status']=='ok', r2;"
        "print('SUBPROCESS_OK')"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SUBPROCESS_OK" in out.stdout, out.stderr[-2000:]
