"""Docs stay truthful: every code path named in the project docs must exist.

Scans README.md, docs/*.md, EXPERIMENTS.md, and ROADMAP.md for repo-path
references (backtick-quoted paths and markdown link targets) and asserts
each resolves in the tree; ``path::symbol`` references additionally assert
the symbol occurs in the file. This is the tier-1 guard behind the CI docs
job — rename a module and the doc that points at it fails here, not in a
reader's head.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOCS = sorted(
    p for p in [ROOT / "README.md", ROOT / "EXPERIMENTS.md",
                ROOT / "ROADMAP.md", *(ROOT / "docs").glob("*.md")]
    if p.exists())

# a repo path reference: known top-level prefix, or any *.py/*.md/*.json
# relative path with a directory component
_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "docs/")
_PATH_RE = re.compile(
    r"(?:[A-Za-z0-9_.-]+/)*[A-Za-z0-9_.-]+\.(?:py|md|json)")


def _doc_refs(text):
    # drop fenced code blocks first: they contain commands/diagrams, and a
    # stray ``` would otherwise invert the single-backtick pairing below
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for token in re.findall(r"`([^`\n]+)`", text):
        path, _, symbol = token.partition("::")
        if _PATH_RE.fullmatch(path) and ("/" in path
                                         or path.startswith(_PREFIXES)):
            yield path, symbol
    for target in re.findall(r"\]\(([^)#]+)\)", text):
        if not target.startswith(("http://", "https://", "mailto:")):
            yield target, ""


def _cases():
    for doc in DOCS:
        for path, symbol in _doc_refs(doc.read_text()):
            yield pytest.param(doc, path, symbol,
                               id=f"{doc.name}:{path}"
                                  + (f"::{symbol}" if symbol else ""))


@pytest.mark.parametrize("doc, path, symbol", _cases())
def test_doc_reference_resolves(doc, path, symbol):
    # repo-root paths, plus package-relative spellings like `kernels/ops.py`
    # (docs refer to modules the way imports do)
    candidates = [ROOT / path, ROOT / "src" / path, ROOT / "src/repro" / path]
    target = next((c for c in candidates if c.exists()), None)
    assert target is not None, (
        f"{doc.relative_to(ROOT)} references {path!r}, which does not exist")
    if symbol:
        assert symbol.lstrip("_").split("(")[0] in target.read_text(), (
            f"{doc.relative_to(ROOT)} references {path}::{symbol}, "
            f"but the symbol does not occur in the file")


def test_docs_exist_and_nonempty():
    for required in ("README.md", "docs/architecture.md", "EXPERIMENTS.md"):
        p = ROOT / required
        assert p.exists() and p.stat().st_size > 500, required


def test_scanner_sees_references():
    """The scanner must actually find refs (guards against regex rot)."""
    readme_refs = list(_doc_refs((ROOT / "README.md").read_text()))
    arch_refs = list(_doc_refs((ROOT / "docs/architecture.md").read_text()))
    assert len(readme_refs) >= 5, readme_refs
    assert len(arch_refs) >= 10, arch_refs


def test_kernel_contracts_report_in_sync(analysis_results):
    """The committed per-kernel contract report is the analyzer's current
    output, byte for byte — change a kernel's grid, blocks, or probe set
    and this fails until the report is regenerated."""
    committed = ROOT / "docs" / "kernel_contracts.md"
    assert committed.exists(), "docs/kernel_contracts.md is missing"
    assert committed.read_text() == analysis_results["contracts"], (
        "docs/kernel_contracts.md is stale: regenerate with "
        "`PYTHONPATH=src python -m repro.analysis --write-contracts`")
