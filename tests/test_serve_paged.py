"""Property-based lifecycle fuzz for block-paged continuous serving.

The paged-mode contract (serve/continuous.py + serve/paged.py):

* **bitwise solo parity** — in digital greedy mode every request's tokens
  are identical to serving it alone, however its prompt was chunked into
  pages and however its neighbours churned (admission order, page
  shuffling, retire-mid-chunk, backpressure stalls change *nothing*);
* **page-economy invariants** — after every step, each physical page is
  in exactly one of {free list, one slot's private list, the prefix
  cache's shared set, leaked} — the refcount-aware pool partition
  ``free + leaked + Σ private + shared = n_pages − 1`` — the trash page 0
  is in none, live block-table rows mirror shared references then private
  ownership exactly, and once the trace drains everything except the
  resident ref==0 cache pages is back on the free list;
* **quarantine accounting** — a faulted slot's private pages leak (never
  re-issued), its shared references are merely released, and the slot
  never hosts another request (satellite: the dead-slot re-admission
  regression).

The fuzz runs ≥ 200 generated traces (110 per config: gpt2-large is MHA,
command-r-35b is RoPE + GQA — the two fused-decode kernel families) with
prompt lengths hitting the paging corner cases: 1 token, page_size ± 1,
exact page multiples, and 3x the prefill chunk (longer than any pinned
admission width the contiguous path would have locked). Half the
generated prompts are truncations of a small pool of shared long prompts,
so traces mix shared-prefix requests organically and the prefix cache
(on by default in paged mode since PR 8) sees hits, promotions, and
evictions under the same bitwise-parity oracle as cold requests. The page
pool is deliberately undersized (8 allocatable pages for 3 slots x
up to 4-page requests) so admission backpressure and retire-reissue churn
occur organically inside the traces. `tests/_hypothesis_compat.py` keeps
the sweep deterministic when hypothesis isn't installed.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.serve import ContinuousBatcher, GenerationEngine, Request
from repro.serve.paged import PageAllocator

from conftest import tiny_config
from _hypothesis_compat import given, settings, strategies as st

PS = 8            # page size AND prefill chunk: 8 divides nothing tested
N_SLOTS = 3
N_PAGES = 9       # 8 allocatable: 3 slots x 4-page requests MUST contend
MAX_LEN = 64
LENGTHS = (1, PS - 1, PS, PS + 1, 2 * PS, 3 * PS)  # paging corner cases

_ENGINES: dict = {}
_SOLO: dict = {}


def _engine(name):
    """One engine (and so one compiled executable set) per config for the
    whole module — the fuzz's device cost is per-step, not per-trace."""
    if name not in _ENGINES:
        cfg = tiny_config(get_config(name))
        ec = ExecConfig(mode="digital", fused_attention=True)
        eng = GenerationEngine(cfg, None, ec, max_len=MAX_LEN)
        eng.params = eng.model.init(jax.random.PRNGKey(0))
        _ENGINES[name] = eng
    return _ENGINES[name]


def _prompt(L, cseed, shared=False):
    """Deterministic prompt content per (length, content-seed): a small
    pool keeps the memoized solo oracle's hit rate high across traces.
    ``shared`` prompts are truncations of ONE 3-page pool prompt per
    cseed, so requests of different lengths share page-aligned prefixes
    — the traffic shape the prefix cache exists for."""
    if shared:
        return _prompt(3 * PS, cseed)[:L]
    rng = np.random.default_rng(100_000 * L + cseed)
    return rng.integers(0, 255, size=L, dtype=np.int64).tolist()


def _solo(name, L, cseed, n_new, shared=False):
    """Memoized solo-generation oracle (the parity reference)."""
    key = (name, L, cseed, n_new, shared)
    if key not in _SOLO:
        eng = _engine(name)
        prompt = np.asarray(_prompt(L, cseed, shared), np.int32)
        _SOLO[key] = [int(t) for t in eng.generate(prompt[None, :], n_new)[0]]
    return _SOLO[key]


def _check_invariants(cb):
    """The page-economy assertions run after EVERY step of every trace."""
    a = cb.allocator
    a.assert_invariants()  # exact partition, refcounts, no double-holds
    # the refcount-aware pool partition, spelled out (satellite 3):
    assert (a.n_free + a.n_leaked + a.pages_in_use + a.n_shared
            == cb.n_pages - 1)
    for slot, s in enumerate(cb.slots):
        owned = a.owned(slot)
        refs = a.refs(slot)
        row = cb.block_table[slot]
        if s is not None:
            # a live row maps its shared references (prefix-cache hits +
            # its own promotions) then its private pages, in order, then 0s
            mapped = refs + owned
            assert list(row[: len(mapped)]) == mapped
            assert not row[len(mapped):].any()
        else:
            assert not owned and not refs and not row.any()
    for slot in cb.dead_slots:
        # quarantined slots never host a request or map a page again
        assert cb.slots[slot] is None
        assert not cb.block_table[slot].any()


def _fuzz_trace(name, trace_seed):
    rng = np.random.default_rng(trace_seed)
    eng = _engine(name)
    cb = ContinuousBatcher(eng, n_slots=N_SLOTS, page_size=PS,
                           n_pages=N_PAGES)
    assert cb.paged  # decoder-only all-attn models serve paged by default
    reqs = []
    for rid in range(int(rng.integers(2, 6))):
        L = int(LENGTHS[rng.integers(0, len(LENGTHS))])
        cseed = int(rng.integers(0, 3))
        n_new = int(rng.integers(1, 5))
        # half the prompts truncate a shared pool prompt: same-cseed
        # requests then share page-aligned prefixes and the trace
        # exercises prefix-cache hits/promotions against the same oracle
        shared = bool(rng.integers(0, 2))
        reqs.append((Request(rid, _prompt(L, cseed, shared), n_new=n_new),
                     L, cseed, shared))
    for r, _, _, _ in reqs:
        cb.submit(r)
    steps, max_in_use = 0, 0
    while cb.queue or any(s is not None for s in cb.slots):
        cb.step()
        steps += 1
        assert steps < 500, "trace failed to drain"
        _check_invariants(cb)
        max_in_use = max(max_in_use, cb.allocator.pages_in_use)
    # drained: nothing leaked (no faults here), nothing still owned by a
    # retired slot, and every page is back on the free list EXCEPT the
    # ref==0 prefix-cache pages — resident shared pages ARE the cache
    assert cb.allocator.pages_in_use == 0
    assert cb.allocator.n_leaked == 0
    assert cb.allocator.n_free + cb.allocator.n_shared == N_PAGES - 1
    assert cb.allocator.n_shared == len(cb.prefix)
    assert max_in_use <= N_PAGES - 1
    for r, L, cseed, shared in reqs:
        done = cb.done[r.rid]
        assert done.error is None, done.error
        got = [int(t) for t in done.result]
        want = _solo(name, L, cseed, r.n_new, shared)
        assert got == want, (
            f"rid={r.rid} P={L} n_new={r.n_new} shared={shared} diverged "
            f"from solo: {got} != {want}")


@settings(max_examples=110, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_paged_lifecycle_fuzz_mha(trace_seed):
    """110 random traces on the MHA config (gpt2-large tiny): bitwise
    solo parity + page-economy invariants after every step."""
    _fuzz_trace("gpt2-large", trace_seed)


@settings(max_examples=110, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_paged_lifecycle_fuzz_gqa(trace_seed):
    """Same 110-trace property on RoPE + grouped-query KV
    (command-r-35b tiny): the GQA-native paged decode kernel family."""
    _fuzz_trace("command-r-35b", trace_seed)


# ---------------------------------------------------------------------------
# directed lifecycle tests: the acceptance scenarios, pinned explicitly
# ---------------------------------------------------------------------------

def test_long_prompt_streams_through_chunks():
    """A prompt 3x the prefill chunk — longer than any contiguous
    admission width could pin without resizing the pool — serves
    end-to-end through chunked prefill-into-slot, bitwise equal to solo."""
    eng = _engine("gpt2-large")
    cb = ContinuousBatcher(eng, n_slots=N_SLOTS, page_size=PS,
                           n_pages=N_PAGES, prefill_chunk=PS)
    L = 3 * PS
    cb.submit(Request(0, _prompt(L, 0), n_new=4))
    done = cb.run_all()
    assert [int(t) for t in done[0].result] == _solo("gpt2-large", L, 0, 4)
    # 24 prompt tokens at chunk width 8 is exactly 3 chunk calls
    assert cb.chunk_calls == 3
    assert cb.model_calls == cb.chunk_calls + cb.decode_steps


def test_pool_exhaustion_backpressures_in_fifo_order():
    """Two 3-page requests against a 4-page pool: the second stays queued
    (admission returns None, no side effects) until the first retires and
    frees its pages — and completion order stays FIFO."""
    eng = _engine("gpt2-large")
    cb = ContinuousBatcher(eng, n_slots=N_SLOTS, page_size=PS, n_pages=5)
    for rid in range(2):
        cb.submit(Request(rid, _prompt(2 * PS, rid), n_new=3))
    saw_queued_while_running = False
    order = []
    while cb.queue or any(s is not None for s in cb.slots):
        retired = cb.step()
        order.extend(retired)
        _check_invariants(cb)
        assert cb.allocator.pages_in_use <= 4
        if cb.queue and any(s is not None for s in cb.slots):
            saw_queued_while_running = True
    assert saw_queued_while_running  # the pool really was too small
    assert order == [0, 1]
    for rid in range(2):
        assert cb.done[rid].error is None
        assert [int(t) for t in cb.done[rid].result] == _solo(
            "gpt2-large", 2 * PS, rid, 3)


def test_submit_rejects_requests_beyond_capacity():
    eng = _engine("gpt2-large")
    cb = ContinuousBatcher(eng, n_slots=2, page_size=PS, n_pages=3)
    with pytest.raises(ValueError, match="exceeds the block table"):
        cb.submit(Request(0, _prompt(PS, 0) * 8, n_new=1))  # P = max_len
    with pytest.raises(ValueError, match="pages"):
        cb.submit(Request(1, _prompt(3 * PS, 0), n_new=1))  # 3 pages > 2
    with pytest.raises(ValueError, match="empty prompt"):
        cb.submit(Request(2, [], n_new=1))
    assert not cb.queue


def test_paged_mode_gating():
    """prefill_len pins the contiguous path; paged=True refuses it, and
    models whose caches have no paged form refuse paged=True with the
    layout named."""
    eng = _engine("gpt2-large")
    with pytest.raises(ValueError, match="pass prefill_chunk"):
        ContinuousBatcher(eng, paged=True, prefill_len=16)
    # explicit prefill_len silently selects contiguous (back-compat)
    assert not ContinuousBatcher(eng, prefill_len=16).paged
    assert ContinuousBatcher(eng, paged=False).paged is False
    for name, frag in (("jamba-v0.1-52b", "paged cache form"),
                       ("gemma3-4b", "paged cache form"),
                       ("whisper-tiny", "encoder-decoder")):
        why = ContinuousBatcher.pageable_reason(
            dataclasses.replace(eng, cfg=get_config(name)))
        assert why is not None and frag in why


# ---------------------------------------------------------------------------
# quarantine accounting (satellite): dead slots keep their pages leaked
# ---------------------------------------------------------------------------

def _faulty_engine(fault_rate, seed=0):
    """Digital engine with decode attention routed through the noisy
    staged backend at the given fault rate (tests/test_serve_continuous.py
    documents the idiom); paged serving reaches it through the
    gather-degrade path, so faults land per slot row exactly as on the
    contiguous pool."""
    from repro.hw.noise import NoiseConfig
    nz = dataclasses.replace(NoiseConfig.preset("worst_case", seed=seed),
                             fault_rate=fault_rate)
    ec = ExecConfig(mode="digital", noise=nz).with_ops(
        attention_decode="raceit_noisy_staged")
    cfg = tiny_config(get_config("gpt2-large"))
    eng = GenerationEngine(cfg, None, ec, max_len=MAX_LEN)
    eng.params = eng.model.init(jax.random.PRNGKey(0))
    return eng


def test_quarantined_slot_leaks_pages_and_never_readmits():
    """The quarantine-accounting regression: after a decode fault kills a
    slot, (a) its pages leave the economy for good — never re-issued to a
    later admission — and (b) every later request is served by the
    surviving slots only; the dead slot's block-table row stays zero."""
    from repro.hw.noise import fault_rows, site_key

    eng = _faulty_engine(0.5)
    # prefix cache off: this is the PR 7 regression pinned on *private*
    # page counts — promotion would move the full prompt page to shared
    # (released, not leaked, on quarantine) and change the arithmetic;
    # the shared-page quarantine contract lives in test_serve_prefix.py
    cb = ContinuousBatcher(eng, n_slots=2, page_size=PS,
                           n_pages=1 + 2 * (MAX_LEN // PS),
                           prefix_cache=False)
    # pin the scenario: at seed 0 the (2,)-row fault map kills slot 1
    nz = eng.plan.exec_cfg.noise
    fmap = np.asarray(fault_rows(nz, site_key(nz, "decode_fault", (2,)), 2))
    assert list(fmap) == [False, True]

    for rid in range(4):
        cb.submit(Request(rid, _prompt(PS + 1, rid % 3), n_new=3))
    leaked_after_fault = None
    while cb.queue or any(s is not None for s in cb.slots):
        cb.step()
        _check_invariants(cb)  # leaked pages counted, never double-held
        if cb.dead_slots:
            if leaked_after_fault is None:
                leaked_after_fault = cb.allocator.n_leaked
            # the leak never shrinks and the dead slot never comes back
            assert cb.allocator.n_leaked == leaked_after_fault
            assert cb.dead_slots == {1}
    assert leaked_after_fault == 2  # ceil((9 + 3 - 1) / 8) pages, leaked
    # exactly one request died (structured error), the rest completed on
    # the surviving slot with clean results
    failed = [r for r in cb.done.values() if r.error is not None]
    assert len(failed) == 1
    assert failed[0].error.stage in ("decode", "prefill")
    for r in cb.done.values():
        if r.error is None:
            assert len(r.result) == 3
    # end state: everything not leaked is back on the free list
    assert cb.allocator.pages_in_use == 0
    assert cb.allocator.n_free == cb.n_pages - 1 - leaked_after_fault


def test_all_slots_dead_drains_queue_and_deadlock_names_leak():
    """Every slot faulting must not hang run_all (stage='admit' errors),
    and the deadlock error names the leaked-page count — the operator's
    signal that the pool shrank for good."""
    eng = _faulty_engine(1.0, seed=1)
    cb = ContinuousBatcher(eng, n_slots=1, page_size=PS, n_pages=3)
    for rid in range(2):
        cb.submit(Request(rid, _prompt(PS - 1, rid), n_new=4))
    done = cb.run_all()  # must terminate
    assert sorted(done) == [0, 1]
    assert all(done[r].error is not None for r in done)
    assert done[1].error.stage == "admit"
    assert cb.dead_slots == {0}
    _check_invariants(cb)


def test_allocator_unit_invariants():
    """PageAllocator alone: alloc is all-or-nothing, double-admit raises,
    leak+free partition the pool exactly."""
    a = PageAllocator(6)  # pages 1..5
    assert a.alloc(0, 6) is None and a.n_free == 5  # no side effects
    p0 = a.alloc(0, 2)
    p1 = a.alloc(1, 2)
    assert len(p0) == 2 and len(p1) == 2 and not set(p0) & set(p1)
    with pytest.raises(ValueError, match="already holds"):
        a.alloc(0, 1)
    a.assert_invariants()
    a.leak_slot(0)
    a.free_slot(1)
    a.assert_invariants()
    assert a.n_leaked == 2 and a.n_free == 3 and a.pages_in_use == 0
    # leaked pages are gone: even an ask for "everything" can't get them
    assert a.alloc(2, 4) is None
    got = a.alloc(2, 3)
    assert got is not None and not set(got) & set(p0)
    a.assert_invariants()
    with pytest.raises(ValueError, match="at least"):
        PageAllocator(1)
