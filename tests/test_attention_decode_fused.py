"""Fused decode-path (Sq=1, KV-cache) attention vs the staged oracle.

The contract: `raceit_attention_decode_fused(q, k_buf, v_buf, kv_len)` is
bit-exact vs the staged `raceit_attention` oracle evaluated on the cache
*slice* ``k_buf[:, :, :kv_len]`` — for every softmax mode the staged path
accepts and any cache fill level, regardless of what the buffer holds past
the fill (stale rows from longer past sequences, zeros, anything).
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ExecConfig, ModelConfig
from repro.core.attention import fused_attention_supported, raceit_attention
from repro.core.ops import PROB_FMT
from repro.core.quant import quantize_tensor
from repro.exec import reset_plan_cache
from repro.kernels.ops import (masked_prefix_quantize,
                               raceit_attention_decode_fused)
from repro.models import layers


def _assert_parity(got, want, v):
    """Bit-exact, with the <=1 PROB ulp acceptance bound as the hard floor."""
    got, want = np.asarray(got), np.asarray(want)
    if np.array_equal(got, want):
        return
    ulp = PROB_FMT.scale * float(jnp.max(jnp.abs(v)))
    np.testing.assert_allclose(got, want, atol=ulp, rtol=0)


def _decode_case(rng, B, H, Smax, D, fill, std=1.5):
    """(q, k_buf, v_buf): buffers valid to `fill`, zeros past it."""
    mk = lambda s: jnp.asarray(rng.normal(0, std, s), jnp.float32)
    q = mk((B, H, 1, D))
    k = jnp.zeros((B, H, Smax, D), jnp.float32).at[:, :, :fill].set(
        mk((B, H, fill, D)))
    v = jnp.zeros((B, H, Smax, D), jnp.float32).at[:, :, :fill].set(
        mk((B, H, fill, D)))
    return q, k, v


@pytest.mark.parametrize("fill", [1, 7, 33, 96])
@pytest.mark.parametrize("mode", ["pot", "uniform", "pot_fine"])
def test_decode_matches_oracle_on_cache_slice(rng, mode, fill):
    B, H, Smax, D = 2, 3, 96, 16
    q, k, v = _decode_case(rng, B, H, Smax, D, fill)
    want = raceit_attention(q, k[:, :, :fill], v[:, :, :fill],
                            softmax_mode=mode)
    got = raceit_attention_decode_fused(q, k, v, jnp.int32(fill),
                                        softmax_mode=mode, block_k=32)
    _assert_parity(got, want, v[:, :, :fill])


def test_decode_ignores_stale_cache_tail(rng):
    """Garbage past kv_len (stale rows, huge magnitudes) must not leak into
    the quantizer scales, the row sum, the global PROB max, or matmul-2."""
    B, H, Smax, D, fill = 1, 2, 64, 8, 20
    q, k, v = _decode_case(rng, B, H, Smax, D, fill)
    k = k.at[:, :, fill:].set(99.0)
    v = v.at[:, :, fill:].set(-99.0)
    want = raceit_attention(q, k[:, :, :fill], v[:, :, :fill])
    got = raceit_attention_decode_fused(q, k, v, jnp.int32(fill), block_k=32)
    _assert_parity(got, want, v[:, :, :fill])


def test_decode_kv_len_is_traced_one_compile(rng):
    """One executable serves every fill level (kv_len is traced, not static)."""
    B, H, Smax, D = 1, 2, 64, 8
    q, k, v = _decode_case(rng, B, H, Smax, D, 64)
    fn = lambda L: raceit_attention_decode_fused(q, k, v, L, block_k=32)
    with jax.log_compiles(False):
        outs = [fn(jnp.int32(L)) for L in (3, 17, 64)]
    for L, got in zip((3, 17, 64), outs):
        want = raceit_attention(q, k[:, :, :L], v[:, :, :L])
        _assert_parity(got, want, v[:, :, :L])


def test_decode_rejects_multi_query(rng):
    q, k, v = _decode_case(rng, 1, 1, 16, 8, 16)
    q2 = jnp.concatenate([q, q], axis=2)  # Sq=2
    with pytest.raises(ValueError):
        raceit_attention_decode_fused(q2, k, v, jnp.int32(16))


def test_masked_prefix_quantize_matches_slice_quantize(rng):
    x = jnp.asarray(rng.normal(0, 2, (2, 3, 40, 8)), jnp.float32)
    for L in (1, 11, 40):
        codes, scale = masked_prefix_quantize(x, jnp.int32(L))
        ref = quantize_tensor(x[:, :, :L], bits=8)
        np.testing.assert_array_equal(np.asarray(codes[:, :, :L]),
                                      np.asarray(ref.codes))
        assert float(scale) == float(ref.scale)
        assert not np.asarray(codes[:, :, L:]).any()


# ---------------------------------------------------------------------------
# model-layer and config wiring
# ---------------------------------------------------------------------------

def _layer_cfg():
    return ModelConfig(name="t", n_layers=1, d_model=32, n_heads=4,
                       n_kv_heads=2, d_ff=64, vocab_size=64,
                       param_dtype="float32", compute_dtype="float32")


def _run_prefill_then_decode(p, cfg, exec_cfg, rng_seed=7, n_decode=3):
    rng = np.random.default_rng(rng_seed)
    B, L, hd = 2, 16, cfg.resolved_head_dim
    cache = {"k": jnp.zeros((B, L, cfg.n_kv_heads, hd), jnp.float32),
             "v": jnp.zeros((B, L, cfg.n_kv_heads, hd), jnp.float32),
             "idx": jnp.int32(0)}
    x = jnp.asarray(rng.normal(0, 1, (B, 6, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(6), (B, 6))
    out, cache = layers.attention(p, x, cfg=cfg, positions=pos,
                                  plan=exec_cfg, cache=cache)
    outs = [out]
    for t in range(6, 6 + n_decode):
        xt = jnp.asarray(rng.normal(0, 1, (B, 1, cfg.d_model)), jnp.float32)
        o, cache = layers.attention(p, xt, cfg=cfg,
                                    positions=jnp.full((B, 1), t),
                                    plan=exec_cfg, cache=cache)
        outs.append(o)
    return outs


def test_layers_fused_decode_close_to_staged(key):
    """Fused decode (full quantized Fig.-12 pipeline) vs the staged layer
    decode (float scores + ACAM softmax): different numerics by design, but
    they must agree to quantization noise and stay finite."""
    cfg = _layer_cfg()
    p = layers.init_attention(key, cfg, jnp.float32)
    staged = _run_prefill_then_decode(p, cfg, ExecConfig(mode="raceit"))
    fused = _run_prefill_then_decode(
        p, cfg, ExecConfig(mode="raceit", fused_attention=True))
    # prefill outputs are bit-exact (same fused-vs-staged contract as PR 1)
    np.testing.assert_array_equal(np.asarray(staged[0]), np.asarray(fused[0]))
    for s, f in zip(staged[1:], fused[1:]):
        f = np.asarray(f)
        assert np.isfinite(f).all()
        scale = max(float(np.abs(np.asarray(s)).max()), 1e-6)
        assert float(np.abs(f - np.asarray(s)).max()) / scale < 0.25


def test_layers_fused_fallback_warns_once_and_matches_staged(key):
    """Unsupported combo (matmul_fidelity='acam') degrades to the staged
    path with one RuntimeWarning instead of crashing — and the degraded
    outputs are exactly the staged outputs. (The warning now fires at plan
    resolution; reset_plan_cache drops the cache + warned-reason set.)"""
    cfg = _layer_cfg()
    p = layers.init_attention(key, cfg, jnp.float32)
    reset_plan_cache()
    bad = ExecConfig(mode="raceit", fused_attention=True,
                     matmul_fidelity="acam")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = _run_prefill_then_decode(p, cfg, bad)
        got2 = _run_prefill_then_decode(p, cfg, bad)
    msgs = [x for x in w if issubclass(x.category, RuntimeWarning)
            and "fused_attention" in str(x.message)]
    assert len(msgs) == 1, [str(x.message) for x in w]
    want = _run_prefill_then_decode(
        p, cfg, ExecConfig(mode="raceit", matmul_fidelity="acam"))
    for a, b, c in zip(got, got2, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        np.testing.assert_array_equal(np.asarray(b), np.asarray(c))


def test_fused_softmax_modes_in_sync():
    """core.attention duplicates the kernel's mode tuple (to avoid a
    load-time kernels import); they must never drift apart."""
    from repro.core.attention import _FUSED_SOFTMAX_MODES
    from repro.kernels.acam_attention import (FUSED_SOFTMAX_MODES,
                                              softmax_tables)
    assert _FUSED_SOFTMAX_MODES == FUSED_SOFTMAX_MODES
    for mode in FUSED_SOFTMAX_MODES:
        softmax_tables(mode)  # every advertised mode must actually build


def test_fused_supported_predicate():
    assert fused_attention_supported() is None
    assert fused_attention_supported(softmax_mode="uniform") is None
    assert fused_attention_supported(softmax_mode="pot_fine") is None
    assert fused_attention_supported(hw=True)
    assert fused_attention_supported(fidelity="acam")
    assert fused_attention_supported(softmax_mode="nonsense")


def test_execconfig_serving_defaults_fused():
    ec = ExecConfig.serving()
    assert ec.mode == "raceit" and ec.fused_attention
    assert ExecConfig.serving(mode="digital").fused_attention
    assert not ExecConfig.serving(fused_attention=False).fused_attention
    assert not ExecConfig().fused_attention  # plain default stays staged


def test_core_raceit_attention_accepts_uniform_fused(rng):
    q = jnp.asarray(rng.normal(0, 1.5, (1, 2, 24, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1.5, (1, 2, 24, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1.5, (1, 2, 24, 8)), jnp.float32)
    want = raceit_attention(q, k, v, softmax_mode="uniform")
    got = raceit_attention(q, k, v, softmax_mode="uniform", fused=True)
    _assert_parity(got, want, v)
