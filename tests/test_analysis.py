"""Tier-1 gate for `repro.analysis`: the passes prove the repo clean, the
adversarial fixture corpus proves the passes can still see, and the
defects this PR fixed stay fixed (each with the pre-fix code preserved as
a fixture the pass must flag).
"""
import ast
import dataclasses
import pathlib
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import __main__ as analysis_cli
from repro.analysis import kernelcheck, tracelint
from repro.analysis.findings import (RULES, Finding, Suppression,
                                     apply_suppressions, load_suppressions)

FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"
sys.path.insert(0, str(FIXTURES))

import broken_specs  # noqa: E402
import routing_broken  # noqa: E402


# ---------------------------------------------------------------------------
# the acceptance gate: strict run is clean, coverage is total
# ---------------------------------------------------------------------------

def test_strict_run_is_clean(analysis_results):
    r = analysis_results
    assert not r["malformed"], [f.render() for f in r["malformed"]]
    assert not r["active"], [f.render() for f in r["active"]]
    assert not r["stale"], [f.render() for f in r["stale"]]
    # suppressions exist and every one is live (matched a real finding)
    assert r["suppressed"], "expected justified suppressed findings"


def test_every_pallas_kernel_covered(analysis_results):
    cov = analysis_results["coverage"]
    # every kernel module with a pallas_call is in the capture set
    assert set(cov["kernelcheck.kernel_modules"]) == {
        "src/repro/kernels/acam_attention.py",
        "src/repro/kernels/acam_lut.py",
        "src/repro/kernels/acam_mvm.py",
        "src/repro/kernels/acam_softmax.py",
    }
    assert cov["kernelcheck.spec_sites"] >= 26
    assert cov["kernelcheck.index_map_sites"] >= 20
    assert cov["kernelcheck.frontier_domains"] >= 60
    assert cov["kernelcheck.grid_points"] >= 200


def test_probe_matrix_spans_required_domains():
    names = [p.name for p in kernelcheck._probes()]
    fams = {p.name: p for p in kernelcheck._probes()}
    # scalar AND per-group-vector kv_len, paged AND contiguous, gqa,
    # chunked prefill with mask, one-tile degenerate grid
    assert any("scalar" in n for n in names)
    assert any("rows" in n for n in names)
    assert any("onetile" in n for n in names)
    assert sum(1 for p in fams.values() if p.paged) >= 3
    assert any("gqa_paged" in n for n in names)
    assert any("chunk" in n and fams[n].paged for n in names)
    scalar = next(p for p in fams.values() if "scalar" in p.name)
    assert not scalar.kv_vector
    rows = next(p for p in fams.values() if "rows" in p.name)
    assert rows.kv_vector


def test_dispatch_audit_confirms_totality(analysis_results):
    cov = analysis_results["coverage"]
    assert cov["plan_audit.unreachable"] == []
    assert cov["plan_audit.backends"] >= 20
    assert cov["plan_audit.plans_resolved"] == (
        cov["plan_audit.models"] * cov["plan_audit.exec_configs"])
    assert not any(f.rule.startswith("PA")
                   for f in analysis_results["findings"])


def test_cli_strict_exit_codes(analysis_results, monkeypatch, capsys):
    r = analysis_results
    monkeypatch.setattr(
        analysis_cli, "run_all",
        lambda: (r["findings"], r["coverage"], r["contracts"]))
    assert analysis_cli.main(["--strict"]) == 0
    out = capsys.readouterr().out
    assert "analysis: CLEAN" in out
    # an unsuppressed finding must flip strict to exit 1 (and only strict)
    bad = Finding("kernelcheck", "KC101", "src/x.py", 1, "s", "boom")
    monkeypatch.setattr(
        analysis_cli, "run_all",
        lambda: (r["findings"] + [bad], r["coverage"], r["contracts"]))
    assert analysis_cli.main(["--strict"]) == 1
    assert analysis_cli.main([]) == 0


# ---------------------------------------------------------------------------
# adversarial fixtures: every planted violation must be flagged
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fixture", broken_specs.ALL,
                         ids=lambda f: f.__name__)
def test_kernelcheck_flags_broken_fixture(fixture):
    probe, call, expected_rule = fixture()
    findings, _ = kernelcheck.analyze_call(probe, call)
    rules = {f.rule for f in findings}
    assert expected_rule in rules, (
        f"{fixture.__name__}: expected {expected_rule}, got "
        f"{[f.render() for f in findings] or 'nothing'}")


def test_kernelcheck_fixture_rules_span_the_ruleset():
    expected = {f()[2] for f in broken_specs.ALL}
    assert expected >= {"KC101", "KC102", "KC104", "KC105", "KC106",
                        "KC109"}


def test_write_fence_flags_prefix_routing():
    # the exact routing shipped before this PR, preserved as a fixture
    f_chunk = kernelcheck.check_write_fence(
        route_chunk=routing_broken.chunk_targets_unfenced)
    assert any(x.rule == "KC107" and "chunk" in x.site for x in f_chunk)
    f_dec = kernelcheck.check_write_fence(
        route_decode=routing_broken.decode_targets_unfenced)
    assert any(x.rule == "KC107" and "decode" in x.site for x in f_dec)


def test_write_fence_passes_fixed_routing():
    assert kernelcheck.check_write_fence() == []


def test_allocator_never_issues_trash_page():
    assert kernelcheck.check_allocator() == []


def test_tracelint_flags_tainted_fixture():
    src = (FIXTURES / "tainted_trace.py").read_text()
    findings, stats = tracelint.lint_source(src, "tainted_trace.py",
                                            in_kernels=True)
    by_site = {}
    for f in findings:
        by_site.setdefault(f.site, set()).add(f.rule)
    assert "TL101" in by_site.get("branch_on_traced", set())
    assert "TL101" in by_site.get("while_on_traced", set())
    assert "TL101" in by_site.get("_tainted_kernel", set())
    assert "TL102" in by_site.get("concretize_int", set())
    assert "TL102" in by_site.get("concretize_item", set())
    assert "clean_static_branches" not in by_site, by_site
    assert stats["traced_scopes"] >= 6


def test_tracelint_flags_broken_cache_key():
    src = (FIXTURES / "tainted_trace.py").read_text()
    tree = ast.parse(src)
    cls = next(n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)
               and n.name == "BrokenCacheKey")
    findings = tracelint._lint_cache_key_class(cls, "tainted_trace.py")
    msgs = [f.message for f in findings]
    assert all(f.rule == "TL104" for f in findings)
    assert any("unhashable" in m for m in msgs)              # tags: list
    assert any("hash(self.noise)" in m for m in msgs)        # opaque noise
    assert any("op_overrides" in f.site and "canonicalize" in f.message
               for f in findings)                            # order
    assert len(findings) == 3


def test_tracelint_accepts_fixed_execconfig():
    # the shipped ExecConfig (post __post_init__ guards) must lint clean
    findings, stats = tracelint.run()
    assert "ExecConfig" in stats["cache_key_classes"]
    assert not [f for f in findings if f.rule == "TL104"], \
        [f.render() for f in findings]


# ---------------------------------------------------------------------------
# regression tests for the defects the passes surfaced (satellite a)
# ---------------------------------------------------------------------------

def test_paged_write_overflow_routes_to_trash_page():
    """Pre-fix: a slot filled past block-table capacity wrote into its own
    last live page (silent corruption); fixed routing fences to page 0."""
    from repro.models.layers import (paged_write_targets_chunk,
                                     paged_write_targets_decode)
    ps, mp = 4, 2
    bt = jnp.asarray([[3, 5]], jnp.int32)
    cap = ps * mp

    # decode one past capacity: fenced -> trash, unfenced -> last live page
    pages, _ = paged_write_targets_decode(bt, jnp.asarray([cap + 1]), ps)
    assert int(pages[0]) == 0
    old_pages, _ = routing_broken.decode_targets_unfenced(
        bt, jnp.asarray([cap + 1]), ps)
    assert int(old_pages[0]) == 5      # the corruption the fence prevents

    # chunk write straddling capacity: overflow columns -> trash only
    pages, slots = paged_write_targets_chunk(
        bt, jnp.asarray([cap + 2]), jnp.asarray([cap - 1]), 4, ps)
    assert pages.tolist() == [[5, 0, 0, 0]]
    old_pages, _ = routing_broken.chunk_targets_unfenced(
        bt, jnp.asarray([cap + 2]), jnp.asarray([cap - 1]), 4, ps)
    # cols cap..cap+1 are "live" pre-fix and clamp into live page 5
    assert old_pages.tolist() == [[5, 5, 5, 0]]

    # in-capacity behavior identical to the pre-fix routing
    lens, offs = jnp.asarray([6]), jnp.asarray([3])
    new = paged_write_targets_chunk(bt, lens, offs, 4, ps)
    old = routing_broken.chunk_targets_unfenced(bt, lens, offs, 4, ps)
    assert np.array_equal(new[0], old[0]) and np.array_equal(new[1], old[1])


def test_execconfig_overrides_are_order_canonical():
    """Pre-fix: permuted op_overrides minted distinct plan-cache keys."""
    from repro.configs.base import ExecConfig
    a = ExecConfig(op_overrides=(("softmax", "digital"),
                                 ("lm_head", "raceit_q8")))
    b = ExecConfig(op_overrides=(("lm_head", "raceit_q8"),
                                 ("softmax", "digital")))
    assert a == b and hash(a) == hash(b)
    # later pins win on duplicate slots, matching with_ops semantics
    c = ExecConfig(op_overrides=(("lm_head", "digital"),
                                 ("lm_head", "raceit_q8")))
    assert dict(c.op_overrides) == {"lm_head": "raceit_q8"}
    assert a == a.with_ops(lm_head="raceit_q8")


def test_execconfig_rejects_unhashable_noise():
    """Pre-fix: an unhashable noise value exploded at first resolve_plan
    deep inside dispatch; now it fails fast at construction."""
    from repro.configs.base import ExecConfig
    with pytest.raises(TypeError, match="noise must be hashable"):
        ExecConfig(noise={"sigma": 0.1})
    from repro.hw.noise import NoiseConfig
    ExecConfig(noise=NoiseConfig.preset("nominal"))   # frozen: fine


# ---------------------------------------------------------------------------
# suppression hygiene
# ---------------------------------------------------------------------------

def test_stale_suppression_is_a_finding(tmp_path):
    f = Finding("kernelcheck", "KC101", "src/a.py", 3, "site", "msg")
    live = Suppression("KC101", "src/a.py", "site", "why", 1)
    stale = Suppression("KC102", "src/b.py", "gone", "why", 2)
    active, suppressed, stale_out = apply_suppressions([f], [live, stale])
    assert active == [] and suppressed == [f]
    assert len(stale_out) == 1 and stale_out[0].rule == "SUP001"
    assert stale_out[0].line == 2


def test_malformed_and_unknown_rule_suppressions(tmp_path):
    p = tmp_path / "sups.txt"
    p.write_text("# comment\n"
                 "KC101 | src/a.py | frag | justified\n"
                 "not enough fields\n"
                 "NOPE99 | src/a.py | frag | why\n"
                 "KC101 | src/a.py | frag |\n")
    sups, bad = load_suppressions(p)
    assert len(sups) == 1
    assert len(bad) == 3 and all(f.rule == "SUP002" for f in bad)


def test_rule_registry_is_closed():
    assert all(r in RULES for r in
               ("KC101", "KC107", "TL101", "TL104", "PA101", "SUP001"))
    # every committed suppression names a known rule and parses clean
    sups, bad = load_suppressions()
    assert not bad and sups, "committed suppression file must parse clean"


# ---------------------------------------------------------------------------
# interval/symbolic domain unit checks (the proof substrate)
# ---------------------------------------------------------------------------

def test_interval_arithmetic_soundness():
    from repro.analysis.intervals import Iv
    assert (Iv(0, 5) + 3) == Iv(3, 8)
    assert (Iv(2, 7) - Iv(1, 2)) == Iv(0, 6)
    assert (Iv(-2, 3) * 4) == Iv(-8, 12)
    assert (Iv(5, 13) // 4) == Iv(1, 3)
    assert (Iv(5, 7) % 4) == Iv(1, 3)        # same quotient: exact
    assert (Iv(3, 9) % 4) == Iv(0, 3)        # quotient straddles: widen
    assert Iv.min2(Iv(0, 9), 4) == Iv(0, 4)
    assert Iv.max2(Iv(0, 9), Iv(-1, 2)) == Iv(0, 9)
    with pytest.raises(ValueError):
        Iv(0, 4) // Iv(1, 2)                 # non-constant divisor


def test_symbolic_fixed_point_equality():
    from repro.analysis.intervals import Sym
    a = Sym.var(("bt", 0, 3)) * 2 + 1
    b = Sym.var(("bt", 0, 3)) * 2 + 1
    c = Sym.var(("bt", 0, 4)) * 2 + 1
    assert a == b
    assert a != c                            # different table cell read
