import os

# smoke tests and benches must see 1 device (the dry-run sets its own flags
# in a separate process); keep CPU math deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

import jax
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def analysis_results():
    """One shared `repro.analysis` run (the kernel capture re-traces every
    wrapper, ~a minute) — test_analysis.py and the test_docs.py contract
    sync check both read from here instead of re-running the passes."""
    from repro import analysis
    findings, coverage, contracts = analysis.run_all()
    sups, malformed = analysis.load_suppressions()
    active, suppressed, stale = analysis.apply_suppressions(findings, sups)
    return dict(findings=findings, coverage=coverage, contracts=contracts,
                malformed=malformed, active=active, suppressed=suppressed,
                stale=stale)


def tiny_config(cfg):
    """Reduced same-family config for per-arch smoke tests."""
    kw = dict(d_model=64, d_ff=128, vocab_size=256, param_dtype="float32",
              compute_dtype="float32", max_seq_len=128, window=8)
    P = cfg.block_period
    kw["n_layers"] = min(cfg.n_layers, 2 * P + (1 if cfg.n_layers % P else 0))
    if cfg.n_heads:
        kw.update(n_heads=4, head_dim=16,
                  n_kv_heads=(min(cfg.n_kv_heads, 2)
                              if cfg.n_kv_heads < cfg.n_heads else 4))
    if cfg.head_pad_to:
        kw["head_pad_to"] = 6
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=min(cfg.top_k, 2), capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_headdim=8, ssm_chunk=8)
    if cfg.is_encoder_decoder:
        kw.update(n_encoder_layers=2, encoder_len=12)
    return cfg.replace(**kw)
