"""Tainted AST snippets the trace lint MUST flag (and clean ones it must
not). Never imported at test time — `tests/test_analysis.py` feeds this
file's *source* to `tracelint.lint_source` and checks the expected rules
fire on the expected functions, so the lint can't silently go blind.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=("mode",))
def branch_on_traced(x, mode):
    if x > 0:                              # TL101: traced branch
        return x
    return -x


@partial(jax.jit, static_argnames=("mode",))
def while_on_traced(x, mode):
    s = x.sum()
    while s > 0:                           # TL101: via taint propagation
        s = s - 1
    return s


@partial(jax.jit, static_argnames=())
def concretize_int(x):
    n = int(x.sum())                       # TL102: int() on a tracer
    return x * n


@partial(jax.jit, static_argnames=())
def concretize_item(x):
    return x * x.max().item()              # TL102: .item() on a tracer


def _tainted_kernel(x_ref, o_ref, *, bias):
    v = x_ref[0, 0]
    if v > bias:                           # TL101: kernel-scope branch
        o_ref[0, 0] = v


@partial(jax.jit, static_argnames=("mode",))
def clean_static_branches(x, mask, mode):
    # none of these may fire: static arg, is-None test, shape inspection
    if mode == "uniform":
        x = x * 2
    if mask is not None:
        x = jnp.where(mask, x, 0)
    if x.ndim == 3:
        x = x[None]
    if len(x.shape) > 2 and x.shape[0] > 4:
        x = x.reshape(-1, x.shape[-1])
    return x


@dataclasses.dataclass(frozen=True)
class BrokenCacheKey:
    """Pre-fix ExecConfig shape: every TL104 defect class in one key."""

    mode: str = "digital"
    # sorted by with_ops below but not canonicalized at construction
    op_overrides: tuple = ()
    # opaque annotation, no fail-fast hash() guard anywhere
    noise: Optional[object] = None
    # unhashable member in an lru_cache key
    tags: list = dataclasses.field(default_factory=list)

    def with_ops(self, **slot_backends):
        merged = dict(self.op_overrides)
        merged.update(slot_backends)
        return dataclasses.replace(
            self, op_overrides=tuple(sorted(merged.items())))
