"""The pre-fix paged write routing, kept as a regression fixture.

This is the routing `models.layers.attention` shipped between PR 7 and
this PR: the chunk path clamps an overflowing column's *table index*
(`minimum(cols // ps, mp - 1)`) instead of fencing the write, and the
decode path doesn't consider capacity at all — so a slot filled past
`block_table.shape[1] * page_size` silently overwrites the slot's last
live page while the read side caps `kv_len` at capacity. The fixed
helpers live in `models.layers.paged_write_targets_{chunk,decode}`;
`kernelcheck.check_write_fence` run against *these* functions must
report KC107, proving the pass catches the pre-fix code.
"""
from __future__ import annotations

import jax.numpy as jnp


def chunk_targets_unfenced(block_table, lens, chunk_offs, sq, page_size):
    ps = int(page_size)
    bt = jnp.asarray(block_table, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    offs = jnp.asarray(chunk_offs, jnp.int32)
    rows = jnp.arange(bt.shape[0])
    cols = offs[:, None] + jnp.arange(sq, dtype=jnp.int32)[None, :]
    live = cols < lens[:, None]                      # no capacity clause
    pages = jnp.where(live, bt[rows[:, None],
                               jnp.minimum(cols // ps, bt.shape[1] - 1)], 0)
    slots = jnp.where(live, cols % ps, 0)
    return pages, slots


def decode_targets_unfenced(block_table, lens, page_size):
    ps = int(page_size)
    bt = jnp.asarray(block_table, jnp.int32)
    lens = jnp.asarray(lens, jnp.int32)
    rows = jnp.arange(bt.shape[0])
    pos = jnp.maximum(lens - 1, 0)
    pages = jnp.where(lens > 0, bt[rows, pos // ps], 0)  # no capacity fence
    return pages, pos % ps
