"""Deliberately broken synthetic kernels the contract checker MUST flag.

Each fixture returns (probe, call, expected_rule): a hand-built
`CapturedCall` whose index maps reproduce a specific contract violation.
`tests/test_analysis.py` fails if `analyze_call` passes any of them —
an analyzer that goes blind can never rot silently.

The shapes mirror the real decode geometry (bg=1, bk=512, Smax=2048) so
a fixture failing to trip its rule means the rule is broken, not that
the fixture drifted from the kernel idiom.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.analysis.kernelcheck import CapturedCall, PagedMeta, Probe

F32, I32 = jnp.float32, jnp.int32
BK = 512
SMAX = 2048
NK = SMAX // BK


@dataclasses.dataclass
class FakeSpec:
    block_shape: tuple
    index_map: object


def _st(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _probe(name, family="attention", smax=SMAX, paged=None):
    return Probe(name=name, family=family, fn_name=f"fixture.{name}",
                 build=None, smax=smax, kv_vector=True, paged=paged)


def _zero2(p, g, i, k, kvl, kvm):
    return (0, 0)


def _q_map(p, g, i, k, kvl, kvm):
    return (g, i, 0)


def _lut_map(p, g, i, k, kvl, kvm):
    return (0,)


def _clamped_kv(p, g, i, k, kvl, kvm):
    last = jnp.maximum((kvm[g] + BK - 1) // BK - 1, 0)
    return (g, jnp.minimum(k, last), 0)


def _attention_call(k_map, v_map=None, out_map=None, nsp=2, operands=None,
                    in_specs=None, grid=(2, 1, 1, NK)):
    """A decode-shaped call: [scale, qoff, cmax_floor, q, k, v, lut x3]
    + (out, cmax)."""
    v_map = v_map or _clamped_kv
    out_map = out_map or _q_map
    specs = in_specs or [
        FakeSpec((1, 1), _zero2),              # scale
        FakeSpec((1, 1), _zero2),              # qoff
        FakeSpec((1, 1), _zero2),              # cmax_floor
        FakeSpec((1, 1, 64), _q_map),          # q
        FakeSpec((1, BK, 64), k_map),          # k
        FakeSpec((1, BK, 64), v_map),          # v
        FakeSpec((256,), _lut_map),            # lut_exp
        FakeSpec((256,), _lut_map),            # lut_log
        FakeSpec((256,), _lut_map),            # lut_prob
    ]
    prefetch = [_st((4,), I32), _st((1,), I32)]
    if nsp == 3:
        prefetch.append(_st((1, 4), I32))
    ops = operands or [
        _st((1, 1)), _st((1, 1)), _st((1, 1), I32), _st((1, 1, 64)),
        _st((1, SMAX, 64)), _st((1, SMAX, 64)),
        _st((256,), I32), _st((256,), I32), _st((256,), I32),
    ]
    return CapturedCall(
        grid=grid, num_scalar_prefetch=nsp, in_specs=specs,
        out_specs=[FakeSpec((1, 1, 64), out_map), FakeSpec((1, 1), _zero2)],
        scratch=[],
        out_shape=(_st((1, 1, 64)), _st((1, 1))),
        operands=prefetch + ops,
        kernel_name="fixture")


# ---------------------------------------------------------------------------
# the corpus
# ---------------------------------------------------------------------------

def off_by_one_index_map():
    """Classic +1 in a static row map: last grid step reads past the end."""
    call = CapturedCall(
        grid=(4,), num_scalar_prefetch=0,
        in_specs=[FakeSpec((256, 512), lambda i: (i + 1, 0)),
                  FakeSpec((256,), lambda i: (0,))],
        out_specs=[FakeSpec((256, 512), lambda i: (i, 0))],
        scratch=[], out_shape=_st((1024, 512), I32),
        operands=[_st((1024, 512), I32), _st((256,), I32)],
        kernel_name="fixture")
    return _probe("fx_off_by_one", family="lut", smax=0), call, "KC101"


def unclamped_dead_block():
    """k/v map streams block k unconditionally — dead blocks DMA fresh
    tiles and (worse) the quantizer sees garbage keys."""
    def k_map(p, g, i, k, kvl, kvm):
        return (g, k, 0)
    return _probe("fx_unclamped"), _attention_call(k_map), "KC102"


def off_frontier_clamp():
    """Clamps, but to `ceil(kvm/bk)` instead of `ceil(kvm/bk) - 1`: the
    first dead block is fetched once more past the frontier — the
    off-by-one this proof exists for."""
    def k_map(p, g, i, k, kvl, kvm):
        last_plus_one = (kvm[g] + BK - 1) // BK
        return (g, jnp.minimum(k, last_plus_one), 0)
    return _probe("fx_off_frontier"), _attention_call(k_map), "KC102"


def prefetch_vector_oob():
    """Indexes the per-group kv_len vector past its length."""
    def k_map(p, g, i, k, kvl, kvm):
        last = jnp.maximum((kvm[g + 5] + BK - 1) // BK - 1, 0)
        return (g, jnp.minimum(k, last), 0)
    return _probe("fx_prefetch_oob"), _attention_call(k_map), "KC109"


def out_map_reads_prefetch():
    """Output routing through runtime lengths: the write side must be
    length-independent (fencing lives in the serving layer)."""
    def out_map(p, g, i, k, kvl, kvm):
        return (jnp.minimum(kvm[g] // SMAX, 0), i, 0)
    return (_probe("fx_out_prefetch"),
            _attention_call(_clamped_kv, out_map=out_map), "KC104")


def paged_column_past_frontier():
    """Paged map clamps the slot dim but consults block-table column
    k//spb raw — a dead step reads table entries past the live frontier
    (and the address is no longer a fixed point)."""
    ps, mp, n_pages, spb = 512, 4, 5, 1

    def k_map(p, g, i, k, kvl, kvm, bt):
        last = jnp.maximum((kvm[g] + BK - 1) // BK - 1, 0)
        kc = jnp.minimum(k, last)
        page = bt[0, k // spb]          # should be kc // spb
        return (page, kc % spb, 0)

    def v_map(p, g, i, k, kvl, kvm, bt):
        last = jnp.maximum((kvm[g] + BK - 1) // BK - 1, 0)
        kc = jnp.minimum(k, last)
        return (bt[0, kc // spb], kc % spb, 0)

    pool = _st((n_pages, ps, 64))
    ops = [_st((1, 1)), _st((1, 1)), _st((1, 1), I32), _st((1, 1, 64)),
           pool, pool,
           _st((256,), I32), _st((256,), I32), _st((256,), I32)]
    specs = [
        FakeSpec((1, 1), lambda p, g, i, k, kvl, kvm, bt: (0, 0)),
        FakeSpec((1, 1), lambda p, g, i, k, kvl, kvm, bt: (0, 0)),
        FakeSpec((1, 1), lambda p, g, i, k, kvl, kvm, bt: (0, 0)),
        FakeSpec((1, 1, 64), lambda p, g, i, k, kvl, kvm, bt: (g, i, 0)),
        FakeSpec((1, ps, 64), k_map),
        FakeSpec((1, ps, 64), v_map),
        FakeSpec((256,), lambda p, g, i, k, kvl, kvm, bt: (0,)),
        FakeSpec((256,), lambda p, g, i, k, kvl, kvm, bt: (0,)),
        FakeSpec((256,), lambda p, g, i, k, kvl, kvm, bt: (0,)),
    ]
    call = _attention_call(k_map, nsp=3, operands=ops, in_specs=specs)
    # out maps in the paged call take the bt ref too
    call.out_specs = [
        FakeSpec((1, 1, 64), lambda p, g, i, k, kvl, kvm, bt: (g, i, 0)),
        FakeSpec((1, 1), lambda p, g, i, k, kvl, kvm, bt: (0, 0))]
    probe = _probe("fx_paged_frontier",
                   paged=PagedMeta(ps, mp, n_pages, 1))
    return probe, call, "KC105"


def vmem_blowup():
    """A whole-array block: 2 x 4096 x 4096 x f32 double-buffered blows
    any 16 MiB budget."""
    call = CapturedCall(
        grid=(1,), num_scalar_prefetch=0,
        in_specs=[FakeSpec((4096, 4096), lambda i: (0, 0)),
                  FakeSpec((256,), lambda i: (0,))],
        out_specs=[FakeSpec((4096, 4096), lambda i: (0, 0))],
        scratch=[], out_shape=_st((4096, 4096)),
        operands=[_st((4096, 4096)), _st((256,), I32)],
        kernel_name="fixture")
    return _probe("fx_vmem", family="lut", smax=0), call, "KC106"


ALL = [off_by_one_index_map, unclamped_dead_block, off_frontier_clamp,
       prefetch_vector_oob, out_map_reads_prefetch,
       paged_column_past_frontier, vmem_blowup]
