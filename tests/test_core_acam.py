"""Core Compute-ACAM properties: compiler exactness, Gray coding, formats."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (FixedPointFormat, PoTFormat, compile_1var,
                        compile_2var, eval_range_program, eval_rect_program,
                        gray_decode, gray_encode, mult8_codes, ops)
from repro.core.compiler import ACAM_ARRAY_COLS
from repro.core.gray import gray_decode_bits


# ---------------------------------------------------------------- gray code
@given(st.integers(0, 2**16 - 1))
def test_gray_roundtrip(n):
    assert gray_decode(gray_encode(n), 16) == n


@given(st.integers(0, 2**12 - 2))
def test_gray_adjacent_single_bit(n):
    diff = gray_encode(n) ^ gray_encode(n + 1)
    assert bin(diff).count("1") == 1


def test_gray_decode_bits_matches_scalar():
    vals = np.arange(256, dtype=np.uint32)
    g = gray_encode(vals)
    bits = np.stack([(g >> b) & 1 for b in range(7, -1, -1)], -1)
    dec_bits = gray_decode_bits(bits, axis=-1)
    dec = sum(dec_bits[:, i].astype(np.uint32) << (7 - i) for i in range(8))
    assert (dec == vals).all()


# ---------------------------------------------------------------- compiler
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([4, 6, 8]),
       st.booleans())
def test_random_lut_range_program_exact(seed, bits, encode):
    """THE invariant: any truth table compiles to an equivalent range program."""
    rng = np.random.default_rng(seed)
    table = rng.integers(0, 1 << bits, 1 << bits).astype(np.uint32)
    prog = compile_1var(table, bits, encode=encode)
    got = eval_range_program(prog, np.arange(1 << bits))
    assert (got == table).all()


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**32 - 1), st.booleans())
def test_random_2var_rect_program_exact(seed, encode):
    rng = np.random.default_rng(seed)
    table = rng.integers(0, 256, (16, 16)).astype(np.uint32)
    prog = compile_2var(table, 8, encode=encode)
    xi, yi = np.meshgrid(np.arange(16), np.arange(16), indexing="ij")
    assert (eval_rect_program(prog, xi, yi) == table).all()


def test_encoding_reduces_cells_paper_fig9():
    """Gray encoding ~halves LSB run counts (paper §V-A)."""
    op_plain = ops.get_op("gelu", encode=False)
    op_enc = ops.get_op("gelu", encode=True)
    assert op_enc.program.num_cells < op_plain.program.num_cells
    # paper reports 22-35% operator-level reduction; ours is in-family
    red = 1 - op_enc.program.rows_needed() / op_plain.program.rows_needed()
    assert 0.15 < red < 0.6


def test_fig7_multiplication_cell_counts():
    """Rect cover matches the paper's Fig. 7 counts (8/21/36/58) closely."""
    m = ops.mult4_paper(encode=False)
    ours = m.program.cells_per_bit
    paper = [8, 21, 36, 58]
    for o, p in zip(ours, paper):
        assert abs(o - p) <= 2, (ours, paper)


def test_all_ops_hw_equals_lut():
    for name in ops.OPS:
        op = ops.get_op(name)
        lo = getattr(op.in_fmt, "code_min", 0)
        codes = jnp.arange(op.in_fmt.num_codes) + lo
        a = op.apply_codes(codes, hw=False)
        b = op.apply_codes(codes, hw=True)
        assert (np.asarray(a) == np.asarray(b)).all(), name


# ---------------------------------------------------------------- formats
@settings(max_examples=50, deadline=None)
@given(st.integers(0, 3), st.integers(0, 6),
       st.floats(-100, 100, allow_nan=False))
def test_fixed_point_quantize_bounds(i, f, x):
    fmt = FixedPointFormat(int_bits=i, frac_bits=f)
    q = float(fmt.quantize_value(np.asarray([x]))[0])
    assert fmt.min_value <= q <= fmt.max_value
    if fmt.min_value <= x <= fmt.max_value:
        assert abs(q - x) <= fmt.scale / 2 + 1e-9


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-6, 1e6))
def test_pot_relative_error_bound(x):
    fmt = PoTFormat(e_min=-24)
    q = float(fmt.quantize_value(np.asarray([x], np.float64))[0])
    assert q > 0
    assert 2 ** -0.5 - 1e-6 <= q / x <= 2 ** 0.5 + 1e-6


@settings(max_examples=50, deadline=None)
@given(st.floats(1e-6, 1e6))
def test_pot_fine_tighter_than_pot(x):
    fine = PoTFormat(e_min=-24, octave_step=0.25)
    q = float(fine.quantize_value(np.asarray([x], np.float64))[0])
    assert 2 ** -0.125 - 1e-6 <= q / x <= 2 ** 0.125 + 1e-6


# ---------------------------------------------------------------- mult8
def test_mult8_exhaustive():
    x = jnp.arange(-128, 128, dtype=jnp.int32)
    X, Y = jnp.meshgrid(x, x, indexing="ij")
    assert (np.asarray(mult8_codes(X, Y)) == np.asarray(X) * np.asarray(Y)).all()


def test_array_sizing_budget():
    """454 4-bit multipliers + 16 exp units fit the 1280-array GCE (§VI)."""
    from repro.hw.area import gce_unit_arrays
    u = gce_unit_arrays()
    total = 454 * u["mult4_arrays_frac"] + 16 * u["exp8"] + u["log8"] + u["act8"]
    assert total <= 1280 * 1.02
