"""Per-row kv_len decode: every row at its own fill level, bit-exactly.

The tentpole contract of the per-row decode kernels
(`acam_attention_decode_codes` / `acam_attention_decode_gqa_codes` with a
kv_len *vector*): each batch row attends exactly the first ``kv_len[b]``
cache columns — keys past a row's own fill level are *nonexistent* for
that row (no exp weight, no PROB-max contribution, no matmul-2 term, no
quantizer-scale contribution), a zero-length row outputs exact zeros (the
empty-slot case, riding the PR 4 fully-masked-row semantics), and the
shared int8 scales reduce over the *union* of the rows' valid prefixes
(the batched-raceit quantizer granularity).

Oracles:

* a **per-row staged oracle** built from the same core stages
  (`quantize_tensor` / `masked_prefix_quantize` / `acam_softmax`) with the
  per-row probability rows computed on each row's own slice and one shared
  PROB re-quantization across rows — exactly the Fig.-12 pipeline with
  per-request lengths;
* the **flat kernel at the max fill** with each row's tail masked out via
  the pad-mask operand — bit-identical when the buffers carry zeros past
  each row's fill (the masked-to-LOGIT-min exp weight is exactly 0), which
  is also the `raceit_fused`/`raceit_gqa_native` backends' degrade path
  for vector kv_len.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ExecConfig, ModelConfig
from repro.core.ops import PROB_FMT
from repro.core.quant import quantize_tensor
from repro.core.softmax import acam_softmax
from repro.exec import resolve_plan
from repro.kernels.ops import (masked_prefix_quantize,
                               raceit_attention_decode_fused,
                               raceit_attention_decode_gqa)
from repro.models import layers

LENS = (96, 33, 1, 0)  # one full, one partial, one single-key, one EMPTY row


def _assert_parity(got, want, v):
    """Bit-exact, with the <=1 PROB ulp acceptance bound as the hard floor
    (the jitted wrappers' final descale multiply may fuse differently than
    the eagerly-evaluated oracle — same bound as tests/test_attention_gqa)."""
    got, want = np.asarray(got), np.asarray(want)
    if np.array_equal(got, want):
        return
    ulp = PROB_FMT.scale * float(jnp.max(jnp.abs(v)))
    np.testing.assert_allclose(got, want, atol=ulp, rtol=0)


def _case(rng, rep, B=4, KV=2, Smax=96, D=16, lens=LENS, std=1.5):
    """Native-layout decode case with per-request fills, zeroed tails."""
    H = KV * rep
    mk = lambda s: jnp.asarray(rng.normal(0, std, s), jnp.float32)
    q = mk((B, H, 1, D))
    k = jnp.zeros((B, KV, Smax, D), jnp.float32)
    v = jnp.zeros((B, KV, Smax, D), jnp.float32)
    for b, ln in enumerate(lens):
        k = k.at[b, :, :ln].set(mk((KV, ln, D)))
        v = v.at[b, :, :ln].set(mk((KV, ln, D)))
    return q, k, v, jnp.asarray(lens, jnp.int32)


def _perrow_staged_oracle(q, k, v, lens, mode):
    """The Fig.-12 stages with per-row lengths and shared quantizers.

    q (B, H, 1, D); k/v (B, H, Smax, D) with zeroed tails. Probabilities
    are computed per row on its own slice (keys past the row's fill do
    not exist), then re-quantized with ONE tensor-wide scale — the exact
    contract the per-row kernel implements online.
    """
    B, H, _, D = q.shape
    Smax = k.shape[2]
    qq = quantize_tensor(q, bits=8)
    k_codes, k_scale = masked_prefix_quantize(k, lens, axis=2)
    v_codes, v_scale = masked_prefix_quantize(v, lens, axis=2)
    s = jnp.einsum("bhqd,bhcd->bhqc", qq.codes.astype(jnp.int32),
                   k_codes.astype(jnp.int32)).astype(jnp.float32)
    logits = s * (qq.scale * k_scale) / jnp.sqrt(jnp.float32(D))
    probs = jnp.zeros((B, H, 1, Smax), jnp.float32)
    for b, ln in enumerate(np.asarray(lens)):
        if ln == 0:
            continue  # no keys exist: the row's probabilities are empty
        pr = acam_softmax(logits[b:b + 1, :, :, :int(ln)], axis=-1, mode=mode)
        probs = probs.at[b:b + 1, :, :, :int(ln)].set(pr)
    pq = quantize_tensor(probs, bits=8)  # shared scale; zero rows stay zero
    out = jnp.einsum("bhqc,bhcd->bhqd", pq.codes.astype(jnp.int32),
                     v_codes.astype(jnp.int32)).astype(jnp.float32)
    return out * (pq.scale * v_scale)


# ---------------------------------------------------------------------------
# kernel wrappers: per-row == per-row staged oracle == flat-at-max + mask
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", (1, 4))
@pytest.mark.parametrize("mode", ["pot", "pot_fine", "uniform"])
def test_perrow_matrix_bitexact_vs_staged_oracle(rng, mode, rep):
    q, k, v, lens = _case(rng, rep)
    kf, vf = (jnp.repeat(a, rep, axis=1) for a in (k, v))
    want = _perrow_staged_oracle(q, kf, vf, lens, mode)
    got_flat = raceit_attention_decode_fused(q, kf, vf, lens,
                                             softmax_mode=mode, block_k=32)
    _assert_parity(got_flat, want, vf)
    got_gqa = raceit_attention_decode_gqa(q, k, v, lens, softmax_mode=mode,
                                          block_k=32)
    np.testing.assert_array_equal(np.asarray(got_gqa), np.asarray(got_flat))


def test_perrow_empty_row_outputs_zeros(rng):
    """kv_len 0 = an empty slot: defined-zero output, and the dead row must
    not pollute the shared PROB re-quantization of the live rows.

    The dead row's *query* is zeroed first: queries are a live activation
    tensor whose whole-tensor int8 scale spans every row (the documented
    batched-raceit coupling); per-row kv_len removes the dead row's
    *cache* and *probability* contributions, which is what is tested."""
    q, k, v, lens = _case(rng, rep=4)
    q = q.at[3].set(0.0)
    out = raceit_attention_decode_gqa(q, k, v, lens, block_k=32)
    np.testing.assert_array_equal(np.asarray(out[3]), 0.0)
    # live rows match the dead row being absent entirely (different batch
    # shape -> different executable, so the <=1-ulp descale bound applies)
    sub = raceit_attention_decode_gqa(q[:3], k[:3], v[:3], lens[:3],
                                      block_k=32)
    _assert_parity(out[:3], sub, v[:3])


@pytest.mark.parametrize("rep", (1, 2))
def test_perrow_bitexact_vs_flat_kernel_at_max_fill(rng, rep):
    """With zeroed tails, per-row kv_len == the flat kernel at the shared
    max fill with each row's tail pad-masked (the degrade path the
    scalar backends serve a vector through) — masked keys carry exactly
    zero exp weight, so 'masked' and 'nonexistent' coincide here."""
    lens = (96, 33, 17, 1)  # the flat+mask path needs >= 1 live key per row
    q, k, v, lv = _case(rng, rep, lens=lens)
    plan = resolve_plan(_gqa_cfg(rep), ExecConfig.serving())
    scale = 1.0 / math.sqrt(q.shape[-1])
    ql = q.transpose(0, 2, 1, 3)   # (B, 1, H, hd) layer layout
    kl = k.transpose(0, 2, 1, 3)   # (B, Smax, KV, hd)
    vl = v.transpose(0, 2, 1, 3)
    Smax = kl.shape[1]
    tail_mask = jnp.arange(Smax)[None, :] < lv[:, None]  # (B, Smax)
    got = layers._raceit_fused_decode(ql, kl, vl, lv, scale, plan)
    # masked_prefix_quantize at max fill sweeps stale tails into the scale
    # window; the tails are zeroed here, so the scales coincide and the
    # comparison is exact
    want = layers._raceit_fused_decode(ql, kl, vl, jnp.max(lv), scale, plan,
                                       pad_valid=tail_mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_perrow_ignores_stale_tails(rng):
    """Garbage past each row's own fill level must touch nothing — not the
    outputs, not the shared quantizer scales (the flat-at-max degrade
    cannot promise the latter; the per-row kernels do)."""
    q, k, v, lens = _case(rng, rep=2, lens=(96, 33, 17, 5))
    out_clean = raceit_attention_decode_gqa(q, k, v, lens, block_k=32)
    k_dirty = k.at[1, :, 33:].set(1e4).at[3, :, 5:].set(-1e4)
    v_dirty = v.at[1, :, 33:].set(-1e4).at[3, :, 5:].set(1e4)
    out_dirty = raceit_attention_decode_gqa(q, k_dirty, v_dirty, lens,
                                            block_k=32)
    np.testing.assert_array_equal(np.asarray(out_clean), np.asarray(out_dirty))


def test_perrow_uniform_vector_equals_scalar(rng):
    """A constant vector is the scalar path, bitwise (flat callers degrade
    cleanly through the per-row backends)."""
    q, k, v, _ = _case(rng, rep=2, lens=(33, 33, 33, 33))
    kf, vf = (jnp.repeat(a, 2, axis=1) for a in (k, v))
    vec = jnp.full((4,), 33, jnp.int32)
    got = raceit_attention_decode_fused(q, kf, vf, vec, block_k=32)
    want = raceit_attention_decode_fused(q, kf, vf, jnp.int32(33), block_k=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_perrow_kv_len_is_traced_one_compile(rng):
    """One executable serves every per-row fill pattern."""
    q, k, v, lens = _case(rng, rep=2)
    fn = lambda lv: raceit_attention_decode_gqa(q, k, v, lv, block_k=32)
    fn(lens)
    traces = raceit_attention_decode_gqa._cache_size()
    fn(jnp.asarray((5, 96, 0, 12), jnp.int32))
    assert raceit_attention_decode_gqa._cache_size() == traces


# ---------------------------------------------------------------------------
# layer adapters + plan dispatch
# ---------------------------------------------------------------------------

def _gqa_cfg(rep, kv=2):
    return ModelConfig(name=f"pr{rep}", n_layers=1, d_model=kv * rep * 16,
                       n_heads=kv * rep, n_kv_heads=kv, d_ff=64,
                       vocab_size=64, head_dim=16, param_dtype="float32",
                       compute_dtype="float32")


def test_layer_adapters_perrow_bitexact_and_plan_dispatch(rng):
    """The rows backends dispatch through the plan with a vector kv_len and
    match the flat backends' max-fill degrade bitwise (zeroed tails)."""
    rep, B, Smax, KV, hd = 4, 4, 64, 2, 16
    plan = resolve_plan(_gqa_cfg(rep), ExecConfig.serving())
    # the paged default serves contiguous callers too (no block table ->
    # falls through to the rows path, still per-row kv_len)
    assert plan.backend("attention_decode") == "raceit_gqa_paged"
    H = KV * rep
    scale = 1.0 / math.sqrt(hd)
    lens = jnp.asarray((64, 20, 7, 0), jnp.int32)
    mk = lambda s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    q = mk((B, 1, H, hd))
    k = jnp.zeros((B, Smax, KV, hd), jnp.float32)
    v = jnp.zeros((B, Smax, KV, hd), jnp.float32)
    for b, ln in enumerate(np.asarray(lens)):
        k = k.at[b, :int(ln)].set(mk((int(ln), KV, hd)))
        v = v.at[b, :int(ln)].set(mk((int(ln), KV, hd)))
    got = plan.attention_decode(q, k, v, kv_len=lens, scale=scale)
    rows_flat = layers._raceit_fused_decode(q, k, v, lens, scale, plan)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(rows_flat))
    # the scalar backends' degrade (max fill + per-row mask) agrees on
    # zeroed tails — pin them explicitly and dispatch the same call
    for pin in ("raceit_gqa_native", "raceit_fused", "raceit_staged",
                "digital"):
        p2 = resolve_plan(_gqa_cfg(rep),
                          ExecConfig.serving().with_ops(attention_decode=pin))
        assert p2.backend("attention_decode") == pin
        out = p2.attention_decode(q, k, v, kv_len=lens, scale=scale)
        if pin.startswith("raceit_gqa") or pin == "raceit_fused":
            np.testing.assert_array_equal(np.asarray(out), np.asarray(got))
        else:  # float-score paths: per-row masks, different numerics
            assert np.asarray(out).shape == np.asarray(got).shape
    # empty slot row through the plan default is exact zeros
    np.testing.assert_array_equal(np.asarray(got[3]), 0.0)


def test_digital_and_staged_backends_accept_vector_kv_len(rng):
    """The float decode paths are per-row-native: a vector kv_len masks
    each row at its own fill, matching per-row slicing."""
    B, Smax, KV, hd, H = 3, 32, 2, 8, 4
    plan = resolve_plan(_gqa_cfg(2, kv=2).replace(head_dim=hd,
                                                  d_model=H * hd),
                        ExecConfig())
    scale = 1.0 / math.sqrt(hd)
    lens = jnp.asarray((32, 11, 4), jnp.int32)
    mk = lambda s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    q, k, v = mk((B, 1, H, hd)), mk((B, Smax, KV, hd)), mk((B, Smax, KV, hd))
    out = plan.attention_decode(q, k, v, kv_len=lens, scale=scale)
    for b, ln in enumerate(np.asarray(lens)):
        ref = plan.attention_decode(q[b:b + 1], k[b:b + 1, :int(ln)],
                                    v[b:b + 1, :int(ln)],
                                    kv_len=jnp.int32(int(ln)), scale=scale)
        np.testing.assert_allclose(np.asarray(out[b]), np.asarray(ref[0]),
                                   rtol=1e-5, atol=1e-6)
