"""Host-side (1-device) coverage for mesh-sharded ExecPlan serving.

Everything here runs without building a mesh: capability predicates and
`resolve_plan` are purely structural (they read `MeshSpec.model_size`,
never `jax.devices()`), `ShardingPolicy`/`param_specs` only consult
``mesh.shape``/``mesh.axis_names``, and the big-config dry-runs use
`jax.eval_shape` — so command-r-35B / mixtral-8x22B-class parameter
trees resolve their sharded serving plans and FSDP placement specs on a
one-CPU pytest process. Actually *running* the TP backends needs
devices: that's `tests/test_sharded_parity.py` (subprocess, 8 simulated
devices).
"""
from types import SimpleNamespace

import jax
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.configs.catalog import ASSIGNED, PAPER_OWN
from repro.dist import MeshSpec
from repro.dist.sharding import ShardingPolicy, param_specs
from repro.exec.plan import layer_plan, resolve_plan
from repro.exec.registry import get_backend

CATALOG = list(ASSIGNED) + list(PAPER_OWN)


def _gqa_cfg(**kw):
    base = dict(n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, d_ff=256,
                vocab_size=512, pos_emb="rope", norm="rmsnorm", glu=False,
                qkv_bias=False, param_dtype="float32",
                compute_dtype="float32", remat="none", tie_embeddings=True)
    base.update(kw)
    return get_config("gpt2-large").replace(name="tp-exec-test", **base)


def _serving(mesh_text=None):
    mesh = MeshSpec.parse(mesh_text) if mesh_text else None
    return ExecConfig.serving(mesh=mesh)


# --------------------------------------------------------------- registry

def test_tp_backends_registered():
    import repro.exec.backends  # noqa: F401 — registration is import-time
    for slot, name in (("attention_prefill", "raceit_fused_tp"),
                       ("attention_decode", "raceit_fused_tp"),
                       ("attention_decode", "raceit_gqa_tp")):
        spec = get_backend(slot, name)
        assert spec is not None, f"{slot}:{name} not registered"
    # both TP decode backends take the block-paged KV pool directly
    assert get_backend("attention_decode", "raceit_fused_tp").paged
    assert get_backend("attention_decode", "raceit_gqa_tp").paged


# ------------------------------------------------------------- resolution

def test_tp_resolution_on_model_mesh():
    plan = resolve_plan(_gqa_cfg(), _serving("model=4"))
    assert plan.backend("attention_decode") == "raceit_gqa_tp"
    assert plan.backend("attention_prefill") == "raceit_fused_tp"
    mha = resolve_plan(_gqa_cfg(n_kv_heads=8), _serving("model=4"))
    assert mha.backend("attention_decode") == "raceit_fused_tp"


def test_tp_resolution_ignores_data_axes():
    """A pure data-parallel mesh is not tensor parallelism."""
    ref = resolve_plan(_gqa_cfg(), _serving())
    dp = resolve_plan(_gqa_cfg(), _serving("data=4"))
    for slot in ("attention_prefill", "attention_decode"):
        assert dp.backend(slot) == ref.backend(slot)
        assert "tp" not in dp.backend(slot)
    mixed = resolve_plan(_gqa_cfg(), _serving("data=2,model=2"))
    assert mixed.backend("attention_decode") == "raceit_gqa_tp"


def test_tp_degrades_without_divisibility():
    """model=3 on n_kv_heads=4: KV-head chunks would straddle shards, so
    the chain falls through to the single-device fused family — same
    backends as no mesh at all, with the reason on the predicate."""
    ref = resolve_plan(_gqa_cfg(), _serving())
    odd = resolve_plan(_gqa_cfg(), _serving("model=3"))
    for slot in ("attention_prefill", "attention_decode"):
        assert odd.backend(slot) == ref.backend(slot)
    reason = get_backend("attention_decode", "raceit_gqa_tp").supported(
        _gqa_cfg(), _serving("model=3"))
    assert reason is not None and "divisible" in reason
    # 1-device meshes degrade with the no-mesh reason
    one = resolve_plan(_gqa_cfg(), _serving("model=1"))
    assert one.backend("attention_decode") == ref.backend("attention_decode")


def test_tp_mesh_is_part_of_plan_cache_key():
    a = resolve_plan(_gqa_cfg(), _serving("model=4"))
    b = resolve_plan(_gqa_cfg(), _serving())
    assert a is not b
    assert a.backend("attention_decode") != b.backend("attention_decode")
    # same spec -> same lru entry
    assert resolve_plan(_gqa_cfg(), _serving("model=4")) is a


def test_layer_overrides_per_mixer_kind():
    """The PR-3 override surface, per layer kind: pin sliding-window
    attn_local layers to the staged path while global attn layers keep
    the TP chain."""
    ec = ExecConfig.serving(
        mesh=MeshSpec.parse("model=4"),
        layer_overrides=(("attn_local",
                          (("attention_prefill", "raceit_staged"),
                           ("attention_decode", "raceit_staged"))),))
    plan = resolve_plan(_gqa_cfg(), ec)
    assert plan.backend("attention_decode") == "raceit_gqa_tp"
    local = layer_plan(plan, "attn_local")
    assert local.backend("attention_decode") == "raceit_staged"
    assert local.backend("attention_prefill") == "raceit_staged"
    # kinds without pins share the incoming plan object (no allocation)
    assert layer_plan(plan, "attn") is plan


# ------------------------------------------- ShardingPolicy edge cases

def _fake_mesh(**shape):
    return SimpleNamespace(axis_names=tuple(shape), shape=dict(shape))


def test_policy_nondividing_assignment_drops_silently():
    pol = ShardingPolicy(_fake_mesh(data=2, model=8))
    spec = pol.spec_for((6, 64), ("heads", "mlp"))
    assert spec[0] is None      # 6 % 8 != 0 -> replicated, no error
    assert spec[1] == "model"


def test_policy_never_reuses_a_mesh_axis():
    pol = ShardingPolicy(_fake_mesh(model=4))
    spec = pol.spec_for((16, 16), ("heads", "mlp"))
    assert spec[0] == "model" and spec[1] is None


def test_policy_on_one_device_mesh():
    """A 1-device mesh must produce valid (trivially replicated) specs,
    not crash — the engine skips device_put at n_devices==1, but
    `make_policy` call sites still build specs."""
    pol = ShardingPolicy(_fake_mesh(model=1))
    spec = pol.spec_for((8, 64), ("heads", "mlp"))
    assert pol.axes_size(("model",)) == 1
    assert all(e in (None, "model") for e in spec)


@pytest.mark.parametrize("name", CATALOG)
def test_param_specs_total_over_catalog(name):
    """`param_specs` must assign a spec to every leaf of every catalog
    architecture's parameter tree (eval_shape: no arrays materialize),
    with each spec rank-matched to its leaf and every named axis real."""
    from repro.models import Model
    cfg = get_config(name)
    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    mesh = _fake_mesh(data=2, model=4)
    specs = param_specs(shapes, cfg, ShardingPolicy(mesh))
    leaves = jax.tree_util.tree_leaves_with_path(shapes)
    spec_leaves = dict(jax.tree_util.tree_leaves_with_path(
        specs, is_leaf=lambda x: isinstance(x, tuple) and not any(
            isinstance(e, (list, dict)) for e in x)))
    assert len(leaves) > 0
    for path, leaf in leaves:
        spec = spec_leaves[path]
        assert len(spec) == len(leaf.shape), (name, path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, spec):
            for ax in ((entry,) if isinstance(entry, str) else entry or ()):
                assert ax in mesh.axis_names
                assert dim % mesh.shape[ax] == 0, (name, path, spec)


# ----------------------------------------------- big-config dry runs

@pytest.mark.parametrize("name", ["command-r-35b", "mixtral-8x22b"])
def test_big_config_sharded_dryrun(name):
    """command-r-35B / mixtral-8x22B-class configs resolve the sharded
    serving chain and an FSDP placement for every parameter — without
    ever fitting (or allocating) the tree on one device."""
    from repro.models import Model
    cfg = get_config(name)
    assert cfg.fsdp
    plan = resolve_plan(cfg, _serving("data=2,model=4"))
    assert plan.backend("attention_decode") == "raceit_gqa_tp"

    shapes = jax.eval_shape(Model(cfg).init, jax.random.PRNGKey(0))
    mesh = _fake_mesh(data=2, model=4)
    policy = ShardingPolicy(mesh)
    # the engine's FSDP extension: weight axes may also take the data axes
    amap = dict(policy.axis_map)
    for ax in ("heads", "mlp", "vocab"):
        amap[ax] = tuple(amap.get(ax, ())) + ("data",)
    policy.axis_map = amap
    specs = param_specs(shapes, cfg, policy)
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, tuple) and not any(
            isinstance(e, (list, dict)) for e in x))
    used = {ax for spec in flat for entry in spec
            for ax in ((entry,) if isinstance(entry, str) else entry or ())}
    assert "model" in used, f"{name}: no parameter took the model axis"
    assert "data" in used, f"{name}: FSDP never engaged the data axis"
