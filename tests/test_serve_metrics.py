"""Step-clock serving metrics: histograms, TTFT/per-token accounting, Jain.

Everything here is pure host-side Python (`repro.serve.metrics`) — no
model, no jax — so this file is the fail-fast front of the CI service-
layer lane. The recorder's clock is the scheduler step counter, which is
what makes the latency numbers bit-deterministic and CI-gateable; the
tests drive it exactly the way `ContinuousBatcher` does (tick at the top
of each step, then events).
"""
import pytest

from repro.serve.metrics import Histogram, ServeMetrics, jain


# ---------------------------------------------------------------- histogram

def test_histogram_nearest_rank_percentiles():
    h = Histogram()
    for v in [5, 1, 4, 2, 3]:  # order must not matter
        h.add(v)
    assert h.percentile(50) == 3   # rank ceil(5*.5)=3 -> 3rd smallest
    assert h.percentile(99) == 5   # rank ceil(5*.99)=5
    assert h.percentile(100) == 5
    assert h.percentile(1) == 1    # rank max(1, ceil(.05)) = 1
    assert h.summary() == {"n": 5, "p50": 3, "p99": 5, "mean": 3.0,
                           "max": 5}


def test_histogram_single_sample_and_empty():
    h = Histogram()
    assert h.percentile(50) is None
    assert h.summary()["n"] == 0 and h.summary()["p99"] is None
    h.add(7)
    assert h.percentile(50) == 7 and h.percentile(99) == 7
    assert len(h) == 1


def test_histogram_rejects_out_of_range_p():
    h = Histogram()
    h.add(1)
    for p in (0, -1, 101):
        with pytest.raises(ValueError, match="must be in"):
            h.percentile(p)


# --------------------------------------------------------------------- jain

def test_jain_known_values():
    assert jain([1, 1, 1]) == pytest.approx(1.0)
    assert jain([16, 8]) == pytest.approx(0.9)  # (24^2)/(2*320)
    # one tenant got everything out of n: index -> 1/n
    assert jain([10, 0, 0, 0]) == pytest.approx(0.25)
    assert jain([]) == 1.0       # no tenants: vacuously fair
    assert jain([0, 0]) == 1.0   # no service at all: nothing unfair yet


# ------------------------------------------------------------ serve metrics

def test_ttft_counts_queue_wait_and_tpl_counts_gaps():
    """The scenario the recorder exists for: a request that waited in the
    queue pays its wait in TTFT, and a slot that sits out steps pays the
    gap in per-token latency."""
    m = ServeMetrics()
    m.tick()                       # step 1
    m.on_submit(0, "a")
    m.on_submit(1, "a")            # waits behind rid 0
    m.tick()                       # step 2
    m.on_first_token(0, "a")       # TTFT = 2 - 1 = 1
    m.tick()                       # step 3
    m.on_token(0, "a")             # gap 1
    m.tick()                       # step 4
    m.on_first_token(1, "a")       # TTFT = 4 - 1 = 3 (queue wait included)
    m.tick()                       # step 5
    m.tick()                       # step 6 (rid 0 sat steps 4-5 out)
    m.on_token(0, "a")             # gap 6 - 3 = 3: idle steps are paid
    m.on_token(1, "a")             # gap 6 - 4 = 2
    assert sorted(m.ttft.samples) == [1, 3]
    assert sorted(m.tpl.samples) == [1, 2, 3]
    assert m.tenant_tokens == {"a": 5}
    assert m.tenant_requests == {"a": 2}
    s = m.summary()
    assert s["steps"] == 6 and s["ttft_n"] == 2 and s["tpl_n"] == 3
    assert s["ttft_p99"] == 3 and s["tpl_p50"] == 2


def test_reject_and_error_do_not_pollute_latency():
    m = ServeMetrics()
    m.tick()
    m.on_submit(0)
    m.on_reject(0)         # depth-cap rejection: no TTFT sample ever
    m.on_submit(1)
    m.tick()
    m.on_first_token(1)
    m.on_error(1)          # faulted mid-flight: no further tpl samples
    m.tick()
    m.on_token(1)          # stale event after error: gap has no baseline
    assert m.rejected == 1 and m.errored == 1
    assert len(m.ttft) == 1 and len(m.tpl) == 0
    s = m.summary()
    assert s["rejected"] == 1 and s["errored"] == 1


def test_fairness_normalizes_by_weight():
    m = ServeMetrics()
    m.tick()
    for _ in range(30):
        m.on_token(0, "heavy")
    for _ in range(10):
        m.on_token(1, "light")
    # 30 vs 10 tokens at weights 3:1 is exactly proportional service
    assert m.fairness({"heavy": 3.0, "light": 1.0}) == pytest.approx(1.0)
    # unweighted, the same split is lopsided
    assert m.fairness() == pytest.approx(jain([30, 10]))
    assert ServeMetrics().fairness({"a": 2.0}) == 1.0  # nothing served yet
