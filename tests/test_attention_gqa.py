"""GQA parity matrix: the GQA-native decode kernel and every resolved
attention backend across grouping ratios rep = H/KV in {1, 2, 4, 8}.

The tentpole contract: `raceit_attention_decode_gqa` (native (B, KV, Smax,
D) cache layout, no KV repeat anywhere) is *bit-identical* to
`raceit_attention_decode_fused` on the repeated cache — and hence bit-exact
vs the staged `raceit_attention` oracle on the cache slice — for every
softmax mode x fill level x rep, with and without per-row pad masks. The
prefill matrix extends the fused-vs-staged bit-exactness contract (tested
at rep in {1, 2} since PR 1) to rep in {4, 8}.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ExecConfig, ModelConfig
from repro.core.attention import raceit_attention
from repro.core.ops import PROB_FMT
from repro.exec import resolve_plan
from repro.kernels.ops import (raceit_attention_decode_fused,
                               raceit_attention_decode_gqa)
from repro.models import layers

REPS = (1, 2, 4, 8)


def _assert_parity(got, want, v):
    """Bit-exact, with the <=1 PROB ulp acceptance bound as the hard floor."""
    got, want = np.asarray(got), np.asarray(want)
    if np.array_equal(got, want):
        return
    ulp = PROB_FMT.scale * float(jnp.max(jnp.abs(v)))
    np.testing.assert_allclose(got, want, atol=ulp, rtol=0)


def _gqa_cfg(rep, kv=2):
    return ModelConfig(name=f"t{rep}", n_layers=1, d_model=kv * rep * 16,
                       n_heads=kv * rep, n_kv_heads=kv, d_ff=64,
                       vocab_size=64, head_dim=16, param_dtype="float32",
                       compute_dtype="float32")


def _decode_case(rng, rep, Smax=96, D=16, B=2, KV=2, fill=None, std=1.5):
    H = KV * rep
    mk = lambda s: jnp.asarray(rng.normal(0, std, s), jnp.float32)
    q = mk((B, H, 1, D))
    fill = Smax if fill is None else fill
    k = jnp.zeros((B, KV, Smax, D), jnp.float32).at[:, :, :fill].set(
        mk((B, KV, fill, D)))
    v = jnp.zeros((B, KV, Smax, D), jnp.float32).at[:, :, :fill].set(
        mk((B, KV, fill, D)))
    return q, k, v


# ---------------------------------------------------------------------------
# kernel wrappers: gqa == fused == oracle, the full matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", REPS)
@pytest.mark.parametrize("mode", ["pot", "pot_fine", "uniform"])
def test_gqa_decode_matrix_bitexact_vs_fused_and_oracle(rng, mode, rep):
    q, k, v = _decode_case(rng, rep)
    kf, vf = (jnp.repeat(a, rep, axis=1) for a in (k, v))
    for fill in (1, 33, 96):
        L = jnp.int32(fill)
        want = raceit_attention_decode_fused(q, kf, vf, L, softmax_mode=mode,
                                             block_k=32)
        got = raceit_attention_decode_gqa(q, k, v, L, softmax_mode=mode,
                                          block_k=32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        oracle = raceit_attention(q, kf[:, :, :fill], vf[:, :, :fill],
                                  softmax_mode=mode)
        _assert_parity(got, oracle, vf[:, :, :fill])


def test_gqa_decode_ignores_stale_cache_tail(rng):
    """Garbage past kv_len in the *native* buffers must not leak."""
    q, k, v = _decode_case(rng, rep=4, fill=20)
    k = k.at[:, :, 20:].set(99.0)
    v = v.at[:, :, 20:].set(-99.0)
    kf, vf = (jnp.repeat(a, rep := 4, axis=1) for a in (k, v))
    want = raceit_attention(q, kf[:, :, :20], vf[:, :, :20])
    got = raceit_attention_decode_gqa(q, k, v, jnp.int32(20), block_k=32)
    _assert_parity(got, want, vf[:, :, :20])


def test_gqa_decode_kv_len_is_traced_one_compile(rng):
    """One executable serves every fill level (kv_len traced, not static)."""
    q, k, v = _decode_case(rng, rep=4)
    kf, vf = (jnp.repeat(a, 4, axis=1) for a in (k, v))
    fn = lambda L: raceit_attention_decode_gqa(q, k, v, L, block_k=32)
    out0 = fn(jnp.int32(3))
    traces = raceit_attention_decode_gqa._cache_size()
    outs = [out0] + [fn(jnp.int32(L)) for L in (17, 96)]
    # later fill levels must reuse the first call's executable — if kv_len
    # regressed to a static argument this count would grow per fill level
    assert raceit_attention_decode_gqa._cache_size() == traces
    for L, got in zip((3, 17, 96), outs):
        _assert_parity(got, raceit_attention(q, kf[:, :, :L], vf[:, :, :L]),
                       vf[:, :, :L])


def test_gqa_decode_rejects_bad_shapes(rng):
    q, k, v = _decode_case(rng, rep=2)
    with pytest.raises(ValueError):  # Sq != 1
        raceit_attention_decode_gqa(jnp.concatenate([q, q], axis=2), k, v,
                                    jnp.int32(4))
    with pytest.raises(ValueError):  # H not a multiple of KV
        raceit_attention_decode_gqa(q[:, :3], k, v, jnp.int32(4))


# ---------------------------------------------------------------------------
# layer adapters: plan-dispatched decode, pad masks, resolution policy
# ---------------------------------------------------------------------------

def _plan(rep, **kw):
    return resolve_plan(_gqa_cfg(max(rep, 1)),
                        ExecConfig.serving(**kw))


@pytest.mark.parametrize("rep", REPS[1:])  # rep=1 resolves to the flat family
def test_layer_gqa_decode_bitexact_vs_fused_adapter(rng, rep):
    """The plan's default GQA decode == the flat fused adapter, bitwise —
    including per-row pad masks (left-padded buckets)."""
    plan = _plan(rep)
    assert plan.backend("attention_decode") == "raceit_gqa_paged"
    B, Smax, KV, hd = 3, 64, 2, 16
    H = KV * rep
    fill = 40
    scale = 1.0 / np.sqrt(hd)
    mk = lambda s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    q = mk((B, 1, H, hd))
    k = jnp.zeros((B, Smax, KV, hd)).at[:, :fill].set(mk((B, fill, KV, hd)))
    v = jnp.zeros((B, Smax, KV, hd)).at[:, :fill].set(mk((B, fill, KV, hd)))
    pad = jnp.asarray([0, 3, 7], jnp.int32)
    for pad_valid in (None, jnp.arange(Smax)[None, :] >= pad[:, None]):
        want = layers._raceit_fused_decode(q, k, v, jnp.int32(fill), scale,
                                           plan, pad_valid=pad_valid)
        got = layers._raceit_gqa_decode(q, k, v, jnp.int32(fill), scale,
                                        plan, pad_valid=pad_valid)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # and both match the staged quantized pipeline on the masked slice
        mask = (jnp.ones((B, 1, fill), bool) if pad_valid is None
                else jnp.broadcast_to(pad_valid[:, None, :fill], (B, 1, fill)))
        oracle = layers._raceit_staged_attention(q, k[:, :fill], v[:, :fill],
                                                 mask, scale, plan)
        _assert_parity(got, oracle, v[:, :fill])


def test_resolution_gqa_vs_mha():
    """serving() prefers the per-row GQA-native decode exactly when KV
    heads are shared; MHA degrades within the fused family to the per-row
    flat kernel with a recorded reason and *no* warning (same dataflow,
    nothing lost). The scalar-kv_len variants stay registered for pins."""
    import warnings
    gqa = resolve_plan(_gqa_cfg(4), ExecConfig.serving())
    assert gqa.backend("attention_decode") == "raceit_gqa_paged"
    assert gqa.op("attention_decode").reason is None
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # any RuntimeWarning fails the test
        mha = resolve_plan(_gqa_cfg(1), ExecConfig.serving())
    op = mha.op("attention_decode")
    assert op.backend == "raceit_fused_paged"
    assert op.requested == "raceit_gqa_paged"
    assert "KV-head sharing" in op.reason
    assert "raceit_gqa_paged" in mha.explain()
    # the pre-rows backends remain pinnable for A/B
    pinned = resolve_plan(_gqa_cfg(4), ExecConfig.serving().with_ops(
        attention_decode="raceit_gqa_native"))
    assert pinned.backend("attention_decode") == "raceit_gqa_native"


def test_gqa_native_not_used_without_fused_attention():
    plan = resolve_plan(_gqa_cfg(4), ExecConfig(mode="raceit"))
    assert plan.backend("attention_decode") == "raceit_staged"


# ---------------------------------------------------------------------------
# prefill matrix: staged == fused for every rep (extends the rep<=2 tests)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", REPS)
def test_prefill_fused_vs_staged_bitexact_per_rep(rng, rep):
    B, S, KV, hd = 2, 24, 2, 16
    H = KV * rep
    cfg = _gqa_cfg(rep)
    scale = 1.0 / np.sqrt(hd)
    mk = lambda s: jnp.asarray(rng.normal(0, 1.5, s), jnp.float32)
    q, = (mk((B, S, H, hd)),)
    k, v = mk((B, S, KV, hd)), mk((B, S, KV, hd))
    common = dict(scale=scale, q_offset=0, kind="causal", window=cfg.window,
                  chunk=1024, probs_dtype=jnp.float32)
    staged = resolve_plan(cfg, ExecConfig(mode="raceit"))
    fused = resolve_plan(cfg, ExecConfig.serving())
    assert staged.backend("attention_prefill") == "raceit_staged"
    assert fused.backend("attention_prefill") == "raceit_fused"
    want = staged.attention_prefill(q, k, v, **common)
    got = fused.attention_prefill(q, k, v, **common)
    _assert_parity(got, want, v)
    # the digital backend agrees to float-vs-int8 noise on the same shapes
    dig = resolve_plan(cfg, ExecConfig()).attention_prefill(q, k, v, **common)
    scale_ref = max(float(jnp.max(jnp.abs(want))), 1e-6)
    assert float(jnp.max(jnp.abs(dig - want))) / scale_ref < 0.35
