"""Content-addressed prefix cache over the paged pool: parity + lifecycle.

The prefix-cache contract (serve/prefix.py + the allocator transitions in
serve/paged.py):

* **bitwise hit parity** — a request admitted over cached prefix pages
  produces tokens bitwise identical to the cold path (and to solo
  generation) in digital greedy mode, on both fused-decode kernel
  families (gpt2-large tiny = MHA, command-r-35b tiny = RoPE + GQA):
  a shared page holds exactly the KV the request would have computed
  (same tokens, same absolute positions, per-tensor quantizer scales)
  and the paged kernels are page-permutation invariant;
* **refcounted sharing** — promotion moves a slot's first private page
  into the shared set (ref 1, refs-then-owned row order), hits acquire
  (ref += 1), retire/quarantine release, and only ref==0 pages are
  evictable, LRU-first, pinned hits excluded;
* **quarantine** — a faulted slot leaks its *private* pages only; its
  shared references are released and the cached pages stay servable.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.serve import ContinuousBatcher, GenerationEngine, Request
from repro.serve.paged import PageAllocator
from repro.serve.prefix import PrefixCache, _ROOT, page_digest

from conftest import tiny_config
from test_serve_paged import (_check_invariants, _engine, _faulty_engine,
                              _prompt, _solo, MAX_LEN, N_PAGES, N_SLOTS, PS)


# ---------------------------------------------------------------- hashing

def test_page_digest_chains_and_separates():
    d1 = page_digest(b"", list(range(8)))
    d2 = page_digest(b"", list(range(8)))
    assert d1 == d2 and len(d1) == 16
    # content-sensitive ...
    assert d1 != page_digest(b"", list(range(1, 9)))
    # ... and CHAIN-sensitive: the same tokens after a different history
    # must key a different page (same page content at a different
    # absolute position holds different KV)
    assert page_digest(d1, [7]) != page_digest(d2 + b"x", [7])
    # no width ambiguity: [1, 23] vs [12, 3]
    assert page_digest(b"", [1, 23]) != page_digest(b"", [12, 3])


# ------------------------------------------------- cache unit (no model)

def _pool(n_pages=9, ps=4):
    a = PageAllocator(n_pages)
    return a, PrefixCache(a, ps)


def _feed(a, pc, slot, tokens, ps):
    """Stream a prompt's full pages through promote, like the batcher
    (the chain starts at the cache's root, exactly as ``match`` walks it)."""
    n_full = len(tokens) // ps
    pages = a.alloc(slot, n_full)
    digest = _ROOT
    for i, page in enumerate(pages):
        ok, digest = pc.promote(slot, page, digest, tokens[i * ps:(i + 1) * ps])
        assert ok
    return pages, digest


def test_match_walks_chain_and_caps_last_token():
    a, pc = _pool()
    toks = list(range(12))  # 3 full pages at ps=4
    pages, _ = _feed(a, pc, 0, toks, 4)
    a.free_slot(0)
    # full-prefix lookup: the cap keeps the LAST token uncached — its
    # logits seed generation, so at 12 tokens only (12-1)//4 = 2 pages hit
    hits, digest, covered = pc.match(toks)
    assert [p for _, p in hits] == pages[:2] and covered == 8
    # 13+ tokens may hit all 3
    hits13, _, covered13 = pc.match(toks + [99])
    assert [p for _, p in hits13] == pages and covered13 == 12
    # divergence stops the walk at the first mismatched page
    fork = toks[:4] + [77] + toks[5:]
    hits_f, _, covered_f = pc.match(fork)
    assert [p for _, p in hits_f] == pages[:1] and covered_f == 4
    # match is pure: counters and LRU untouched until commit
    assert pc.lookups == 0 and pc.hit_pages == 0
    pc.commit(hits, 3)
    assert pc.lookups == 1 and pc.hit_pages == 2 and pc.miss_pages == 1
    assert pc.hit_requests == 1


def test_promote_enforces_row_order_and_stops_on_duplicate():
    a, pc = _pool()
    toks = list(range(8))
    _feed(a, pc, 0, toks, 4)
    # slot 1 streamed the same prefix concurrently: its first page's
    # digest is already cached -> promote refuses (False) with NO side
    # effects; the caller must stop walking (promo_dead)
    pages1 = a.alloc(1, 2)
    ok, _ = pc.promote(1, pages1[0], _ROOT, toks[:4])
    assert not ok
    assert a.owned(1) == pages1  # still private, row order intact
    # promotion must walk in order: page[1] before page[0] raises
    with pytest.raises(ValueError, match="first private page"):
        pc.promote(1, pages1[1], b"", toks[:4])
    a.assert_invariants()


def test_lru_eviction_is_ref0_only_and_pin_aware():
    a, pc = _pool(n_pages=9, ps=4)
    t1, t2 = list(range(0, 8)), list(range(100, 108))
    p1, _ = _feed(a, pc, 0, t1, 4)   # older entries
    p2, _ = _feed(a, pc, 1, t2, 4)   # newer entries
    # slot 0 retires -> t1's pages at ref 0; slot 1 keeps t2 pinned
    a.free_slot(0)
    assert pc.n_evictable() == 2
    # LRU order: t1's chain evicts before t2's would
    assert pc.evict(1) == 1
    assert a.is_shared(p1[1]) and not a.is_shared(p1[0])  # oldest first
    # pinning excludes a page even at ref 0
    a.free_slot(1)
    assert pc.evict(10, pinned=frozenset([p2[0]])) == 2  # p1[1] + p2[1]
    assert a.is_shared(p2[0]) and pc.evictions == 3
    # referenced pages are never victims: re-acquire and try to evict
    a.acquire(2, p2[0])
    assert pc.evict(10) == 0
    with pytest.raises(ValueError, match="not an evictable"):
        a.evict_shared(p2[0])
    a.assert_invariants()


def test_allocator_shared_transitions():
    a = PageAllocator(6)
    pages = a.alloc(0, 3)
    with pytest.raises(ValueError, match="not shared"):
        a.acquire(1, pages[0])
    a.promote(0, pages[0])
    assert a.shared_ref(pages[0]) == 1 and a.refs(0) == [pages[0]]
    assert a.owned(0) == pages[1:]
    a.acquire(1, pages[0])
    assert a.shared_ref(pages[0]) == 2
    # quarantine: slot 0's PRIVATE pages leak, its shared ref releases
    a.leak_slot(0)
    assert a.n_leaked == 2 and a.shared_ref(pages[0]) == 1
    a.free_slot(1)
    assert a.shared_ref(pages[0]) == 0  # evictable, still cached
    a.assert_invariants()
    # n == 0 is a valid reservation (a would-be full-hit admission)
    assert a.alloc(2, 0) == []
    a.acquire(2, pages[0])
    a.free_slot(2)
    # a slot holding only shared refs still blocks re-admission: alloc
    # refuses until the refs are released
    a.acquire(3, pages[0])
    with pytest.raises(ValueError, match="already holds"):
        a.alloc(3, 1)
    a.release_refs(3)
    assert a.alloc(3, 1) is not None
    a.assert_invariants()


# ------------------------------------------- end-to-end (tiny models)

def _shared_prefix_trace(cb):
    """Submit 4 requests sharing a 2-page prefix with distinct
    page-misaligned tail lengths (truncations of one pool prompt);
    returns [(rid, L, cseed, shared)] for the solo oracle."""
    meta = []
    for rid, L in enumerate((2 * PS + 3, 2 * PS + 1, 3 * PS, 2 * PS + 5)):
        cb.submit(Request(rid, _prompt(L, 0, shared=True), n_new=3))
        meta.append((rid, L, 0, True))
    return meta


@pytest.mark.parametrize("name", ["gpt2-large", "command-r-35b"])
def test_hit_path_bitwise_equals_cold_and_solo(name):
    """The acceptance criterion: prefix-hit requests' tokens are bitwise
    identical to the cold path across MHA and GQA — checked against BOTH
    a prefix-off run of the same trace and the memoized solo oracle."""
    eng = _engine(name)
    runs = {}
    for on in (False, True):
        cb = ContinuousBatcher(eng, n_slots=2, page_size=PS,
                               n_pages=N_PAGES + 4, prefix_cache=on)
        meta = _shared_prefix_trace(cb)
        while cb.queue or any(s is not None for s in cb.slots):
            cb.step()
            _check_invariants(cb)
        for rid, L, cseed, shared in meta:
            assert cb.done[rid].error is None
            got = [int(t) for t in cb.done[rid].result]
            assert got == _solo(name, L, cseed, 3, shared), (name, on, rid)
        runs[on] = cb
    hot = runs[True]
    assert hot.prefix.hit_pages > 0  # the trace really did share pages
    assert runs[False].prefix is None
    # hits skipped chunk work: strictly fewer chunk calls than cold
    assert hot.chunk_calls < runs[False].chunk_calls
    # and the step-clock sees it: later requests' TTFT improves
    assert (hot.metrics.ttft.summary()["mean"]
            < runs[False].metrics.ttft.summary()["mean"])


def test_identical_prompt_readmission_hits_and_matches():
    """Serving the same prompt twice in sequence: the second admission
    maps (P-1)//PS pages from cache, streams only the final partial page,
    and still matches the solo oracle exactly."""
    name = "gpt2-large"
    eng = _engine(name)
    cb = ContinuousBatcher(eng, n_slots=1, page_size=PS, n_pages=N_PAGES)
    L = 2 * PS + 1
    for rid in range(2):
        cb.submit(Request(rid, _prompt(L, 1, shared=True), n_new=2))
    cb.run_all()
    for rid in range(2):
        got = [int(t) for t in cb.done[rid].result]
        assert got == _solo(name, L, 1, 2, True)
    s = cb.prefix.stats()
    assert s["prefix_hit_pages"] == 2   # (17-1)//8 on the second admission
    assert s["prefix_hit_requests"] == 1 and s["prefix_promotions"] == 2


def test_eviction_under_pressure_end_to_end():
    """A pool too small to keep the cache resident: admission evicts
    ref==0 LRU pages to make room, and everything still matches solo."""
    name = "gpt2-large"
    eng = _engine(name)
    # 2 slots x up-to-4-page requests against 6 allocatable pages
    cb = ContinuousBatcher(eng, n_slots=2, page_size=PS, n_pages=7)
    reqs = []
    for rid in range(5):
        cseed, shared = (0, True) if rid % 2 == 0 else (rid, False)
        L = 2 * PS + (1 + rid) % 3
        cb.submit(Request(rid, _prompt(L, cseed, shared), n_new=2))
        reqs.append((rid, L, cseed, shared))
    steps = 0
    while cb.queue or any(s is not None for s in cb.slots):
        cb.step()
        steps += 1
        assert steps < 500
        _check_invariants(cb)
    assert cb.prefix.evictions > 0  # pressure really forced evictions
    for rid, L, cseed, shared in reqs:
        assert cb.done[rid].error is None, cb.done[rid].error
        got = [int(t) for t in cb.done[rid].result]
        assert got == _solo(name, L, cseed, 2, shared)


def test_quarantine_releases_shared_keeps_cache_servable():
    """A decode-faulted slot leaks only its private pages: its shared
    references release (back to ref 0), the cached pages stay resident,
    and a later identical request hits them and completes cleanly on the
    surviving slot."""
    from repro.hw.noise import fault_rows, site_key

    eng = _faulty_engine(0.5)
    cb = ContinuousBatcher(eng, n_slots=2, page_size=PS,
                           n_pages=1 + 2 * (MAX_LEN // PS))
    nz = eng.plan.exec_cfg.noise
    fmap = np.asarray(fault_rows(nz, site_key(nz, "decode_fault", (2,)), 2))
    assert list(fmap) == [False, True]  # slot 1 faults at decode

    L = 2 * PS + 1  # 2 full (promotable) prompt pages + 1 streamed token
    for rid in range(4):
        cb.submit(Request(rid, _prompt(L, 2, shared=True), n_new=3))
    while cb.queue or any(s is not None for s in cb.slots):
        cb.step()
        _check_invariants(cb)
    assert cb.dead_slots == {1}
    # slot 1 lost the promotion race to slot 0 (promo_dead), so ALL 3 of
    # its pages were still private when it faulted — leaked, while the 2
    # shared prefix pages slot 0 promoted survive in the cache at ref 0
    assert cb.allocator.n_leaked == 3
    assert cb.allocator.n_shared == 2
    hits, _, _ = cb.prefix.match(_prompt(L, 2, shared=True))
    assert len(hits) == 2
    assert all(cb.allocator.shared_ref(p) == 0 for _, p in hits)
    failed = [r for r in cb.done.values() if r.error is not None]
    assert len(failed) == 1
    # the post-fault admissions HIT the cache the healthy slot built
    # (2 pages each); on the NOISY engine the clean solo oracle doesn't
    # apply, but the hit path must still be transparent: every healthy
    # request ran the same prompt on the same surviving row, so cold
    # (rid 0) and hit (rids 2, 3) outputs must be identical
    assert cb.prefix.hit_pages == 4
    healthy = [list(map(int, r.result)) for r in cb.done.values()
               if r.error is None]
    assert len(healthy) == 3
    assert all(got == healthy[0] for got in healthy)
