"""Block-paged decode kernels: page-table indirection, bit-exactly.

The paged tentpole contract (`raceit_attention_decode_paged` /
`raceit_attention_decode_gqa_paged` over a ``(n_pages, page_size, KV, D)``
pool + ``(B, max_pages)`` block table): output is **bit-identical** to the
contiguous per-row wrappers (`raceit_attention_decode_fused` /
`raceit_attention_decode_gqa`) evaluated on the gathered layout of the same
table — pages move the DMA source of each key tile, never its logical
coordinates, the block visit order, or the quantizer windows
(`masked_page_quantize` reduces over the same union of live prefixes as
`masked_prefix_quantize`, and f32 max is order-free).

Matrix: softmax_mode x fill (full / partial / single-key / EMPTY row) x
rep x page permutation (shuffled block tables), plus stale-page and
trash-page garbage immunity, the chunked Sq>1 masked call, and the
one-executable-per-run compile contract (block tables are traced).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.ops import (masked_page_quantize, masked_prefix_quantize,
                               page_valid_lengths,
                               raceit_attention_decode_fused,
                               raceit_attention_decode_gqa,
                               raceit_attention_decode_gqa_paged,
                               raceit_attention_decode_paged)
from test_attention_perrow import _assert_parity, _perrow_staged_oracle

LENS = (96, 33, 1, 0)  # one full, one partial, one single-key, one EMPTY row


def _paged_case(rng, rep, lens=LENS, B=4, KV=2, D=16, ps=16, mp=6,
                perm_seed=0, garbage=True):
    """A contiguous native-layout case plus its paged twin.

    Returns (q, k, v, lens, k_pool, v_pool, block_table): k/v are the
    zero-tailed contiguous (B, KV, Smax, D) buffers, the pools scatter the
    same live entries into shuffled physical pages of a shared
    (n_pages, ps, KV, D) pool, and — when ``garbage`` — every pool entry
    NOT holding live cache data (unmapped pages, the trash page, live-page
    rows past the slot's fill) is filled with +-1e4 junk the paged path
    must treat as nonexistent.
    """
    H = KV * rep
    Smax = ps * mp
    assert all(ln <= Smax for ln in lens) and len(lens) == B
    mk = lambda s: jnp.asarray(rng.normal(0, 1.5, s), jnp.float32)
    q = mk((B, H, 1, D))
    k = jnp.zeros((B, KV, Smax, D), jnp.float32)
    v = jnp.zeros((B, KV, Smax, D), jnp.float32)
    for b, ln in enumerate(lens):
        k = k.at[b, :, :ln].set(mk((KV, ln, D)))
        v = v.at[b, :, :ln].set(mk((KV, ln, D)))
    n_pages = 1 + B * mp
    if garbage:
        junk = np.random.default_rng(perm_seed + 7)
        pool_k = np.asarray(junk.choice((-1e4, 1e4), (n_pages, ps, KV, D)),
                            np.float32)
        pool_v = -pool_k
    else:
        pool_k = np.zeros((n_pages, ps, KV, D), np.float32)
        pool_v = np.zeros((n_pages, ps, KV, D), np.float32)
    order = np.random.default_rng(perm_seed).permutation(
        np.arange(1, n_pages))  # physical page 0 stays the trash page
    bt = np.zeros((B, mp), np.int32)
    nxt = 0
    for b, ln in enumerate(lens):
        for j in range(-(-ln // ps)):
            pg = int(order[nxt]); nxt += 1
            bt[b, j] = pg
            lv = min(ps, ln - j * ps)  # only live rows — page tail stays junk
            pool_k[pg, :lv] = np.asarray(
                k[b, :, j * ps:j * ps + lv]).transpose(1, 0, 2)
            pool_v[pg, :lv] = np.asarray(
                v[b, :, j * ps:j * ps + lv]).transpose(1, 0, 2)
    return (q, k, v, jnp.asarray(lens, jnp.int32), jnp.asarray(pool_k),
            jnp.asarray(pool_v), jnp.asarray(bt))


# ---------------------------------------------------------------------------
# the matrix: paged == contiguous rows == per-row staged oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rep", (1, 4))
@pytest.mark.parametrize("mode", ["pot", "pot_fine", "uniform"])
def test_paged_matrix_bitexact_vs_contiguous_and_oracle(rng, mode, rep):
    """Every softmax mode x rep, mixed fills incl. an empty slot: GQA-paged
    == flat-paged == the contiguous rows wrappers (matched block order)
    bitwise, and all of them match the per-row staged oracle."""
    q, k, v, lens, pk, pv, bt = _paged_case(rng, rep)
    ps = pk.shape[1]
    got_gqa = raceit_attention_decode_gqa_paged(q, pk, pv, lens, bt,
                                                softmax_mode=mode, block_k=ps)
    got_flat = raceit_attention_decode_paged(q, pk, pv, lens, bt,
                                             softmax_mode=mode, block_k=ps)
    np.testing.assert_array_equal(np.asarray(got_gqa), np.asarray(got_flat))
    # contiguous per-row wrappers on the gathered layout, same key-block
    # size so the streamed PoT row sums add in the same order
    kf, vf = (jnp.repeat(a, rep, axis=1) for a in (k, v))
    want_rows = raceit_attention_decode_fused(q, kf, vf, lens,
                                              softmax_mode=mode, block_k=ps)
    np.testing.assert_array_equal(np.asarray(got_flat), np.asarray(want_rows))
    oracle = _perrow_staged_oracle(q, kf, vf, lens, mode)
    _assert_parity(got_gqa, oracle, vf)


@pytest.mark.parametrize("perm_seed", (1, 2, 3))
def test_paged_shuffled_tables_bit_identical(rng, perm_seed):
    """The same logical contents under different page permutations are the
    same computation: outputs are bitwise invariant to the physical
    placement the allocator happened to pick."""
    draws = [np.random.default_rng(42) for _ in range(2)]
    a = _paged_case(draws[0], rep=2, perm_seed=0)
    b = _paged_case(draws[1], rep=2, perm_seed=perm_seed)
    out_a = raceit_attention_decode_gqa_paged(a[0], a[4], a[5], a[3], a[6],
                                              block_k=16)
    out_b = raceit_attention_decode_gqa_paged(b[0], b[4], b[5], b[3], b[6],
                                              block_k=16)
    np.testing.assert_array_equal(np.asarray(out_a), np.asarray(out_b))


def test_paged_garbage_everywhere_ignored(rng):
    """Junk in unmapped pages, the trash page, and live-page tails past each
    slot's fill must touch nothing — not the outputs, not the shared
    quantizer scales (`masked_page_quantize` zeroes them, the kernel's
    per-row frontier masks them)."""
    draws = [np.random.default_rng(9) for _ in range(2)]
    clean = _paged_case(draws[0], rep=2, lens=(96, 33, 17, 5), garbage=False)
    dirty = _paged_case(draws[1], rep=2, lens=(96, 33, 17, 5), garbage=True)
    out_clean = raceit_attention_decode_gqa_paged(
        clean[0], clean[4], clean[5], clean[3], clean[6], block_k=16)
    out_dirty = raceit_attention_decode_gqa_paged(
        dirty[0], dirty[4], dirty[5], dirty[3], dirty[6], block_k=16)
    np.testing.assert_array_equal(np.asarray(out_clean), np.asarray(out_dirty))


def test_paged_quantizer_scale_matches_contiguous(rng):
    """`masked_page_quantize` reduces over the union of live page entries —
    the *same set* `masked_prefix_quantize` reduces over on the gathered
    layout — so scales (and hence every downstream code) are bitwise
    equal, junk and shuffling notwithstanding."""
    q, k, v, lens, pk, pv, bt = _paged_case(rng, rep=1, lens=(96, 33, 17, 5))
    n_pages, ps = pk.shape[0], pk.shape[1]
    pvl = page_valid_lengths(bt, lens, n_pages, ps)
    # the trash page is never valid, reserved-but-unfilled entries scatter 0
    assert int(pvl[0]) == 0
    codes_p, scale_p = masked_page_quantize(pk, pvl)
    codes_c, scale_c = masked_prefix_quantize(
        k.transpose(0, 2, 1, 3), lens, axis=1)  # (B, Smax, KV, D) layout
    assert np.float32(scale_p) == np.float32(scale_c)
    # gather the pool back to contiguous: codes agree entry-for-entry
    gathered = np.asarray(codes_p)[np.asarray(bt)].reshape(
        len(lens), -1, *pk.shape[2:])
    np.testing.assert_array_equal(gathered, np.asarray(codes_c))


def test_paged_chunk_call_matches_masked_contiguous(rng):
    """The chunked-prefill call (Sq=C queries + intra-chunk causal mask)
    through the flat paged entry is bit-identical to the contiguous flat
    kernel under the same mask — the shape the batcher's prefill chunks
    compile to."""
    B, KV, rep, D, ps, mp, C = 3, 2, 2, 16, 8, 6, 4
    H = KV * rep
    offs, clens = np.array([9, 0, 3]), np.array([4, 4, 1])
    lens = tuple(int(t) for t in offs + clens)
    draws = np.random.default_rng(11)
    _, k, v, lv, pk, pv, bt = _paged_case(
        draws, rep=rep, lens=lens, B=B, KV=KV, D=D, ps=ps, mp=mp)
    q = jnp.asarray(draws.normal(0, 1.5, (B, H, C, D)), jnp.float32)
    cols = np.arange(ps * mp)[None, None, :]
    mask = jnp.asarray(
        cols < (offs[:, None] + np.arange(C)[None, :] + 1)[..., None])
    got = raceit_attention_decode_paged(q, pk, pv, lv, bt, mask=mask,
                                        block_k=ps)
    # contiguous reference with identical quantization (the decode wrappers'
    # prefix-restricted scales) and the same mask, at code level
    from repro.core.quant import quantize_tensor
    from repro.kernels.acam_attention import acam_attention_codes
    from repro.kernels.ops import expand_row_lens, prob_requant_scale
    kf, vf = (jnp.repeat(a, rep, axis=1) for a in (k, v))
    qq = quantize_tensor(q, bits=8)
    kc, ks = masked_prefix_quantize(kf, lv, axis=2)
    vc, vs = masked_prefix_quantize(vf, lv, axis=2)
    Smax = kf.shape[2]
    out32, cmax = acam_attention_codes(
        qq.codes.reshape(B * H, C, D), kc.reshape(B * H, Smax, D),
        vc.reshape(B * H, Smax, D), qq.scale * ks,
        jnp.broadcast_to(mask[:, None], (B, H, C, Smax)).reshape(
            B * H, C, Smax),
        kv_len=expand_row_lens(lv, H), scale_by_sqrt_d=D, block_k=ps)
    want = (out32.astype(jnp.float32)
            * (prob_requant_scale(cmax) * vs)).reshape(B, H, C, D)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_block_table_is_traced_one_compile(rng):
    """One executable serves every block-table assignment and fill pattern
    — the allocator may shuffle pages freely without re-jitting."""
    q, k, v, lens, pk, pv, bt = _paged_case(rng, rep=2)
    fn = lambda lv, t: raceit_attention_decode_gqa_paged(q, pk, pv, lv, t,
                                                         block_k=16)
    fn(lens, bt)
    traces = raceit_attention_decode_gqa_paged._cache_size()
    rolled = jnp.roll(bt, 1, axis=0)
    fn(jnp.asarray((5, 96, 0, 12), jnp.int32), rolled)
    assert raceit_attention_decode_gqa_paged._cache_size() == traces


def test_paged_page_size_not_multiple_of_block_k(rng):
    """page_size smaller than / coprime-free vs the requested block_k: the
    kernel clamps the key block to gcd(page_size, block_k) so blocks never
    straddle pages — result still bitwise vs contiguous at that block."""
    draws = np.random.default_rng(13)
    q, k, v, lens, pk, pv, bt = _paged_case(
        draws, rep=1, lens=(40, 12, 1, 0), ps=8, mp=6)
    got = raceit_attention_decode_paged(q, pk, pv, lens, bt, block_k=32)
    kf, vf = k, v  # rep=1
    want = raceit_attention_decode_fused(q, kf, vf, lens, block_k=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
