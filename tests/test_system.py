"""End-to-end behaviour: train a tiny LM, verify learning + RACE-IT serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.data import SyntheticLM
from repro.models import Model
from repro.train import optim, trainer

from conftest import tiny_config


def test_end_to_end_learns_and_serves_raceit(key):
    cfg = tiny_config(get_config("gpt2-large")).replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, vocab_size=128)
    data = SyntheticLM(vocab_size=128, seq_len=32, global_batch=8, seed=5)
    model = Model(cfg)
    params = model.init(key)
    step = jax.jit(trainer.make_train_step(
        model, optim.AdamWConfig(lr=1e-3,
                                 schedule=optim.warmup_cosine(10, 120))))
    opt_state = optim.adamw_init(params)
    losses = []
    for _ in range(120):
        b = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])

    # RACE-IT inference agrees with digital on argmax for most positions
    ev = SyntheticLM(vocab_size=128, seq_len=32, global_batch=8, seed=77)
    b = {k: jnp.asarray(v) for k, v in ev.next_batch().items()}
    ld = Model(cfg, ExecConfig()).forward(params, b, use_remat=False)
    lr = Model(cfg, ExecConfig(mode="raceit")).forward(params, b,
                                                       use_remat=False)
    agree = float((jnp.argmax(ld, -1) == jnp.argmax(lr, -1)).mean())
    assert agree > 0.7, agree


def test_microbatched_train_step_matches(key):
    cfg = tiny_config(get_config("olmo-1b"))
    model = Model(cfg)
    params = model.init(key)
    tokens = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    s1 = trainer.make_train_step(model, optim.AdamWConfig(lr=1e-3))
    s2 = trainer.make_train_step(model, optim.AdamWConfig(lr=1e-3),
                                 microbatches=2)
    p1, _, m1 = jax.jit(s1)(params, optim.adamw_init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, optim.adamw_init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-6)
