"""Use real `hypothesis` when installed; otherwise a deterministic fallback.

The container this repo ships in does not always have hypothesis, and the
tier-1 suite must not depend on installing anything. The fallback keeps the
property tests running as fixed-seed sweeps: `given(...)` calls the test with
`max_examples` pseudo-random samples drawn from a per-test deterministic
stream, so failures reproduce exactly. Only the strategy subset used by this
suite is implemented (integers, floats, booleans, sampled_from).
"""
from __future__ import annotations

try:  # pragma: no cover - exercised when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: int(r.integers(min_value,
                                                      max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, **_):
            lo, hi = float(min_value), float(max_value)
            return _Strategy(lambda r: float(lo + (hi - lo) * r.random()))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.integers(0, 2)))

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda r: opts[int(r.integers(0, len(opts)))])

    def given(*strats):
        def deco(fn):
            # no functools.wraps: pytest must see the zero-arg signature,
            # not the wrapped function's parameters (they'd look like fixtures)
            def runner():
                n = getattr(runner, "_max_examples", 10)
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    fn(*(s.draw(rng) for s in strats))
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner._max_examples = 10
            return runner
        return deco

    def settings(max_examples=10, **_):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco
