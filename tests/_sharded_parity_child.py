"""Multi-device child for tests/test_sharded_parity.py (not collected).

Runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` in a
subprocess (the parent pytest process pins JAX to 1 CPU device, and the
flag only takes effect before jax initializes). Two modes:

  python tests/_sharded_parity_child.py ops
      op-level bitwise parity: the raceit_*_tp attention backends vs the
      single-device serving chain, MHA + GQA x mesh model={1,2,4,8}, over
      contiguous decode (per-row kv_len), block-paged decode, causal
      prefill, and padded-bucket prefill. All calls are jitted — the
      single-device paged references are @jax.jit wrappers, and eager
      f32 epilogs round differently by ~1 ulp, so bitwise comparison is
      only meaningful jit-vs-jit (serving always runs jitted anyway).

  python tests/_sharded_parity_child.py soak
      end-to-end: GenerationEngine token parity (mesh model=4 vs no mesh,
      FSDP'd params via device_put) and a paged continuous-batching soak
      on a 4-device mesh — generated mixed-length traces through
      ContinuousBatcher must produce tokens identical to the no-mesh
      batcher, with the pool invariants held after every step.

Prints PARITY_OK / SOAK_OK on success; any assertion kills the process
and the parent test surfaces stderr.
"""
import os
import sys
from pathlib import Path

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.dist import MeshSpec
from repro.exec.plan import resolve_plan

MESH_SIZES = (1, 2, 4, 8)


def _cfg(n_heads, n_kv_heads, d_model):
    return get_config("gpt2-large").replace(
        name=f"tp-parity-h{n_heads}kv{n_kv_heads}", n_layers=2,
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv_heads,
        d_ff=2 * d_model, vocab_size=256, pos_emb="rope", norm="rmsnorm",
        glu=False, qkv_bias=False, param_dtype="float32",
        compute_dtype="float32", remat="none", tie_embeddings=True)


# n_kv_heads=8 in both so every mesh size in {1,2,4,8} divides the KV heads
MHA = _cfg(8, 8, 128)    # hd=16, flat fused decode family
GQA = _cfg(16, 8, 256)   # hd=16, rep=2, gqa-native decode family


def _mesh_exec(ms):
    mesh = None if ms == 0 else MeshSpec.parse(f"model={ms}")
    return ExecConfig.serving(mesh=mesh)


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


def _assert_bitwise(ref, out, what):
    ref, out = np.asarray(ref), np.asarray(out)
    if not np.array_equal(ref, out):
        diff = np.abs(ref - out)
        raise AssertionError(
            f"{what}: sharded output differs from single-device "
            f"(max abs diff {diff.max():.3e} at {diff.argmax()})")


def _assert_ulp(ref, out, what):
    # the prefill epilog is f32 math XLA fuses differently inside
    # shard_map (a*b*c re-association) — identical quantized codes, but
    # the float product can land 1-2 ulp apart. Decode is held bitwise
    # (the serving-parity claim); prefill to <= 4 ulp.
    ref, out = np.asarray(ref), np.asarray(out)
    r, o = ref.view(np.int32), out.view(np.int32)
    ulp = np.abs(r - o).max()
    assert ulp <= 4, (
        f"{what}: sharded prefill drifted past ulp noise "
        f"({ulp} ulp, max abs diff {np.abs(ref - out).max():.3e})")


def _op_parity(cfg):
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / float(np.sqrt(hd))
    key = jax.random.PRNGKey(7)
    kq, kk, kv_, kp, kkp, kvp = jax.random.split(key, 6)

    B, Smax = 2, 24
    q1 = _rand(kq, (B, 1, H, hd))
    k = _rand(kk, (B, Smax, KV, hd))
    v = _rand(kv_, (B, Smax, KV, hd))
    kv_len = jnp.asarray([17, 9], jnp.int32)

    ps, n_pages, blocks = 8, 12, 3
    kpool = _rand(kkp, (n_pages, ps, KV, hd))
    vpool = _rand(kvp, (n_pages, ps, KV, hd))
    bt = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    pkv_len = jnp.asarray([ps * blocks - 3, ps + 1], jnp.int32)

    Sq = 12
    qp = _rand(kp, (B, Sq, H, hd))
    kpre = k[:, :Sq]
    vpre = v[:, :Sq]
    pad_lens = jnp.asarray([0, 3], jnp.int32)

    def run(plan):
        dec = jax.jit(lambda: plan.attention_decode(
            q1, k, v, kv_len=kv_len, scale=scale))
        paged = jax.jit(lambda: plan.attention_decode(
            q1, kpool, vpool, kv_len=pkv_len, scale=scale,
            block_table=bt, page_size=ps))
        causal = jax.jit(lambda: plan.attention_prefill(
            qp, kpre, vpre, scale=scale, q_offset=0, kind="causal",
            window=None, chunk=None))
        padded = jax.jit(lambda: plan.attention_prefill(
            qp, kpre, vpre, scale=scale, q_offset=0, kind="causal",
            window=None, chunk=None, pad_lens=pad_lens))
        return {"decode": dec(), "paged_decode": paged(),
                "prefill_causal": causal(), "prefill_padded": padded()}

    ref_plan = resolve_plan(cfg, _mesh_exec(0))
    assert "tp" not in ref_plan.backend("attention_decode")
    ref = run(ref_plan)

    gqa = KV < H
    for ms in MESH_SIZES:
        plan = resolve_plan(cfg, _mesh_exec(ms))
        dec_backend = plan.backend("attention_decode")
        if ms > 1:
            want = "raceit_gqa_tp" if gqa else "raceit_fused_tp"
            assert dec_backend == want, (ms, dec_backend)
            assert plan.backend("attention_prefill") == "raceit_fused_tp"
        else:
            assert dec_backend == ref_plan.backend("attention_decode")
        out = run(plan)
        for name in ref:
            check = (_assert_bitwise if name.endswith("decode")
                     else _assert_ulp)
            check(ref[name], out[name], f"{cfg.name} model={ms} {name}")
        print(f"  {cfg.name}: model={ms} bitwise ok "
              f"({dec_backend})", flush=True)


def _token_parity(cfg):
    from repro.models import Model
    from repro.serve import GenerationEngine

    params = Model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(11)
    prompts = jnp.asarray(rng.integers(0, 256, (2, 12)), jnp.int32)

    ref_eng = GenerationEngine(cfg, params, exec_cfg=_mesh_exec(0),
                               max_len=32)
    ref = ref_eng.generate(prompts, n_new=6)
    eng = GenerationEngine(cfg, params, exec_cfg=_mesh_exec(4), max_len=32)
    assert eng.plan.backend("attention_decode").endswith("_tp")
    out = eng.generate(prompts, n_new=6)
    assert np.array_equal(ref, out), (
        f"{cfg.name}: greedy tokens diverged on model=4\n{ref}\n{out}")
    print(f"  {cfg.name}: engine tokens identical on model=4", flush=True)


def _paged_soak(cfg, n_traces=3):
    from repro.models import Model
    from repro.serve import ContinuousBatcher, GenerationEngine, Request

    PS, N_SLOTS, N_PAGES = 8, 3, 13
    params = Model(cfg).init(jax.random.PRNGKey(0))
    engines = {ms: GenerationEngine(cfg, params, exec_cfg=_mesh_exec(ms),
                                    max_len=64) for ms in (0, 4)}

    def trace(eng, seed):
        cb = ContinuousBatcher(eng, n_slots=N_SLOTS, page_size=PS,
                               n_pages=N_PAGES)
        assert cb.paged
        rng = np.random.default_rng(seed)
        for rid in range(int(rng.integers(3, 6))):
            L = int(rng.integers(1, 3 * PS))
            prompt = np.random.default_rng(1000 + L).integers(
                0, 256, size=L, dtype=np.int64).tolist()
            cb.submit(Request(rid, prompt, n_new=int(rng.integers(1, 5))))
        steps = 0
        while cb.queue or any(s is not None for s in cb.slots):
            cb.step()
            steps += 1
            assert steps < 500, "soak trace failed to drain"
            cb.allocator.assert_invariants()
        return cb, {rid: [int(t) for t in r.result]
                    for rid, r in cb.done.items()}

    for seed in range(n_traces):
        cb_ref, ref = trace(engines[0], seed)
        cb_tp, out = trace(engines[4], seed)
        assert ref == out, (
            f"soak trace {seed}: paged tokens diverged on model=4 mesh\n"
            f"ref={ref}\ntp={out}")
        s = cb_tp.summary()
        assert s["mesh"] == "model=4" and s["decode_backend"].endswith("_tp")
        assert "mesh" not in cb_ref.summary()
        print(f"  soak trace {seed}: {len(ref)} requests identical "
              f"(backend {s['decode_backend']})", flush=True)


def _bench(reps=6):
    """benchmarks/kernels_bench.py `kernel/attention_decode_tp` row: time
    the jitted raceit_gqa_tp paged decode on a 4-way model mesh, after
    re-asserting bitwise parity with the single-device raceit_gqa_paged
    partner on the same operands. Interleaved min-of-N, us/call on
    stdout (``TP_DECODE_US``) for the parent bench to collect."""
    import time

    cfg = GQA
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / float(np.sqrt(hd))
    key = jax.random.PRNGKey(3)
    kq, kk, kv_ = jax.random.split(key, 3)
    B, ps, blocks = 4, 64, 4
    n_pages = 1 + B * blocks
    q = _rand(kq, (B, 1, H, hd))
    kpool = _rand(kk, (n_pages, ps, KV, hd))
    vpool = _rand(kv_, (n_pages, ps, KV, hd))
    bt = jnp.arange(1, n_pages, dtype=jnp.int32).reshape(B, blocks)
    kv_len = jnp.asarray([ps * blocks, ps * 2 + 5, ps - 1, 1], jnp.int32)

    def call(plan):
        return jax.jit(lambda: plan.attention_decode(
            q, kpool, vpool, kv_len=kv_len, scale=scale,
            block_table=bt, page_size=ps))

    ref_fn = call(resolve_plan(cfg, _mesh_exec(0)))
    tp_plan = resolve_plan(cfg, _mesh_exec(4))
    assert tp_plan.backend("attention_decode") == "raceit_gqa_tp"
    tp_fn = call(tp_plan)
    _assert_bitwise(ref_fn(), tp_fn(), "bench paged decode model=4")
    best = {"ref": float("inf"), "tp": float("inf")}
    for _ in range(reps):
        for name, fn in (("ref", ref_fn), ("tp", tp_fn)):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[name] = min(best[name], time.perf_counter() - t0)
    print(f"TP_DECODE_US {best['tp'] * 1e6:.1f}")
    print(f"REF_DECODE_US {best['ref'] * 1e6:.1f}")
    print("BENCH_OK")


def main():
    mode = sys.argv[1] if len(sys.argv) > 1 else "ops"
    assert len(jax.devices()) == 8, jax.devices()
    if mode == "ops":
        for cfg in (MHA, GQA):
            _op_parity(cfg)
        print("PARITY_OK")
    elif mode == "soak":
        for cfg in (MHA, GQA):
            _token_parity(cfg)
        _paged_soak(GQA)
        print("SOAK_OK")
    elif mode == "bench":
        _bench()
    else:
        raise SystemExit(f"unknown mode {mode!r}")


if __name__ == "__main__":
    main()
