"""Fused streaming attention kernel vs the staged Fig.-12 oracle.

Parity is asserted *bit-exact* (stronger than the <=1 PROB_FMT ulp
acceptance bound): every float op in the kernel replicates the oracle's op
sequence, including the tensor-wide PROB re-quantization via the global
cmax reduction. A jaxpr scan proves the fused path never allocates an
(Sq, Sk)-sized intermediate.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ExecConfig, ModelConfig
from repro.core.attention import raceit_attention
from repro.core.ops import PROB_FMT
from repro.kernels.ops import raceit_attention_fused
from repro.models import layers


def _qkv(rng, B, H, Sq, Sk, D, std=1.5):
    mk = lambda s: jnp.asarray(rng.normal(0, std, s), jnp.float32)
    return mk((B, H, Sq, D)), mk((B, H, Sk, D)), mk((B, H, Sk, D))


def _assert_parity(got, want, v):
    """Bit-exact, with the <=1 PROB ulp acceptance bound as the hard floor."""
    got, want = np.asarray(got), np.asarray(want)
    if np.array_equal(got, want):
        return
    ulp = PROB_FMT.scale * float(jnp.max(jnp.abs(v)))  # 1 prob step x |v|max
    np.testing.assert_allclose(got, want, atol=ulp, rtol=0)


@pytest.mark.parametrize("shape", [(1, 1, 16, 16, 8), (1, 2, 64, 64, 16),
                                   (2, 4, 128, 128, 64)])
@pytest.mark.parametrize("mode", ["pot", "pot_fine", "uniform"])
def test_fused_matches_oracle_unmasked(rng, shape, mode):
    q, k, v = _qkv(rng, *shape)
    want = raceit_attention(q, k, v, softmax_mode=mode)
    got = raceit_attention_fused(q, k, v, softmax_mode=mode,
                                 block_q=32, block_k=64)
    _assert_parity(got, want, v)


@pytest.mark.parametrize("shape", [(1, 2, 33, 57, 8), (2, 1, 100, 130, 24),
                                   (1, 1, 1, 300, 16), (1, 3, 65, 1, 8)])
def test_fused_non_multiple_of_block_shapes(rng, shape):
    """Sq/Sk that don't divide the block sizes exercise the padding paths."""
    q, k, v = _qkv(rng, *shape)
    want = raceit_attention(q, k, v)
    got = raceit_attention_fused(q, k, v, block_q=32, block_k=32)
    _assert_parity(got, want, v)


@pytest.mark.parametrize("mode", ["pot", "pot_fine", "uniform"])
def test_fused_masked_parity(rng, mode):
    B, H, Sq, Sk, D = 2, 2, 48, 72, 16
    q, k, v = _qkv(rng, B, H, Sq, Sk, D)
    mask = jnp.asarray(rng.random((B, H, Sq, Sk)) > 0.3)
    mask = mask.at[:, :, 0, :].set(False)  # fully-masked rows too
    want = raceit_attention(q, k, v, mask=mask, softmax_mode=mode)
    got = raceit_attention_fused(q, k, v, mask=mask, softmax_mode=mode,
                                 block_q=16, block_k=32)
    _assert_parity(got, want, v)


def test_fused_causal_in_kernel_mask(rng):
    """The in-kernel causal mask (no mask array at all) == explicit mask."""
    B, H, S, D = 1, 2, 80, 16
    q, k, v = _qkv(rng, B, H, S, S, D)
    mask = jnp.arange(S)[None, :] <= jnp.arange(S)[:, None]
    want = raceit_attention(q, k, v, mask=mask)
    got = raceit_attention_fused(q, k, v, causal=True, block_q=16, block_k=32)
    _assert_parity(got, want, v)
    # decode-style offset: queries continue a longer key stream
    off = 16
    mask2 = jnp.arange(S)[None, :] <= (jnp.arange(S)[:, None] + off)
    want2 = raceit_attention(q, k, v, mask=mask2)
    got2 = raceit_attention_fused(q, k, v, causal=True, q_offset=off,
                                  block_q=16, block_k=32)
    _assert_parity(got2, want2, v)


def test_fused_batch_head_folding(rng):
    """B x H folding must reduce the PROB quantizer max over the whole tensor."""
    q, k, v = _qkv(rng, 4, 2, 40, 40, 8)
    want = raceit_attention(q, k, v)
    got = raceit_attention_fused(q, k, v, block_q=32, block_k=32)
    _assert_parity(got, want, v)
    # per-(B,H) slices disagree with per-slice oracles unless cmax is global:
    # check one slice explicitly against the global-tensor oracle
    _assert_parity(got[2, 1], want[2, 1], v)


def test_core_dispatch_flag(rng):
    q, k, v = _qkv(rng, 1, 2, 40, 40, 16)
    want = raceit_attention(q, k, v)
    got = raceit_attention(q, k, v, fused=True)
    _assert_parity(got, want, v)
    with pytest.raises(ValueError):
        raceit_attention(q, k, v, fused=True, fidelity="acam")


def test_layers_fused_exec_config(rng):
    """Model-layer attention: ExecConfig(fused_attention=True) == staged
    (the plan resolves the attention slots to raceit_fused vs raceit_staged;
    outputs must be bit-identical)."""
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=64,
                      param_dtype="float32", compute_dtype="float32")
    p = layers.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 24, 32)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(24), (2, 24))
    staged, _ = layers.attention(p, x, cfg=cfg, positions=pos,
                                 plan=ExecConfig(mode="raceit"))
    fused, _ = layers.attention(
        p, x, cfg=cfg, positions=pos,
        plan=ExecConfig(mode="raceit", fused_attention=True))
    np.testing.assert_array_equal(np.asarray(staged), np.asarray(fused))


# ---------------------------------------------------------------------------
# regression: the fused path must never allocate an (Sq, Sk) intermediate
# ---------------------------------------------------------------------------

def _all_avals(jaxpr):
    for eqn in jaxpr.eqns:
        for var in eqn.outvars:
            yield var.aval
        for param in eqn.params.values():
            inner = getattr(param, "jaxpr", param)
            if hasattr(inner, "eqns"):
                yield from _all_avals(inner)


def test_fused_never_materializes_scores():
    Sq = Sk = 256
    bq = bk = 64
    q = jnp.zeros((2, Sq, 64), jnp.float32)[:, None]  # (2, 1, Sq, 64)

    def fused(q, k, v):
        return raceit_attention_fused(q, k, v, causal=True,
                                      block_q=bq, block_k=bk, interpret=True)

    jaxpr = jax.make_jaxpr(fused)(q, q, q)
    big = [a for a in _all_avals(jaxpr.jaxpr)
           if hasattr(a, "shape")
           and sum(1 for dim in a.shape if dim >= min(Sq, Sk)) >= 2]
    assert not big, f"fused path materialized score-shaped arrays: {big}"

    # sanity of the scanner: the staged oracle *does* materialize (Sq, Sk)
    jaxpr_staged = jax.make_jaxpr(
        lambda q, k, v: raceit_attention(q, k, v))(q, q, q)
    big_staged = [a for a in _all_avals(jaxpr_staged.jaxpr)
                  if hasattr(a, "shape")
                  and sum(1 for dim in a.shape if dim >= min(Sq, Sk)) >= 2]
    assert big_staged, "scanner failed to flag the staged (Sq, Sk) tensors"
