"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.configs.catalog import ASSIGNED, PAPER_OWN
from repro.models import Model
from repro.train import optim, trainer

from conftest import tiny_config

ALL_ARCHS = ASSIGNED + PAPER_OWN


def _batch(cfg, key, B=2, S=16):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.is_encoder_decoder:
        batch["enc_feats"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nans(arch, key):
    cfg = tiny_config(get_config(arch))
    model = Model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits = model.forward(params, batch, use_remat=False)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_updates_and_finite(arch, key):
    cfg = tiny_config(get_config(arch))
    model = Model(cfg)
    params = model.init(key)
    step = jax.jit(trainer.make_train_step(model, optim.AdamWConfig(lr=1e-3)))
    opt_state = optim.adamw_init(params)
    batch = _batch(cfg, key)
    new_params, opt_state, m = step(params, opt_state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    # at least one parameter actually moved
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["gpt2-large", "gemma3-4b", "mamba2-130m",
                                  "jamba-v0.1-52b", "mixtral-8x22b",
                                  "whisper-tiny", "qwen2-vl-2b", "olmo-1b",
                                  "llama4-scout-17b-a16e", "command-r-35b",
                                  "starcoder2-15b"])
def test_prefill_decode_matches_forward(arch, key):
    cfg = tiny_config(get_config(arch))
    model = Model(cfg)
    params = model.init(key)
    B, S, T0 = 2, 12, 6
    batch = _batch(cfg, key, B, S)
    full = model.forward(params, batch, use_remat=False)
    cache = model.init_cache(B, max_len=32)
    lg, cache = model.prefill(params, batch["tokens"][:, :T0], cache,
                              enc_feats=batch.get("enc_feats"))
    errs = [float(jnp.abs(lg[:, 0] - full[:, T0 - 1]).max())]
    for t in range(T0, S):
        lg, cache = model.decode_step(params, batch["tokens"][:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-3, errs


@pytest.mark.parametrize("arch", ["gpt2-large", "olmo-1b"])
def test_raceit_mode_runs_and_correlates(arch, key):
    """RACE-IT inference path produces logits correlated with digital."""
    import numpy as np
    cfg = tiny_config(get_config(arch))
    model_d = Model(cfg, ExecConfig(mode="digital"))
    model_r = Model(cfg, ExecConfig(mode="raceit", softmax_mode="pot"))
    params = model_d.init(key)
    batch = _batch(cfg, key)
    ld = np.asarray(model_d.forward(params, batch, use_remat=False))
    lr = np.asarray(model_r.forward(params, batch, use_remat=False))
    assert np.isfinite(lr).all()
    corr = np.corrcoef(ld.ravel(), lr.ravel())[0, 1]
    assert corr > 0.8, corr


def test_local_ring_cache_equals_full_decode(key):
    """Sliding-window ring cache decode == full-cache windowed attention."""
    cfg = tiny_config(get_config("gemma3-4b"))
    model = Model(cfg)
    params = model.init(key)
    B, S = 1, 24  # S > window=8: ring wraps
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full = model.forward(params, {"tokens": tokens}, use_remat=False)
    cache = model.init_cache(B, max_len=32)
    lg, cache = model.prefill(params, tokens[:, :4], cache)
    errs = []
    for t in range(4, S):
        lg, cache = model.decode_step(params, tokens[:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-3, errs
