"""Slot-level continuous batching: solo parity, single-compile, occupancy.

The `ContinuousBatcher` contract (serve/continuous.py): requests retire
and admit mid-stream over a fixed slot pool, every slot decodes at its own
cache fill level (per-row ``kv_len`` down to the kernels), and in digital
greedy mode a request's tokens are **bitwise identical** to serving it
alone — however its neighbours churn. The pool's shapes are pinned, so the
whole run compiles exactly one decode executable and one admission-prefill
executable (the bucketed scheduler's per-shape re-jit, satellite 6).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.models import Model
from repro.serve import (BatchScheduler, ContinuousBatcher, GenerationEngine,
                         Request)

from conftest import tiny_config


def _engine(key, name="gpt2-large", exec_cfg=ExecConfig(), **kw):
    cfg = tiny_config(get_config(name))
    model = Model(cfg, exec_cfg)
    params = model.init(key)
    return GenerationEngine(cfg, params, exec_cfg=exec_cfg, max_len=64, **kw)


def _mixed_trace(rng, n=5):
    lens = (7, 3, 5, 2, 6, 4, 8)[:n]
    nnew = (4, 2, 6, 1, 3, 5, 2)[:n]
    return [Request(i, rng.integers(0, 255, ln).astype(np.int32), n_new=nn)
            for i, (ln, nn) in enumerate(zip(lens, nnew))]


# ---------------------------------------------------------------------------
# bitwise solo parity under churn (the CI continuous-batching smoke)
# ---------------------------------------------------------------------------

def test_continuous_matches_solo_digital(key):
    """More requests than slots, mixed lengths AND mixed n_new: every
    request's tokens are bitwise-identical to its solo run — retirement
    and admission mid-stream change nothing (digital greedy)."""
    eng = _engine(key)
    rng = np.random.default_rng(0)
    reqs = _mixed_trace(rng)
    solo = [eng.generate(r.prompt[None, :], r.n_new)[0] for r in reqs]
    cb = ContinuousBatcher(eng, n_slots=2)
    for r in reqs:
        cb.submit(Request(r.rid, r.prompt, n_new=r.n_new))
    done = cb.run_all()
    assert sorted(done) == [r.rid for r in reqs]
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(done[r.rid].result, want,
                                      err_msg=f"request {r.rid} diverged")


def test_continuous_parity_rope_gqa_digital(key):
    """Same contract on a RoPE + grouped-query config (per-slot positions
    must reach RoPE, not just the masks)."""
    eng = _engine(key, name="command-r-35b")
    assert eng.cfg.n_kv_heads < eng.cfg.n_heads
    rng = np.random.default_rng(1)
    reqs = _mixed_trace(rng, n=4)
    solo = [eng.generate(r.prompt[None, :], r.n_new)[0] for r in reqs]
    cb = ContinuousBatcher(eng, n_slots=2)
    for r in reqs:
        cb.submit(Request(r.rid, r.prompt, n_new=r.n_new))
    done = cb.run_all()
    for r, want in zip(reqs, solo):
        np.testing.assert_array_equal(done[r.rid].result, want)


def test_continuous_single_compiled_step(key):
    """The whole mixed-length run reuses ONE decode executable and ONE
    prefill executable — the slot pool pins both shapes (satellite 6: the
    bucketed path re-jits per bucket shape). Paged mode (the default)
    streams admissions through the pinned (n_slots, prefill_chunk) chunk
    executable; the contiguous pin is the (1, prefill_len) solo prefill."""
    eng = _engine(key)
    rng = np.random.default_rng(2)
    cb = ContinuousBatcher(eng, n_slots=2)
    assert cb.paged  # decoder-only all-attn model: paged by default
    for r in _mixed_trace(rng):
        cb.submit(r)
    cb.run_all()
    assert eng._decode._cache_size() == 1
    assert eng._prefill_chunk._cache_size() == 1

    eng2 = _engine(key)
    cb2 = ContinuousBatcher(eng2, n_slots=2, paged=False)
    for r in _mixed_trace(rng):
        cb2.submit(r)
    cb2.run_all()
    assert eng2._decode._cache_size() == 1
    assert eng2._prefill._cache_size() == 1


def test_continuous_raceit_serving_smoke(key):
    """End-to-end on the raceit serving default: the plan resolves the
    per-row GQA decode backend and mixed traffic produces well-formed
    tokens (bitwise solo parity is the digital-mode guarantee; raceit
    couples slots only through whole-tensor activation scales)."""
    eng = _engine(key, name="command-r-35b", exec_cfg=ExecConfig.serving())
    assert eng.plan.backend("attention_decode") == "raceit_gqa_paged"
    rng = np.random.default_rng(3)
    cb = ContinuousBatcher(eng, n_slots=2)
    for r in _mixed_trace(rng, n=3):
        cb.submit(r)
    done = cb.run_all()
    for r in done.values():
        assert r.result.shape == (r.n_new,)
        assert (r.result >= 0).all() and (r.result < eng.cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# slot lifecycle mechanics
# ---------------------------------------------------------------------------

def test_empty_slots_are_harmless(key):
    """More slots than requests: dead rows (kv_len 0) ride every decode
    step without perturbing the live request."""
    eng = _engine(key)
    rng = np.random.default_rng(4)
    p = rng.integers(0, 255, 5).astype(np.int32)
    solo = eng.generate(p[None, :], 4)[0]
    cb = ContinuousBatcher(eng, n_slots=4)
    cb.submit(Request(0, p, n_new=4))
    done = cb.run_all()
    np.testing.assert_array_equal(done[0].result, solo)


def test_n_new_one_retires_at_admission(key):
    """A 1-token request is satisfied by its prefill logits alone and must
    free its slot without consuming a decode step."""
    eng = _engine(key)
    rng = np.random.default_rng(5)
    cb = ContinuousBatcher(eng, n_slots=2)
    for i in range(3):
        cb.submit(Request(i, rng.integers(0, 255, 4).astype(np.int32),
                          n_new=1))
    done = cb.run_all()
    assert sorted(done) == [0, 1, 2]
    assert cb.decode_steps == 0 and cb.prefills == 3


def test_prompt_longer_than_pinned_width_rejected(key):
    eng = _engine(key)
    cb = ContinuousBatcher(eng, n_slots=2, prefill_len=4)
    with pytest.raises(ValueError):
        cb.submit(Request(0, np.arange(9, dtype=np.int32), n_new=2))
    with pytest.raises(ValueError):  # pinned width + n_new must fit max_len
        cb.submit(Request(1, np.arange(3, dtype=np.int32), n_new=61))


def test_jointly_infeasible_queue_fails_fast_with_state_intact(key):
    """Individually-acceptable requests can be jointly infeasible once the
    pool width locks to the longest queued prompt; that must surface at
    lock time (nothing admitted, queue intact) — not as a crash after
    other requests are already in flight."""
    eng = _engine(key)  # max_len = 64; paged=False: the shared-width lock
    cb = ContinuousBatcher(eng, n_slots=2, paged=False)  # is contiguous-only
    cb.submit(Request(0, np.arange(4, dtype=np.int32), n_new=60))  # 4+60 ok
    cb.submit(Request(1, np.arange(8, dtype=np.int32), n_new=1))   # width 8
    with pytest.raises(ValueError, match="jointly infeasible"):
        cb.run_all()
    assert len(cb.queue) == 2 and all(s is None for s in cb.slots)


# ---------------------------------------------------------------------------
# occupancy: the tokens-per-model-call win the bench row gates
# ---------------------------------------------------------------------------

def test_continuous_beats_bucketed_occupancy(key):
    """On a mixed-n_new trace the bucketed scheduler idles early-finished
    slots until the bucket drains; the slot pool retires/admits
    mid-stream. Deterministic counter contract: >= 1.3x decode tokens per
    decode step — the same metric the serve/continuous_occupancy bench
    row pins in CI (prefill is accounted separately: admission prefills
    are per-request, bucket prefills bucket-wide)."""
    eng = _engine(key)
    rng = np.random.default_rng(6)
    mk = lambda: [Request(i, rng.integers(0, 255, ln).astype(np.int32),
                          n_new=nn)
                  for i, (ln, nn) in enumerate(
                      zip((7, 3, 5, 2, 6, 4, 5, 3), (8, 1, 2, 6, 1, 2, 8, 1)))]
    sched = BatchScheduler(eng, bucket_size=4)
    for r in mk():
        sched.submit(r)
    sched.run_all()
    cb = ContinuousBatcher(eng, n_slots=4)
    for r in mk():
        cb.submit(r)
    cb.run_all()
    assert sched.tokens_out == cb.tokens_out
    bucketed = sched.decode_tokens / sched.decode_steps
    continuous = cb.decode_tokens / cb.decode_steps
    assert continuous >= 1.3 * bucketed, (continuous, bucketed)


# ---------------------------------------------------------------------------
# fail-safe serving: structured errors + slot quarantine under device faults
# ---------------------------------------------------------------------------

def test_empty_prompt_rejected(key):
    """The first token is sampled from the prompt's last position, so an
    empty prompt has nothing to prefill — reject at submit, like the
    too-long case, instead of crashing inside the admission gather."""
    eng = _engine(key)
    cb = ContinuousBatcher(eng, n_slots=2)
    with pytest.raises(ValueError, match="empty prompt"):
        cb.submit(Request(0, np.zeros(0, dtype=np.int32), n_new=2))
    assert not cb.queue


def test_duplicate_rid_rejected_at_submit(key):
    """rids key the result map and (paged) page ownership: a silent
    re-submit would overwrite the first request's ``done`` entry and
    cross-wire allocator slots, so the batcher raises at submit —
    malformed traffic, not operational backpressure. The collision is
    caught whether the first holder is queued, running, or already done."""
    eng = _engine(key)
    cb = ContinuousBatcher(eng, n_slots=2)
    rng = np.random.default_rng(0)
    cb.submit(Request(3, rng.integers(0, 255, 4).astype(np.int32), n_new=2))
    with pytest.raises(ValueError, match="duplicate rid 3"):
        cb.submit(Request(3, rng.integers(0, 255, 5).astype(np.int32),
                          n_new=1))  # collides while QUEUED
    cb.run_all()
    assert cb.done[3].error is None
    with pytest.raises(ValueError, match="duplicate rid 3"):
        cb.submit(Request(3, rng.integers(0, 255, 4).astype(np.int32),
                          n_new=2))  # collides while DONE
    assert cb.done[3].result is not None  # the original survived intact
    # distinct rids keep flowing
    cb.submit(Request(4, rng.integers(0, 255, 4).astype(np.int32), n_new=1))
    cb.run_all()
    assert cb.done[4].error is None


def _faulty_engine(key, fault_rate, seed=0):
    """Digital engine with ONLY the decode attention routed through the
    noisy staged backend, all sigmas at worst_case but fault_rate as
    given: the no-fault run stays deterministic noisy, and the fault map
    is the sole difference between reference and fault runs."""
    import dataclasses

    from repro.hw.noise import NoiseConfig
    nz = dataclasses.replace(NoiseConfig.preset("worst_case", seed=seed),
                             fault_rate=fault_rate)
    ec = ExecConfig(mode="digital", noise=nz).with_ops(
        attention_decode="raceit_noisy_staged")
    return _engine(key, exec_cfg=ec)


def test_decode_fault_retires_only_affected_slot(key):
    """A stuck-row fault mid-decode must (a) end the affected request with
    a structured RequestError instead of emitting NaN-driven garbage
    tokens, (b) quarantine that slot (the fault map is static per
    executable — re-admitting would re-fault), and (c) leave the
    surviving slot's tokens BITWISE identical to a no-fault run of the
    same noisy config (the staged decode path is row-independent)."""
    from repro.hw.noise import fault_rows, site_key

    def run(fault_rate):
        eng = _faulty_engine(key, fault_rate)
        cb = ContinuousBatcher(eng, n_slots=2, prefill_len=6)
        rng = np.random.default_rng(7)
        for rid in range(2):
            cb.submit(Request(rid, rng.integers(0, 255, 6).astype(np.int32),
                              n_new=5))
        cb.run_all()
        return cb

    flt = run(0.5)
    # pin the scenario: at seed 0 the (seed, "decode_fault", n_slots=2)
    # map faults exactly slot 1 — recomputed here from first principles so
    # the test documents, not just assumes, which row dies
    nz = flt.engine.plan.exec_cfg.noise
    fmap = np.asarray(fault_rows(nz, site_key(nz, "decode_fault", (2,)), 2))
    assert list(fmap) == [False, True]

    err = flt.done[1].error
    assert flt.done[1].result is None
    assert err is not None and err.rid == 1
    assert err.stage == "decode" and err.step >= 1
    assert flt.dead_slots == {1}

    ref = run(0.0)
    assert flt.done[0].error is None and ref.done[0].error is None
    np.testing.assert_array_equal(flt.done[0].result, ref.done[0].result)


def test_all_slots_quarantined_drains_queue(key):
    """Every slot faulting must not hang run_all: once the pool is fully
    quarantined the queue drains with stage='admit' errors."""
    eng = _faulty_engine(key, fault_rate=1.0, seed=1)
    cb = ContinuousBatcher(eng, n_slots=1, prefill_len=5)
    rng = np.random.default_rng(8)
    for rid in range(3):
        cb.submit(Request(rid, rng.integers(0, 255, 5).astype(np.int32),
                          n_new=4))
    done = cb.run_all()  # must terminate
    assert sorted(done) == [0, 1, 2]
    assert all(done[r].error is not None and done[r].result is None
               for r in done)
    assert done[0].error.stage == "decode"
    assert {done[1].error.stage, done[2].error.stage} == {"admit"}
    assert cb.dead_slots == {0}
