"""Crossbar bit-slicing and the ACAM softmax dataflow."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CrossbarConfig, acam_softmax, bit_sliced_matmul,
                        crossbar_linear, quantize_tensor, softmax_reference)
from repro.core.attention import raceit_attention


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 12), st.integers(1, 300),
       st.integers(1, 12))
def test_bit_sliced_matmul_exact(seed, m, k, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int32)
    got = bit_sliced_matmul(x, w)
    assert (np.asarray(got) == np.asarray(x) @ np.asarray(w)).all()


def test_adc_resolution_error_curve(rng):
    """More ADC bits -> less error; sufficient bits (385 levels) -> exact."""
    x = jnp.asarray(rng.integers(-128, 128, (8, 256)), jnp.int32)
    w = jnp.asarray(rng.integers(-128, 128, (256, 16)), jnp.int32)
    want = (np.asarray(x) @ np.asarray(w)).astype(np.float64)

    def rel(bits):
        cfg = CrossbarConfig(adc_mode="quantize", adc_bits=bits)
        got = np.asarray(bit_sliced_matmul(x, w, cfg)).astype(np.float64)
        return np.abs(got - want).max() / max(np.abs(want).max(), 1)

    r5, r7, r9 = rel(5), rel(7), rel(9)
    assert r5 > r7 > r9
    assert r7 < 0.15
    assert r9 == 0.0  # 2^9-1 = 511 >= 385 partial-sum levels


def test_crossbar_linear_close_to_float(rng):
    x = jnp.asarray(rng.normal(0, 1, (4, 64)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.1, (64, 32)), jnp.float32)
    wq = quantize_tensor(w, bits=8, axis=1)
    y = crossbar_linear(x, wq)
    ref = x @ w
    rel = float(jnp.abs(y - ref).max() / jnp.abs(ref).max())
    assert rel < 0.05, rel


@pytest.mark.parametrize("mode,tol_mean", [("pot", 0.02), ("pot_fine", 0.01)])
def test_acam_softmax_accuracy(rng, mode, tol_mean):
    x = jnp.asarray(rng.normal(0, 3, (8, 128)), jnp.float32)
    p = acam_softmax(x, mode=mode)
    ref = softmax_reference(x)
    assert float(jnp.abs(p - ref).mean()) < tol_mean
    assert 0.6 < float(p.sum(-1).mean()) < 1.5  # approximately normalized


def test_acam_softmax_uniform_collapses(rng):
    """The paper's Fig. 14 ablation: uniform exp quantization breaks softmax."""
    x = jnp.asarray(rng.normal(0, 3, (8, 128)), jnp.float32)
    ref = softmax_reference(x)
    uni = acam_softmax(x, mode="uniform")
    pot = acam_softmax(x, mode="pot")
    assert float(jnp.abs(uni - ref).mean()) > 10 * float(jnp.abs(pot - ref).mean())


def test_softmax_handles_masked_rows():
    x = jnp.full((2, 16), -16.0)  # all at LOGIT min (fully masked row)
    p = acam_softmax(x, mode="pot")
    assert np.isfinite(np.asarray(p)).all()


def test_raceit_attention_acam_fidelity_equals_int(rng):
    q = jnp.asarray(rng.normal(0, 1, (1, 1, 4, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 1, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 1, 4, 8)), jnp.float32)
    a = raceit_attention(q, k, v, fidelity="int")
    b = raceit_attention(q, k, v, fidelity="acam")
    assert np.allclose(np.asarray(a), np.asarray(b))


def test_raceit_attention_close_to_float(rng):
    q = jnp.asarray(rng.normal(0, 1, (2, 2, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 2, 8, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 2, 8, 16)), jnp.float32)
    ref = jnp.einsum("bhqc,bhcd->bhqd",
                     softmax_reference(jnp.einsum("bhqd,bhcd->bhqc", q, k) / 4.0),
                     v)
    out = raceit_attention(q, k, v)
    rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
    assert rel < 0.6, rel  # PoT row-sum wobble is up to +-2^0.5 (paper mode)
    fine = raceit_attention(q, k, v, softmax_mode="pot_fine")
    rel_fine = float(jnp.abs(fine - ref).max() / jnp.abs(ref).max())
    assert rel_fine < rel + 1e-6  # beyond-paper fractional PoT is tighter
