"""Batched-serving correctness: bucket-vs-solo parity and its load-bearing
fixes.

`BatchScheduler` left-pads mixed-length buckets; the engine must make the
pads invisible — masked out of every attention step, with real tokens kept
at their solo positions — or a request's output depends on its
bucket-mates. These tests pin that contract (bitwise in digital mode),
plus the two bugs it exposed: the chunked online-softmax emitting the
uniform average of V for fully-masked rows (pad query rows!), and the
engine sampling the first token with the root rng key.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.models import Model, layers
from repro.serve import BatchScheduler, GenerationEngine, Request

from conftest import tiny_config


def _engine(key, name="gpt2-large", exec_cfg=ExecConfig(), **kw):
    cfg = tiny_config(get_config(name))
    model = Model(cfg, exec_cfg)
    params = model.init(key)
    return GenerationEngine(cfg, params, exec_cfg=exec_cfg, max_len=64, **kw)


# ---------------------------------------------------------------------------
# bucket-vs-solo parity (satellite 1)
# ---------------------------------------------------------------------------

def test_bucket_matches_solo_digital(key):
    """A request's tokens are identical solo vs in a mixed-length bucket."""
    eng = _engine(key)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 255, n).astype(np.int32) for n in (7, 3, 5)]
    solo = [eng.generate(p[None, :], 4)[0] for p in prompts]
    sched = BatchScheduler(eng, bucket_size=3)
    for i, p in enumerate(prompts):
        sched.submit(Request(i, p, n_new=4))
    done = sched.run_all()
    assert sorted(done) == [0, 1, 2]
    for i in range(3):
        np.testing.assert_array_equal(done[i].result, solo[i],
                                      err_msg=f"request {i} diverged")


def test_bucket_parity_rope_gqa_digital(key):
    """Same contract for a RoPE + grouped-query config (positions must be
    pad-shifted before RoPE, not just the attention mask)."""
    eng = _engine(key, name="command-r-35b")
    assert eng.cfg.n_kv_heads < eng.cfg.n_heads
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 255, n).astype(np.int32) for n in (6, 2)]
    solo = [eng.generate(p[None, :], 3)[0] for p in prompts]
    sched = BatchScheduler(eng, bucket_size=2)
    for i, p in enumerate(prompts):
        sched.submit(Request(i, p, n_new=3))
    done = sched.run_all()
    for i in range(2):
        np.testing.assert_array_equal(done[i].result, solo[i])


def test_equal_length_bucket_passes_no_pads(key, monkeypatch):
    """No mixed lengths -> no pad machinery (the solo hot path stays free
    of mask traffic)."""
    eng = _engine(key)
    seen = {}
    orig = GenerationEngine.generate

    def spy(self, prompts, n_new, **kw):
        seen["pad_lens"] = kw.get("pad_lens")
        return orig(self, prompts, n_new, **kw)

    monkeypatch.setattr(GenerationEngine, "generate", spy)
    sched = BatchScheduler(eng, bucket_size=2)
    rng = np.random.default_rng(2)
    for i in range(2):
        sched.submit(Request(i, rng.integers(0, 255, 5).astype(np.int32),
                             n_new=2))
    sched.run_once()
    assert seen["pad_lens"] is None


def test_raceit_gqa_bucket_serves(key):
    """Mixed-length bucket on the raceit serving default (GQA config →
    raceit_gqa_paged decode, serving the bucketed contiguous cache via its
    no-block-table fall-through): runs end-to-end, tokens well-formed.
    Bitwise solo parity is a digital-mode guarantee — raceit quantizer
    scales span the whole batch tensor by design (see serve/batching.py);
    the masking itself is proven bit-exact against the staged oracle in
    tests/test_attention_gqa.py."""
    eng = _engine(key, name="command-r-35b", exec_cfg=ExecConfig.serving())
    assert eng.plan.backend("attention_decode") == "raceit_gqa_paged"
    sched = BatchScheduler(eng, bucket_size=2)
    rng = np.random.default_rng(3)
    for i, n in enumerate((6, 3)):
        sched.submit(Request(i, rng.integers(0, 255, n).astype(np.int32),
                             n_new=3))
    done = sched.run_all()
    for r in done.values():
        assert r.result.shape == (3,)
        assert (r.result >= 0).all() and (r.result < eng.cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# fully-masked rows output zeros (satellite 2)
# ---------------------------------------------------------------------------

def test_chunked_attention_fully_masked_rows_are_zero(rng):
    """With the finite NEG_INF sentinel, a fully-masked row used to emit
    the *uniform average of V* (m never moves off its init, so
    p = exp(0) = 1 everywhere); masked-row semantics are zeros."""
    B, S, H, hd = 1, 8, 2, 4
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, 1, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, 1, hd)), jnp.float32)
    out = layers._chunked_attention(q, k, v, lambda qi, ki: qi < 0,  # none
                                    chunk=4, scale=0.5,
                                    probs_dtype=jnp.float32)
    np.testing.assert_array_equal(np.asarray(out), 0.0)
    assert not np.asarray(jnp.mean(v, axis=1)).max() == 0  # bug would emit this


def test_chunked_attention_pad_rows_masked_per_row(rng):
    """pad_lens masks keys per row; rows keep exact parity with slicing."""
    B, S, H, hd = 2, 8, 2, 4
    pad = jnp.asarray([3, 0], jnp.int32)
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, 1, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, 1, hd)), jnp.float32)
    full = lambda qi, ki: ki >= 0
    out = layers._chunked_attention(q, k, v, full, chunk=4, scale=0.5,
                                    probs_dtype=jnp.float32, pad_lens=pad)
    # row 0 == unpadded attention over keys 3:, row 1 == over all keys
    ref0 = layers._chunked_attention(q[:1], k[:1, 3:], v[:1, 3:], full,
                                     chunk=5, scale=0.5,
                                     probs_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref0[0]),
                               rtol=1e-5, atol=1e-6)
    ref1 = layers._chunked_attention(q[1:], k[1:], v[1:], full, chunk=4,
                                     scale=0.5, probs_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref1[0]),
                               rtol=1e-6)


def test_chunked_attention_partial_mask_unaffected(rng):
    """Rows with >= 1 valid key are untouched by the masked-row fix."""
    B, S, H, hd = 1, 6, 1, 4
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, 1, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, 1, hd)), jnp.float32)
    causal = lambda qi, ki: ki <= qi
    out = layers._chunked_attention(q, k, v, causal, chunk=3, scale=0.5,
                                    probs_dtype=jnp.float32)
    s = jnp.einsum("bqhd,bchd->bhqc", q * 0.5, jnp.repeat(k, 1, 2))
    s = jnp.where(jnp.arange(S)[None, :] <= jnp.arange(S)[:, None], s,
                  -jnp.inf)
    ref = jnp.einsum("bhqc,bchd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5)


# ---------------------------------------------------------------------------
# ring-overflow prompts: the slot-space pad mask must be dropped, not inverted
# ---------------------------------------------------------------------------

def test_ring_overflow_prompt_drops_decode_pad_mask(key, rng):
    """A prompt longer than a local layer's ring buffer takes the last-L
    prefill branch (column plen-L+s lands at slot s), so slot-space pad
    masking would attend only pads and mask every real token. With
    ``pad_prompt_len > L`` the decode mask must be a no-op for that layer."""
    from repro.configs.base import ModelConfig

    cfg = ModelConfig(name="loc", n_layers=1, d_model=32, n_heads=4,
                      n_kv_heads=2, d_ff=64, vocab_size=64, window=8,
                      mixer_pattern=("attn_local",), param_dtype="float32",
                      compute_dtype="float32")
    p = layers.init_attention(key, cfg, jnp.float32)
    B, L, plen, hd = 2, 8, 12, cfg.resolved_head_dim
    pad = jnp.asarray([5, 0], jnp.int32)
    cache = {"k": jnp.zeros((B, L, cfg.n_kv_heads, hd), jnp.float32),
             "v": jnp.zeros((B, L, cfg.n_kv_heads, hd), jnp.float32),
             "idx": jnp.int32(0)}
    x = jnp.asarray(rng.normal(0, 1, (B, plen, cfg.d_model)), jnp.float32)
    pos = jnp.maximum(jnp.arange(plen)[None] - pad[:, None], 0)
    _, cache = layers.attention(p, x, cfg=cfg, plan=ExecConfig(),
                                positions=pos, local=True, cache=cache,
                                pad_lens=pad)
    xt = jnp.asarray(rng.normal(0, 1, (B, 1, cfg.d_model)), jnp.float32)
    dpos = jnp.full((B, 1), plen) - pad[:, None]
    kw = dict(cfg=cfg, plan=ExecConfig(), positions=dpos, local=True,
              cache=cache)
    o_pad, _ = layers.attention(p, xt, **kw, pad_lens=pad,
                                pad_prompt_len=jnp.int32(plen))
    o_ref, _ = layers.attention(p, xt, **kw)  # no pad machinery at all
    np.testing.assert_array_equal(np.asarray(o_pad), np.asarray(o_ref))


def test_bucket_first_token_exact_with_local_ring_overflow(key):
    """Engine-level guard for the same bug: a mixed bucket whose long
    prompt overflows the local window still prefills exactly (prefill
    masks live in column space), so the first generated token matches the
    solo run even though later decode steps are only near-equal on local
    layers (documented softening)."""
    eng = _engine(key, name="gemma3-4b")
    assert "attn_local" in eng.cfg.mixer_pattern
    rng = np.random.default_rng(4)
    long_p = rng.integers(0, 255, 12).astype(np.int32)  # > window=8
    short_p = rng.integers(0, 255, 4).astype(np.int32)
    solo = [eng.generate(p[None, :], 2)[0] for p in (long_p, short_p)]
    sched = BatchScheduler(eng, bucket_size=2)
    sched.submit(Request(0, long_p, n_new=2))
    sched.submit(Request(1, short_p, n_new=2))
    done = sched.run_all()
    for i in range(2):
        assert done[i].result[0] == solo[i][0], (i, done[i].result, solo[i])
        assert (done[i].result >= 0).all()


# ---------------------------------------------------------------------------
# rng hygiene (satellite 3)
# ---------------------------------------------------------------------------

def test_generate_never_samples_with_root_key(key, monkeypatch):
    """The first token must be sampled with a key *split off* the request
    rng, not the root rng itself (which is then also used as a split
    source — JAX key reuse)."""
    used = []
    orig = jax.random.categorical

    def spy(rng, logits, axis=-1):
        used.append(tuple(np.asarray(jax.random.key_data(rng)).ravel()))
        return orig(rng, logits, axis=axis)

    monkeypatch.setattr(jax.random, "categorical", spy)
    eng = _engine(key, temperature=1.0)
    root = jax.random.PRNGKey(123)
    root_data = tuple(np.asarray(jax.random.key_data(root)).ravel())
    prompt = np.arange(5, dtype=np.int32)[None, :]
    eng.generate(prompt, 3, rng=root)
    assert len(used) == 3
    assert root_data not in used, "first token sampled with the root key"
    assert len(set(used)) == len(used), "a sampling key was reused"
