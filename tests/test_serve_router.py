"""Tenant-aware admission routing: policy order, fairness, depth caps.

Pure host-side (`repro.serve.router`) — no model, no jax. The router's
contract has three parts, each pinned here:

* **deque compatibility** — truthiness/len/iter/[0]/popleft behave like
  the FIFO deque it replaced, so every `ContinuousBatcher` drain loop and
  backpressure path works unchanged (and ``[0]`` then ``popleft()`` agree
  on the head: backpressure re-offers the SAME request);
* **policy order** — fifo is arrival order; priority is strict by weight
  (and a later high-priority arrival preempts a waiting low-priority
  head); wfq shares admitted token budget proportionally to weights and
  never starves anyone — fuzzed over generated multi-tenant backlogs with
  Jain's index as the acceptance measure, mirroring the bench gate;
* **depth caps** — per-tenant overload rejects at push with a structured
  ``RequestError(stage="admit")`` naming the tenant and cap (operational
  backpressure is data, not an exception).
"""
import numpy as np
import pytest

from repro.serve.batching import Request
from repro.serve.metrics import jain
from repro.serve.router import AdmissionRouter, request_cost

from _hypothesis_compat import given, settings, strategies as st


def _req(rid, tenant="default", plen=4, n_new=2):
    return Request(rid, np.arange(plen, dtype=np.int32), n_new=n_new,
                   tenant=tenant)


def _drain(r):
    out = []
    while r:
        out.append(r.popleft())
    return out


# ------------------------------------------------------------ construction

def test_rejects_bad_parameters():
    with pytest.raises(ValueError, match="unknown router policy"):
        AdmissionRouter(policy="lifo")
    with pytest.raises(ValueError, match="quantum"):
        AdmissionRouter(quantum=0)
    with pytest.raises(ValueError, match="max_queue_per_tenant"):
        AdmissionRouter(max_queue_per_tenant=0)


# -------------------------------------------------------- deque-compatible

def test_deque_surface_matches_fifo_semantics():
    r = AdmissionRouter()
    assert not r and len(r) == 0
    with pytest.raises(IndexError, match="empty"):
        r[0]
    with pytest.raises(IndexError, match="empty"):
        r.popleft()
    reqs = [_req(i, t) for i, t in enumerate("abcab")]
    for q in reqs:
        assert r.push(q) is None
    assert r and len(r) == 5
    assert [q.rid for q in r] == [0, 1, 2, 3, 4]  # iteration: arrival order
    with pytest.raises(IndexError, match="only the policy head"):
        r[1]
    assert r[0] is reqs[0] and r[0] is r.popleft()  # peek == pop head
    assert [q.rid for q in _drain(r)] == [1, 2, 3, 4]
    assert r.depths() == {}


def test_fifo_is_tenant_blind_arrival_order():
    r = AdmissionRouter(policy="fifo", weights={"vip": 100.0})
    for i, t in enumerate(["free", "vip", "free", "vip"]):
        r.push(_req(i, t))
    assert [q.rid for q in _drain(r)] == [0, 1, 2, 3]


# ------------------------------------------------------------- priority

def test_priority_serves_heaviest_tenant_first_fifo_within_class():
    r = AdmissionRouter(policy="priority", weights={"gold": 3, "bronze": 1})
    order = ["bronze", "gold", "bronze", "gold", "silverless"]  # w=1 default
    for i, t in enumerate(order):
        r.push(_req(i, t))
    # gold (w=3) first in arrival order, then the three w=1 in arrival order
    assert [q.rid for q in _drain(r)] == [1, 3, 0, 2, 4]


def test_priority_late_arrival_preempts_waiting_head():
    """The head is policy-fresh until popped: a high-priority request that
    arrives while a low-priority head waits (e.g. under page-pool
    backpressure) is served first once admission resumes."""
    r = AdmissionRouter(policy="priority", weights={"gold": 2})
    r.push(_req(0, "bronze"))
    assert r[0].rid == 0          # bronze is the head ...
    r.push(_req(1, "gold"))
    assert r[0].rid == 1          # ... until gold arrives
    assert [q.rid for q in _drain(r)] == [1, 0]


# ------------------------------------------------------------------ wfq

def test_wfq_peek_pop_agree_and_deficits_charge_once():
    r = AdmissionRouter(policy="wfq", weights={"a": 2, "b": 1})
    for i, t in enumerate("abab"):
        r.push(_req(i, t))
    for _ in range(4):
        head = r[0]
        assert r[0] is head        # repeated peeks don't advance DRR state
        assert r.popleft() is head


def test_wfq_proportional_service_on_backlog():
    """Two always-backlogged tenants at weights 3:1 with equal-cost
    requests: a service window's admitted counts track the weights."""
    r = AdmissionRouter(policy="wfq", weights={"heavy": 3, "light": 1},
                        quantum=8.0)
    rid = 0
    for _ in range(40):
        for t in ("heavy", "light"):
            r.push(_req(rid, t, plen=6, n_new=2))  # cost 8 each
            rid += 1
    window = [r.popleft().tenant for _ in range(40)]
    served = {t: window.count(t) for t in ("heavy", "light")}
    # 3:1 on 40 pops is 30/10; DRR rounding can wobble by a request
    assert abs(served["heavy"] - 30) <= 1
    assert served["heavy"] + served["light"] == 40
    fairness = jain([served["heavy"] / 3.0, served["light"] / 1.0])
    assert fairness > 0.99


def test_wfq_emptied_queue_forfeits_deficit():
    """Classic DRR: a tenant that drains its queue cannot bank deficit
    and burst later — it restarts from zero when traffic returns."""
    r = AdmissionRouter(policy="wfq", weights={"a": 5, "b": 1}, quantum=100)
    r.push(_req(0, "a"))
    r.popleft()
    assert r._deficit["a"] == 0.0  # not 100*5 - cost
    # returning traffic competes from scratch
    r.push(_req(1, "b"))
    r.push(_req(2, "a"))
    assert len(_drain(r)) == 2


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_wfq_fuzz_no_starvation_and_conservation(seed):
    """Generated multi-tenant backlogs (the acceptance fuzz): every
    submitted request is served exactly once (conservation), and no
    tenant starves — DRR's guarantee is a BOUNDED first-service delay:
    tenant t needs at most ceil(maxcost / (quantum*w_t)) pointer visits
    to cover its head, and between two visits every other tenant can
    spend at most its per-visit top-up plus its carried deficit
    (< quantum*w_j + maxcost tokens). The bound holds for every seed,
    unlike window-count checks, which DRR's quantum-scale service bursts
    legitimately violate."""
    import math

    rng = np.random.default_rng(seed)
    n_tenants = int(rng.integers(2, 5))
    tenants = [f"t{i}" for i in range(n_tenants)]
    weights = {t: float(rng.integers(1, 5)) for t in tenants}
    quantum = float(rng.integers(2, 9))
    r = AdmissionRouter(policy="wfq", weights=weights, quantum=quantum)
    rid, maxcost = 0, 0
    per_tenant = int(rng.integers(8, 16))
    for _ in range(per_tenant):
        for t in tenants:
            q = _req(rid, t, plen=int(rng.integers(1, 9)),
                     n_new=int(rng.integers(1, 5)))
            maxcost = max(maxcost, request_cost(q))
            r.push(q)
            rid += 1
    first_seen, spent, order = {}, 0, []
    while r:
        q = r.popleft()
        order.append(q.rid)
        first_seen.setdefault(q.tenant, spent)
        spent += request_cost(q)
    assert sorted(order) == list(range(rid))  # conservation, exactly once
    for t in tenants:
        visits = math.ceil(maxcost / (quantum * weights[t]))
        bound = visits * sum(quantum * weights[j] + maxcost
                             for j in tenants if j != t)
        assert first_seen[t] <= bound, (
            f"seed={seed}: tenant {t} first served after {first_seen[t]} "
            f"tokens, DRR delay bound is {bound}")


# -------------------------------------------------------------- depth caps

def test_depth_cap_rejects_with_structured_error():
    r = AdmissionRouter(max_queue_per_tenant=2)
    assert r.push(_req(0, "a")) is None
    assert r.push(_req(1, "a")) is None
    err = r.push(_req(2, "a"))
    assert err is not None and err.stage == "admit" and err.rid == 2
    assert "'a'" in err.reason and "cap (2)" in err.reason
    # caps are per tenant: another tenant is unaffected
    assert r.push(_req(3, "b")) is None
    assert r.rejected == 1 and len(r) == 3
    # popping frees headroom
    r.popleft()
    assert r.push(_req(4, "a")) is None
