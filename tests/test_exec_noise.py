"""Device-variation injection behind the ExecPlan (`repro.hw.noise` +
`repro.exec.noisy`).

The three contracts under test:

  zero-noise no-op   every ``raceit_noisy_*`` backend at an all-zero
                     NoiseConfig is BIT-identical to its clean counterpart
                     — enumerated from the registry, not a hand-kept list,
                     so a new noisy backend is auto-covered (or a missing
                     one is caught);
  determinism        one (seed, NoiseConfig) pair reproduces identical
                     noisy outputs across calls; a different seed is a
                     different simulated chip;
  cache identity     ``noise`` participates in the resolve_plan lru-cache
                     key — configs differing only in noise (or only in
                     noise *seed*) resolve to distinct plans.

Plus the `repro.hw.simulator` degenerate-workload guards (same ISSUE).
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ExecConfig
from repro.exec import OP_SLOTS, list_backends, resolve_plan
from repro.hw.noise import NoiseConfig, fault_rows, site_key

from conftest import tiny_config

CFG = tiny_config(get_config("gpt2-large"))
CLEAN = ExecConfig(mode="raceit")
ZERO = ExecConfig(mode="raceit", noise=NoiseConfig())
NOMINAL = ExecConfig(mode="raceit", noise=NoiseConfig.preset("nominal"))


def _slot_args(rng, slot):
    """Representative call for each op slot (shapes carry the head counts;
    q has H=4 over KV=2 so the staged paths exercise the GQA repeat)."""
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    if slot == "matmul":
        return (f32(2, 16), f32(16, 8), None), {}
    if slot == "activation":
        return (f32(4, 16),), {}
    if slot == "softmax":
        return (f32(2, 8), -1), {}
    if slot == "attention_prefill":
        return ((f32(1, 8, 4, 8), f32(1, 8, 2, 8), f32(1, 8, 2, 8)),
                dict(scale=0.35, q_offset=0, kind="causal", window=4,
                     chunk=8))
    if slot == "attention_decode":
        return ((f32(2, 1, 4, 8), f32(2, 16, 2, 8), f32(2, 16, 2, 8)),
                dict(kv_len=jnp.int32(12), scale=0.35))
    if slot == "dd_matmul":
        i8 = lambda *s: jnp.asarray(rng.integers(-127, 128, s), jnp.int8)
        return (i8(2, 4, 8), i8(2, 8, 4)), {}
    if slot == "lm_head":
        return (f32(1, 4, 16), f32(16, 32)), {}
    raise KeyError(slot)


# ---------------------------------------------------------------------------
# registry-derived zero-noise parity (satellite 1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("slot", OP_SLOTS)
def test_noisy_backends_zero_sigma_bit_parity(slot, rng):
    """Enumerate the registry: every noisy-named backend, pinned via
    op_overrides under an all-zero NoiseConfig, must produce outputs
    bit-identical to its clean counterpart (name minus 'noisy_', falling
    back to raceit_staged)."""
    names = list_backends(slot)
    noisy_names = sorted(n for n in names if "noisy" in n)
    if slot in ("dd_matmul", "lm_head"):
        # no noisy form by design: dd_matmul noise is injected on its
        # operand codes inside the noisy attention backends, and the lm
        # head defaults to full precision (resident-int8 noise rides the
        # matmul slot)
        assert not noisy_names
        return
    assert noisy_names, f"slot {slot!r} has no raceit_noisy_* backend"
    args, kwargs = _slot_args(rng, slot)
    for name in noisy_names:
        ref = name.replace("noisy_", "")
        if ref not in names:
            ref = "raceit_staged"
        p_noisy = resolve_plan(CFG, ZERO.with_ops(**{slot: name}))
        p_clean = resolve_plan(CFG, CLEAN.with_ops(**{slot: ref}))
        assert p_noisy.backend(slot) == name
        assert p_clean.backend(slot) == ref
        got = np.asarray(getattr(p_noisy, slot)(*args, **kwargs))
        want = np.asarray(getattr(p_clean, slot)(*args, **kwargs))
        np.testing.assert_array_equal(got, want,
                                      err_msg=f"{slot}/{name} vs {ref}")


@pytest.mark.parametrize("mode", ["pot", "pot_fine", "uniform"])
@pytest.mark.parametrize("fill", [4, 16])
def test_zero_noise_attention_parity_matrix(mode, fill, rng):
    """Default-chain resolution (no pins): a zero-noise raceit plan routes
    attention to raceit_noisy_staged and stays bit-identical to the clean
    plan across softmax modes and decode fill levels (incl. a per-row
    kv_len vector)."""
    clean = resolve_plan(CFG, ExecConfig(mode="raceit", softmax_mode=mode))
    zero = resolve_plan(CFG, ExecConfig(mode="raceit", softmax_mode=mode,
                                        noise=NoiseConfig()))
    assert zero.backend("attention_decode") == "raceit_noisy_staged"
    f32 = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    q, k, v = f32(2, 1, 4, 8), f32(2, 16, 2, 8), f32(2, 16, 2, 8)
    kv = jnp.asarray([fill, max(fill // 2, 1)], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(clean.attention_decode(q, k, v, kv_len=kv, scale=0.3)),
        np.asarray(zero.attention_decode(q, k, v, kv_len=kv, scale=0.3)))
    qp, kp, vp = f32(1, 8, 4, 8), f32(1, 8, 2, 8), f32(1, 8, 2, 8)
    kw = dict(scale=0.3, q_offset=0, kind="causal", window=4, chunk=8)
    np.testing.assert_array_equal(
        np.asarray(clean.attention_prefill(qp, kp, vp, **kw)),
        np.asarray(zero.attention_prefill(qp, kp, vp, **kw)))


# ---------------------------------------------------------------------------
# determinism + actual effect
# ---------------------------------------------------------------------------

def test_noisy_outputs_reproducible_and_seed_dependent(rng):
    pA = resolve_plan(CFG, NOMINAL)
    pB = resolve_plan(CFG, ExecConfig(
        mode="raceit", noise=NoiseConfig.preset("nominal", seed=1)))
    p0 = resolve_plan(CFG, CLEAN)
    x = jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    y1 = np.asarray(pA.matmul(x, w, None))
    # same seed + config -> bit-identical across calls
    np.testing.assert_array_equal(y1, np.asarray(pA.matmul(x, w, None)))
    # a different seed is a different chip; any noise differs from clean
    assert not np.array_equal(y1, np.asarray(pB.matmul(x, w, None)))
    assert not np.array_equal(y1, np.asarray(p0.matmul(x, w, None)))
    lg = jnp.asarray(2.0 * rng.standard_normal((2, 8)), jnp.float32)
    s1 = np.asarray(pA.softmax(lg, -1))
    np.testing.assert_array_equal(s1, np.asarray(pA.softmax(lg, -1)))


def test_fault_rows_deterministic_and_off_by_default():
    nz = dataclasses.replace(NoiseConfig.preset("worst_case"),
                             fault_rate=0.5)
    assert fault_rows(NoiseConfig.preset("worst_case"),
                      site_key(NoiseConfig(), "decode_fault", (4,)), 4) is None
    m1 = np.asarray(fault_rows(nz, site_key(nz, "decode_fault", (4,)), 4))
    m2 = np.asarray(fault_rows(nz, site_key(nz, "decode_fault", (4,)), 4))
    np.testing.assert_array_equal(m1, m2)


# ---------------------------------------------------------------------------
# plan-cache identity (satellite 3)
# ---------------------------------------------------------------------------

def test_noise_participates_in_plan_cache_key():
    p_clean = resolve_plan(CFG, ExecConfig(mode="raceit"))
    p_zero = resolve_plan(CFG, ExecConfig(mode="raceit",
                                          noise=NoiseConfig()))
    p_seed1 = resolve_plan(CFG, ExecConfig(mode="raceit",
                                           noise=NoiseConfig(seed=1)))
    # configs differing only in noise (even only in SEED) are distinct
    # plans — the frozen NoiseConfig rides the lru-cache key
    assert p_clean is not p_zero
    assert p_zero is not p_seed1
    assert p_clean.backend("softmax") == "raceit_acam"
    assert p_zero.backend("softmax") == "raceit_noisy_acam"
    # and an equal config hits the cache
    assert resolve_plan(CFG, ExecConfig(mode="raceit",
                                        noise=NoiseConfig())) is p_zero


@pytest.mark.filterwarnings(
    "ignore:fused_attention=True requested:RuntimeWarning")
def test_fused_request_degrades_to_noisy_staged_with_reason():
    plan = resolve_plan(CFG, ExecConfig.serving(noise=NoiseConfig.preset(
        "nominal", seed=7)))
    assert plan.backend("attention_prefill") == "raceit_noisy_staged"
    assert plan.backend("attention_decode") == "raceit_noisy_staged"
    reasons = [d.reason for d in plan.degrades
               if d.slot.startswith("attention")]
    assert reasons and all("noise" in r for r in reasons)


# ---------------------------------------------------------------------------
# NoiseConfig surface + ACAM primitives
# ---------------------------------------------------------------------------

def test_noise_config_parse_and_presets():
    assert NoiseConfig.parse("clean").is_clean
    nom = NoiseConfig.parse("nominal")
    worst = NoiseConfig.parse("worst_case")
    assert worst.acam_sigma == 4 * nom.acam_sigma
    assert worst.stuck_rate == 4 * nom.stuck_rate
    assert NoiseConfig.parse("2.5") == NoiseConfig.scaled(2.5)
    assert NoiseConfig.parse(1.0) == nom
    assert NoiseConfig.parse("0").is_clean
    assert nom.fault_rate == 0.0  # faults are never a preset default
    with pytest.raises(ValueError, match="unknown noise spec"):
        NoiseConfig.parse("bogus")


def test_rangearrays_jittered(key):
    from repro.core import ops as acam_ops
    op = acam_ops.get_op("gelu")
    hw = op._hw
    assert hw.jittered(0.0, key) is hw  # zero sigma: the same object
    j1, j2 = hw.jittered(2.0, key), hw.jittered(2.0, key)
    np.testing.assert_array_equal(j1.lo, j2.lo)
    np.testing.assert_array_equal(j1.hi, j2.hi)
    assert not (np.array_equal(j1.lo, hw.lo) and np.array_equal(j1.hi, hw.hi))
    pos = jnp.arange(op.in_fmt.num_codes)
    assert not np.array_equal(np.asarray(j1(pos)), np.asarray(hw(pos)))


def test_apply_codes_noisy_zero_sigma_identity(key):
    from repro.core import ops as acam_ops
    op = acam_ops.get_op("gelu")
    codes = op.in_fmt.encode(jnp.linspace(-3.0, 3.0, 64))
    np.testing.assert_array_equal(
        np.asarray(op.apply_codes_noisy(codes, key, 0.0, 0.0)),
        np.asarray(op.apply_codes(codes)))
    n1 = np.asarray(op.apply_codes_noisy(codes, key, 2.0, 1.0))
    n2 = np.asarray(op.apply_codes_noisy(codes, key, 2.0, 1.0))
    np.testing.assert_array_equal(n1, n2)
    assert not np.array_equal(n1, np.asarray(op.apply_codes(codes)))


# ---------------------------------------------------------------------------
# hw.simulator degenerate-workload guards (satellite 6)
# ---------------------------------------------------------------------------

def test_simulator_rejects_degenerate_workloads():
    from repro.hw.simulator import Workload, gpu_reference, simulate
    good = Workload("w", n_layers=2, d_model=64, d_ff=128, seq_len=16)
    res = simulate(good)
    assert res["tops_per_w"] > 0
    with pytest.raises(ValueError, match="n_layers"):
        simulate(Workload("w", 0, 64, 128, 16))
    with pytest.raises(ValueError, match="seq_len"):
        simulate(Workload("w", 2, 64, 128, 0))
    with pytest.raises(ValueError, match="d_ff"):
        simulate(Workload("w", 2, 64, None, 16))
    with pytest.raises(ValueError, match="d_model"):
        simulate(Workload("w", 2, 0, 128, 16))
    with pytest.raises(ValueError, match="tokens_per_s"):
        gpu_reference({})
    with pytest.raises(ValueError, match="tokens_per_s"):
        gpu_reference({"tokens_per_s": 0.0, "energy_per_token_uj": 1.0})
    with pytest.raises(ValueError, match="energy_per_token_uj"):
        gpu_reference({"tokens_per_s": 10.0})
    assert gpu_reference(res)["p100_tokens_per_s"] > 0
