"""Multi-device parity for the tensor-parallel attention backends.

Both tests fork `tests/_sharded_parity_child.py` under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the parent
process pins JAX to one CPU device (conftest), and the device-count flag
only takes effect before jax initializes, so the sharded paths can only
be exercised in a subprocess. The child asserts:

* ``ops``  — the raceit_fused_tp / raceit_gqa_tp backends produce
  *bitwise identical* decode outputs (contiguous per-row kv_len AND
  block-paged pool) vs the single-device serving chain, MHA + GQA x
  mesh model={1,2,4,8}, with prefill held to <= 4 ulp (XLA re-associates
  the f32 epilog inside shard_map); and that resolution picks the TP
  backends exactly when the mesh has a model axis > 1 that divides
  n_kv_heads.
* ``soak`` — end-to-end greedy tokens through `GenerationEngine`
  (params device_put under FSDP/TP specs) and generated mixed-length
  `ContinuousBatcher` paged traces on a 4-device mesh are identical to
  the no-mesh run, with the page-pool invariants held every step.

These are the CI ``distributed`` lane's teeth (ISSUE 10 acceptance).
"""
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
CHILD = ROOT / "tests" / "_sharded_parity_child.py"


def _run_child(mode, sentinel):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    out = subprocess.run([sys.executable, str(CHILD), mode], env=env,
                         capture_output=True, text=True, timeout=900)
    assert sentinel in out.stdout, (
        f"child {mode!r} failed:\n--- stdout ---\n{out.stdout[-2000:]}\n"
        f"--- stderr ---\n{out.stderr[-4000:]}")


@pytest.mark.slow
def test_sharded_op_parity_8dev():
    """Bitwise TP decode parity, MHA+GQA x mesh {1,2,4,8}."""
    _run_child("ops", "PARITY_OK")


@pytest.mark.slow
def test_sharded_serving_soak_4dev():
    """Engine + paged continuous-batching token parity on a 4-way mesh."""
    _run_child("soak", "SOAK_OK")
