"""§Roofline: per (arch x shape x mesh) roofline terms from the dry-run.

Reads results/dryrun.json (written by repro.launch.dryrun) and prints the
three-term table: compute / memory / collective seconds per step, dominant
term, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction used as the perf score.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent.parent / "results" / "dryrun.json"


def load(path=RESULTS) -> dict:
    return json.loads(Path(path).read_text())


def table(results: dict, mesh: str = "single") -> list[dict]:
    rows = []
    for key, v in sorted(results.items()):
        if v.get("status") != "ok" or v.get("mesh") != mesh:
            continue
        r = v["roofline"]
        frac = r["roofline_fraction"]
        if v["shape"].startswith(("decode", "long")):
            # decode is bandwidth-bound by nature: fraction = ideal time to
            # stream weights+cache once (argument bytes / HBM bw) / bound
            ideal = v["memory"]["argument_bytes"] / 819e9
            frac = ideal / max(r["bound_s"], 1e-30)
        rows.append({
            "cell": f"{v['arch']}|{v['shape']}",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "fraction": frac,
            "useful": v.get("useful_flops_ratio"),
            "fits": v["memory"]["fits_16GB"],
            "mem_gb": v["memory"]["per_device_bytes"] / 1e9,
        })
    return rows


def run() -> list[tuple]:
    if not RESULTS.exists():
        print("  (no results/dryrun.json — run `python -m repro.launch.dryrun"
              " --all` first)")
        return [("roofline/missing", 0.0, "no_data")]
    res = load()
    rows = table(res, "single")
    print("# §Roofline — single-pod (16x16) baseline, per device, per step")
    print(f"{'cell':42s} {'comp_s':>9s} {'mem_s':>9s} {'coll_s':>9s} "
          f"{'dom':>12s} {'frac':>7s} {'useful':>7s} {'GB/dev':>7s}")
    for r in rows:
        print(f"{r['cell']:42s} {r['compute_s']:9.4f} {r['memory_s']:9.4f} "
              f"{r['collective_s']:9.4f} {r['dominant']:>12s} "
              f"{r['fraction']:7.4f} {(r['useful'] or 0):7.3f} "
              f"{r['mem_gb']:7.2f}")
    import collections
    doms = collections.Counter(r["dominant"] for r in rows)
    print(f"  dominant-term histogram: {dict(doms)}")
    worst = sorted(rows, key=lambda r: r["fraction"])[:3]
    print("  worst roofline fractions:", [(r['cell'], round(r['fraction'], 4))
                                          for r in worst])
    return [(f"roofline/{r['cell']}", 0.0,
             f"frac={r['fraction']:.4f},dom={r['dominant']}") for r in rows]
