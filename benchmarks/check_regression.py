"""Kernel-latency trend gate: fail CI when a tracked kernel regresses.

Compares a freshly written ``BENCH_kernels.json`` against a baseline row
set and exits non-zero when any kernel present in *both* files regressed
by more than ``--threshold`` (default 1.2 = +20%) **after drift
correction**: per-kernel ratios are divided by the median ratio across all
tracked kernels, so uniform load drift on the runner shifts the whole
board without tripping the gate, while a *structural* regression — one
kernel suddenly doing an extra pass over the key stream, a lost fast
path — shows up as an outlier against its neighbours and fails. Two
asymmetries keep the normalization honest: the divisor is clamped to
``>= 1`` so a board-wide genuine *speedup* (median < 1) never inflates
unchanged kernels into failures, and a median above ``--drift-limit``
(default 1.5) fails outright — a "uniformly 1.5x slower" board on a
same-machine baseline is a shared-code regression wearing a drift
costume, not noise. New kernels (no baseline row) and removed kernels
are reported but never gate.

The baseline must be **measured on the same machine**: CI (see
.github/workflows/ci.yml) checks out the base ref into a worktree, runs
the bench there first, and gates the PR's fresh numbers against that —
never against the committed artifact, which a kernel-touching PR
regenerates itself (self-compare would always pass) and which was
produced on the author's machine (cross-machine microarchitecture noise
would fail innocent PRs)::

    git worktree add /tmp/bench_base "$(git merge-base origin/main HEAD)"
    (cd /tmp/bench_base && python benchmarks/kernels_bench.py)
    python benchmarks/kernels_bench.py           # the PR's rows
    python benchmarks/check_regression.py --prev /tmp/bench_base/BENCH_kernels.json

The bench itself uses interleaved min-of-N to suppress scheduler noise,
and the 20% normalized gate is deliberately loose. Excuse a knowing trade
on single rows with ``--allow name ...`` (say so in the PR description),
or tighten/loosen with ``--threshold``. ``--expect prefix ...`` adds a
coverage gate: the current artifact must contain at least one row per
named prefix (new-kernel families — e.g. the ``decode_gqa`` rows — stay
tracked instead of silently dropping out of the bench).
``--expect-file PATH`` reads those prefixes from a committed file
(``benchmarks/expected_rows.txt``: one prefix per line, ``#`` comments) —
a new kernel registers its coverage gate by appending a line next to its
bench code instead of editing the CI workflow.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

DEFAULT_CURRENT = Path(__file__).resolve().parent.parent / "BENCH_kernels.json"


def compare(prev: dict, cur: dict, threshold: float,
            allow: set[str], drift_limit: float = 1.5) -> list[str]:
    """Print the drift-corrected comparison; return gating failures."""
    common = sorted(set(prev) & set(cur))
    ratios = {n: (cur[n] / prev[n] if prev[n] > 0 else float("inf"))
              for n in common}
    drift = statistics.median(ratios.values()) if ratios else 1.0
    # clamp: only slowdown-drift is corrected (>=1); speedup-drift must not
    # inflate unchanged kernels into failures
    divisor = min(max(drift, 1.0), drift_limit)
    print(f"[bench-gate] board drift (median ratio): {drift:.2f}x; "
          f"normalizing slowdowns by {divisor:.2f}x")
    failures = []
    if drift > drift_limit:
        failures.append(f"board-wide slowdown: median ratio {drift:.2f}x "
                        f"exceeds --drift-limit {drift_limit:.2f}x (a "
                        f"uniform regression, not runner drift)")
    for name in common:
        norm = ratios[name] / divisor
        marker = "OK"
        if norm > threshold:
            marker = "ALLOWED" if name in allow else "REGRESSION"
        print(f"  {name}: {prev[name]:.1f} -> {cur[name]:.1f} us "
              f"({ratios[name]:.2f}x raw, {norm:.2f}x normalized) {marker}")
        if marker == "REGRESSION":
            failures.append(f"{name} regressed {norm:.2f}x drift-normalized "
                            f"(>{threshold:.2f}x vs previous PR)")
    for name in sorted(set(cur) - set(prev)):
        print(f"  {name}: NEW ({cur[name]:.1f} us, no previous row)")
    for name in sorted(set(prev) - set(cur)):
        print(f"  {name}: REMOVED (was {prev[name]:.1f} us)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", required=True,
                    help="previous PR's BENCH_kernels.json")
    ap.add_argument("--current", default=str(DEFAULT_CURRENT))
    ap.add_argument("--threshold", type=float, default=1.2,
                    help="fail when the drift-normalized current/previous "
                         "ratio exceeds this (1.2 = +20%%)")
    ap.add_argument("--allow", nargs="*", default=[],
                    help="kernel names excused from the gate this run")
    ap.add_argument("--drift-limit", type=float, default=1.5,
                    help="fail outright when the median ratio exceeds this "
                         "(board-wide slowdowns are not drift)")
    ap.add_argument("--expect", nargs="*", default=[],
                    help="row-name prefixes that must be present in the "
                         "current artifact — a coverage gate so tracked "
                         "families (e.g. kernel/attention_decode_gqa) can't "
                         "silently drop out of the bench")
    ap.add_argument("--expect-file", default=None,
                    help="file of expected row-name prefixes, one per line "
                         "('#' comments); the committed "
                         "benchmarks/expected_rows.txt lets new kernels "
                         "self-register their coverage gate instead of "
                         "editing the CI workflow")
    args = ap.parse_args()

    prev = json.loads(Path(args.prev).read_text())
    cur = json.loads(Path(args.current).read_text())
    print(f"[bench-gate] threshold {args.threshold:.2f}x normalized, "
          f"{len(set(prev) & set(cur))} tracked kernels")
    failures = compare(prev, cur, args.threshold, set(args.allow),
                       drift_limit=args.drift_limit)
    expect = list(args.expect)
    if args.expect_file:
        for line in Path(args.expect_file).read_text().splitlines():
            line = line.split("#", 1)[0].strip()
            if line:
                expect.append(line)
    for prefix in expect:
        if not any(name.startswith(prefix) for name in cur):
            failures.append(f"expected bench row(s) {prefix}* missing from "
                            f"the current artifact (coverage gate)")
    if failures:
        print("[bench-gate] FAIL:")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("[bench-gate] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
