"""Benchmark driver — one harness per paper table/figure + roofline.

Prints a ``name,us_per_call,derived`` CSV summary at the end.
"""
from __future__ import annotations

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))


def main() -> None:
    from benchmarks import (fig13_tablev, fig14_accuracy, fig15_gce,
                            kernels_bench, roofline, table_iv)

    all_rows = []
    for name, mod in (("table_iv", table_iv), ("fig13_tablev", fig13_tablev),
                      ("fig15_gce", fig15_gce), ("kernels", kernels_bench),
                      ("fig14_accuracy", fig14_accuracy),
                      ("roofline", roofline)):
        print(f"\n=== {name} " + "=" * (60 - len(name)))
        try:
            rows = mod.run()
            all_rows.extend(rows)
            if name == "kernels":  # machine-readable perf trajectory artifact
                kernels_bench.write_artifact(rows)
        except Exception as e:  # noqa: BLE001
            print(f"  FAILED: {e}")
            all_rows.append((f"{name}/FAILED", 0.0, str(e)[:60]))

    print("\n# CSV summary")
    print("name,us_per_call,derived")
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
