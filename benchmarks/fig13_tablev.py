"""Paper Fig. 13 + Table V: speedup/energy vs baselines, TOPS and TOPS/W.

The analytical simulator is calibrated on bert-base only (hw/simulator.py
docstring); bert-large and gpt2-large rows and all ratios are predictions.
GPU reference points are anchored to the paper's measured ratios (no CUDA in
this container) and flagged as such.
"""
from __future__ import annotations

import time


def run() -> list[tuple]:
    from repro.configs import get_config
    from repro.hw.params import PAPER_CLAIMS
    from repro.hw.simulator import Workload, gpu_reference, simulate

    rows = []
    print("# Table V — TOPS / TOPS/W (ours-modeled vs paper)")
    print(f"{'model':12s} {'arch':14s} {'TOPS':>9s} {'paper':>9s} "
          f"{'TOPS/W':>8s} {'paper':>8s}")
    t0 = time.perf_counter()
    for name in ("bert-base", "bert-large", "gpt2-large"):
        w = Workload.from_config(get_config(name))
        res = {a: simulate(w, a) for a in ("raceit", "puma", "retransformer")}
        paper = PAPER_CLAIMS["table_v_tops"][name]
        for a, label in (("puma", "PUMA"), ("retransformer", "ReTransformer"),
                         ("raceit", "RACE-IT")):
            r = res[a]
            print(f"{name:12s} {label:14s} {r['tops']:9.1f} "
                  f"{paper[label][0]:9.1f} {r['tops_per_w']:8.1f} "
                  f"{paper[label][1]:8.1f}")
        sp_puma = res["raceit"]["tokens_per_s"] / res["puma"]["tokens_per_s"]
        sp_ret = (res["raceit"]["tokens_per_s"]
                  / res["retransformer"]["tokens_per_s"])
        en_puma = (res["puma"]["energy_per_token_uj"]
                   / res["raceit"]["energy_per_token_uj"])
        gpu = gpu_reference(res["raceit"])
        print(f"  -> speedup vs PUMA {sp_puma:.2f} (paper 5.9) | vs ReT "
              f"{sp_ret:.2f} (paper 4.0; NB paper Table V itself implies "
              f"{paper['RACE-IT'][0]/paper['ReTransformer'][0]:.2f}) | "
              f"energy-saving vs PUMA {en_puma:.2f} (paper 3.9)")
        print(f"  -> anchored GPU refs: P100 {gpu['p100_tokens_per_s']:.0f} "
              f"tok/s, H100 {gpu['h100_tokens_per_s']:.0f} tok/s "
              f"(x{PAPER_CLAIMS['speedup_vs_p100']}/"
              f"x{PAPER_CLAIMS['speedup_vs_h100']} paper-measured)")
        rows.append((f"fig13/{name}/speedup_vs_puma",
                     (time.perf_counter() - t0) * 1e6 / 3,
                     f"{sp_puma:.2f}x_paper_5.9x"))
    return rows
