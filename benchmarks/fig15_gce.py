"""Paper Fig. 15: speedup vs GCE multiplier:exponent ratio k."""
from __future__ import annotations

import time


def run() -> list[tuple]:
    from repro.configs import get_config
    from repro.hw.gce import k_sweep, optimal_k_range

    rows = []
    print("# Fig. 15 — k sweep (multipliers : exp units)")
    t0 = time.perf_counter()
    for name, L in (("bert-base", 384), ("bert-large", 384),
                    ("gpt2-large", 384)):
        sw = k_sweep(get_config(name), seq_len=L)
        lo, hi = optimal_k_range(sw, 0.15)
        best = max(sw, key=lambda r: r["tokens_per_s"])
        print(f"  {name:12s} optimal k in [{lo:.1f}, {hi:.1f}] "
              f"(paper: 3.7..38 for BERT, 13.4..38 for GPT-2; chosen 28.3) "
              f"best k={best['k']} bottleneck={best['bottleneck']}")
        rows.append((f"fig15/{name}", (time.perf_counter() - t0) * 1e6 / 3,
                     f"k_opt=[{lo:.1f},{hi:.1f}]"))
    # the paper's design point (454 multipliers / 16 exp units, k=28.3)
    from repro.hw.gce import split_for_k
    s = split_for_k(28.3)
    print(f"  design point k=28.3 -> {s['multipliers']} multipliers / "
          f"{s['exp_units']} exp units (paper: 454 / 16)")
    return rows
