"""Paper Fig. 14: full-precision vs uniform vs PoT quantization accuracy.

No HF hub offline, so the claim under test is evaluated 1:1 on a from-scratch
transformer trained on structured synthetic data (DESIGN.md §7): apply the
RACE-IT inference path with (a) PoT-quantized exp (paper config), (b) our
beyond-paper fractional PoT, (c) straightforward uniform quantization — the
paper reports ~0.2% loss for (a) and catastrophic (~47%) loss for (c).
Metric: next-token top-1 accuracy on held-out batches.
"""
from __future__ import annotations

import time


def run(steps: int = 300) -> list[tuple]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.configs.base import ExecConfig
    from repro.data import SyntheticLM
    from repro.models import Model
    from repro.train import optim, trainer

    cfg = get_config("bert-base").replace(
        name="fig14-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=128, causal=True, pos_emb="rope", norm="rmsnorm",
        glu=False, qkv_bias=False, activation="gelu",
        param_dtype="float32", compute_dtype="float32", remat="none",
        family="dense", tie_embeddings=True)
    data = SyntheticLM(vocab_size=128, seq_len=64, global_batch=16, seed=3)

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(lr=1e-3, weight_decay=0.01,
                                schedule=optim.warmup_cosine(20, steps))
    step_fn = jax.jit(trainer.make_train_step(model, opt_cfg))
    opt_state = optim.adamw_init(params)
    t0 = time.perf_counter()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
    train_us = (time.perf_counter() - t0) * 1e6

    def accuracy(exec_cfg: ExecConfig, n_eval: int = 4) -> float:
        ev = Model(cfg, exec_cfg)
        fwd = jax.jit(lambda p, b: ev.forward(p, b, use_remat=False))
        eval_data = SyntheticLM(vocab_size=128, seq_len=64, global_batch=16,
                                seed=999)
        hits = tot = 0
        for _ in range(n_eval):
            b = {k: jnp.asarray(v) for k, v in eval_data.next_batch().items()}
            logits = fwd(params, b)
            pred = jnp.argmax(logits[:, :-1], -1)
            hits += int((pred == b["tokens"][:, 1:]).sum())
            tot += pred.size
        return hits / tot

    results = {
        "fp32": accuracy(ExecConfig(mode="digital")),
        "raceit_pot": accuracy(ExecConfig(mode="raceit", softmax_mode="pot")),
        "raceit_pot_fine": accuracy(ExecConfig(mode="raceit",
                                               softmax_mode="pot_fine")),
        "raceit_uniform": accuracy(ExecConfig(mode="raceit",
                                              softmax_mode="uniform")),
    }
    print("# Fig. 14 — next-token accuracy under RACE-IT quantization")
    for k, v in results.items():
        print(f"  {k:18s} {v*100:6.2f}%")
    drop_pot = results["fp32"] - results["raceit_pot"]
    drop_uni = results["fp32"] - results["raceit_uniform"]
    print(f"  PoT drop {drop_pot*100:.2f}pp (paper ~0.2pp) | uniform drop "
          f"{drop_uni*100:.2f}pp (paper ~47pp collapse)")
    return [("fig14/train", train_us / steps, f"loss={float(m['loss']):.3f}"),
            ("fig14/acc_pot", 0.0, f"{results['raceit_pot']*100:.2f}%"),
            ("fig14/acc_uniform", 0.0,
             f"{results['raceit_uniform']*100:.2f}%")]
