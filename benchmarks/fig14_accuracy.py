"""Paper Fig. 14: full-precision vs uniform vs PoT quantization accuracy.

No HF hub offline, so the claim under test is evaluated 1:1 on a from-scratch
transformer trained on structured synthetic data (DESIGN.md §7): apply the
RACE-IT inference path with (a) PoT-quantized exp (paper config), (b) our
beyond-paper fractional PoT, (c) straightforward uniform quantization — the
paper reports ~0.2% loss for (a) and catastrophic (~47%) loss for (c).
Metric: next-token top-1 accuracy on held-out batches.

`run_sweep` extends the same harness along the *device-variation* axis
(`repro.hw.noise`): the trained model is evaluated through the
``raceit_noisy_*`` backends at sigma scales of the nominal noise profile
(0 = ideal devices, 1 = nominal, 4 = worst_case), emitting
``accuracy_noise/`` BENCH rows as error-% (lower is better, floored at
0.1 so the trend gate's ratio stays finite). Two hard in-bench gates,
both SystemExit on violation: sigma=0 must be *bit-identical* to the
clean raceit path (full-logits comparison, not accuracy), and error must
be monotone non-decreasing in sigma up to a 2pp eval-noise tolerance.
"""
from __future__ import annotations

import time


def _train(steps: int):
    """Train the Fig.-14 tiny LM once; returns (cfg, params, accuracy_fn,
    train_us_per_step, final_metrics). ``accuracy_fn(exec_cfg, n_eval)``
    is held-out next-token top-1 through that ExecConfig."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.configs.base import ExecConfig
    from repro.data import SyntheticLM
    from repro.models import Model
    from repro.train import optim, trainer

    cfg = get_config("bert-base").replace(
        name="fig14-tiny", n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        d_ff=256, vocab_size=128, causal=True, pos_emb="rope", norm="rmsnorm",
        glu=False, qkv_bias=False, activation="gelu",
        param_dtype="float32", compute_dtype="float32", remat="none",
        family="dense", tie_embeddings=True)
    data = SyntheticLM(vocab_size=128, seq_len=64, global_batch=16, seed=3)

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = optim.AdamWConfig(lr=1e-3, weight_decay=0.01,
                                schedule=optim.warmup_cosine(20, steps))
    step_fn = jax.jit(trainer.make_train_step(model, opt_cfg))
    opt_state = optim.adamw_init(params)
    t0 = time.perf_counter()
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
    train_us = (time.perf_counter() - t0) * 1e6

    def accuracy(exec_cfg: ExecConfig, n_eval: int = 4) -> float:
        ev = Model(cfg, exec_cfg)
        fwd = jax.jit(lambda p, b: ev.forward(p, b, use_remat=False))
        eval_data = SyntheticLM(vocab_size=128, seq_len=64, global_batch=16,
                                seed=999)
        hits = tot = 0
        for _ in range(n_eval):
            b = {k: jnp.asarray(v) for k, v in eval_data.next_batch().items()}
            logits = fwd(params, b)
            pred = jnp.argmax(logits[:, :-1], -1)
            hits += int((pred == b["tokens"][:, 1:]).sum())
            tot += pred.size
        return hits / tot

    return cfg, params, accuracy, train_us / steps, m


def run(steps: int = 300) -> list[tuple]:
    from repro.configs.base import ExecConfig

    cfg, params, accuracy, train_us, m = _train(steps)
    results = {
        "fp32": accuracy(ExecConfig(mode="digital")),
        "raceit_pot": accuracy(ExecConfig(mode="raceit", softmax_mode="pot")),
        "raceit_pot_fine": accuracy(ExecConfig(mode="raceit",
                                               softmax_mode="pot_fine")),
        "raceit_uniform": accuracy(ExecConfig(mode="raceit",
                                              softmax_mode="uniform")),
    }
    print("# Fig. 14 — next-token accuracy under RACE-IT quantization")
    for k, v in results.items():
        print(f"  {k:18s} {v*100:6.2f}%")
    drop_pot = results["fp32"] - results["raceit_pot"]
    drop_uni = results["fp32"] - results["raceit_uniform"]
    print(f"  PoT drop {drop_pot*100:.2f}pp (paper ~0.2pp) | uniform drop "
          f"{drop_uni*100:.2f}pp (paper ~47pp collapse)")
    return [("fig14/train", train_us, f"loss={float(m['loss']):.3f}"),
            ("fig14/acc_pot", 0.0, f"{results['raceit_pot']*100:.2f}%"),
            ("fig14/acc_uniform", 0.0,
             f"{results['raceit_uniform']*100:.2f}%")]


def run_sweep(steps: int = 300, sigmas=(0.0, 0.5, 1.0, 2.0, 4.0),
              n_eval: int = 4) -> list[tuple]:
    """Accuracy-under-device-noise sweep on the raceit_noisy_* backends.

    ``sigmas`` are scales of the nominal noise profile
    (`repro.hw.noise.NoiseConfig.scaled`). Emits one
    ``accuracy_noise/err_pct_sigma<s>`` row per point (error-%, lower is
    better) and enforces the two structural gates documented in the
    module docstring with SystemExit — a CI failure, not a drifting
    number.
    """
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import ExecConfig
    from repro.data import SyntheticLM
    from repro.hw.noise import NoiseConfig
    from repro.models import Model

    cfg, params, accuracy, _, _ = _train(steps)
    base = ExecConfig(mode="raceit", softmax_mode="pot")

    # gate 1: sigma=0 noisy plan is BIT-identical to the clean raceit plan
    # (full logits, one eval batch — stronger than matching accuracy)
    ev_clean = Model(cfg, base)
    ev_zero = Model(cfg, dataclasses.replace(base,
                                             noise=NoiseConfig.scaled(0.0)))
    b = {k: jnp.asarray(v) for k, v in
         SyntheticLM(vocab_size=128, seq_len=64, global_batch=16,
                     seed=999).next_batch().items()}
    lg_clean = np.asarray(jax.jit(
        lambda p, bt: ev_clean.forward(p, bt, use_remat=False))(params, b))
    lg_zero = np.asarray(jax.jit(
        lambda p, bt: ev_zero.forward(p, bt, use_remat=False))(params, b))
    if not np.array_equal(lg_clean, lg_zero):
        raise SystemExit(
            "accuracy_noise: sigma=0 raceit_noisy_* logits are NOT "
            "bit-identical to the clean raceit path — the zero-noise "
            "no-op contract of repro.exec.noisy is broken")
    print("# accuracy-vs-noise sweep (sigma = scale of the nominal profile)")
    print("  sigma=0 bit-parity vs clean raceit path: OK")

    rows, prev_err = [], 0.0
    for lam in sigmas:
        ec = dataclasses.replace(base, noise=NoiseConfig.scaled(float(lam)))
        acc = accuracy(ec, n_eval=n_eval)
        err = (1.0 - acc) * 100.0
        print(f"  sigma {lam:>4g}x nominal: acc {acc*100:6.2f}%  "
              f"err {err:6.2f}%")
        # gate 2: more device noise must not (meaningfully) help — error
        # is monotone non-decreasing up to a 2pp eval-noise tolerance
        # against the running max
        if err < prev_err - 2.0:
            raise SystemExit(
                f"accuracy_noise: error DROPPED by "
                f"{prev_err - err:.2f}pp at sigma={lam:g} — "
                f"accuracy-vs-noise should be monotone (±2pp tolerance); "
                f"the injection is likely not reaching the compute path")
        prev_err = max(prev_err, err)
        # BENCH value is error-% (lower is better, matching the trend
        # gate's direction), floored at 0.1 so a perfect score can never
        # poison the gate's prev/cur ratio with a zero
        rows.append((f"accuracy_noise/err_pct_sigma{lam:g}",
                     max(err, 0.1), f"acc_{acc*100:.2f}pct"))
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--sweep", action="store_true",
                    help="run the accuracy-vs-device-noise sweep instead of "
                         "the Fig. 14 quantization comparison")
    args = ap.parse_args()
    out = run_sweep(steps=args.steps) if args.sweep else run(steps=args.steps)
    for name, val, extra in out:
        print(f"BENCH {name} {val:.3f} {extra}")
