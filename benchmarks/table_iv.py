"""Paper Table IV: Compute-ACAM vs CMOS operator area/power (+/- encoding).

Everything in the "ours" columns is DERIVED from our range/rectangle compiler
(cell counts) x the per-array constants of Table II — not transcribed.
"""
from __future__ import annotations

import time


def run() -> list[tuple]:
    from repro.hw.area import table_iv

    t0 = time.perf_counter()
    tbl = table_iv()
    dt_us = (time.perf_counter() - t0) * 1e6

    rows = []
    print("# Table IV — operator area (um^2) / power (mW):"
          " ours(derived) vs paper vs CMOS")
    print(f"{'operator':12s} {'enc':5s} {'ours A':>8s} {'paper A':>8s} "
          f"{'CMOS A':>8s} {'ours P':>8s} {'paper P':>8s} {'CMOS P':>8s}")
    for op, variants in tbl.items():
        for enc, v in variants.items():
            print(f"{op:12s} {enc:5s} {v['ours_area_um2']:8.1f} "
                  f"{v['paper_area_um2']:8.1f} {v['cmos_area_um2']:8.1f} "
                  f"{v['ours_power_mw']:8.4f} {v['paper_power_mw']:8.4f} "
                  f"{v['cmos_power_mw']:8.4f}")
            rows.append((f"table_iv/{op}/{enc}", dt_us / 8,
                         f"area={v['ours_area_um2']}um2"))
    # headline: encoding reduction + vs-CMOS (paper: 22-35% and 39-82%)
    red = []
    for op, v in tbl.items():
        if op != "adc4":  # ADC already fits one array (paper notes this too)
            red.append(1 - v["encoded"]["ours_area_um2"]
                       / v["plain"]["ours_area_um2"])
    print(f"encoding area reduction (ours): "
          f"{min(red)*100:.0f}%..{max(red)*100:.0f}% (paper: 22%..35%)")
    return rows
