"""Kernel micro-benchmarks (interpret mode on CPU — correctness/latency probe;
the roofline for the real TPU path comes from the dry-run §Roofline).

Timings are interleaved min-of-N (the standard noise-robust estimator on a
shared container). `python benchmarks/kernels_bench.py` also writes the
machine-readable ``BENCH_kernels.json`` artifact (name -> us/call) so the
perf trajectory is comparable across PRs; `benchmarks/run.py` does the same
as part of the full harness. Methodology + current numbers: EXPERIMENTS.md
§Perf.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

ARTIFACT = Path(__file__).resolve().parent.parent / "BENCH_kernels.json"


def _time(fn, *args, n=10):
    # n=10 (was 3): the µs-scale LUT/MVM/softmax rows have enough run-to-run
    # variance that a min-of-3 tripped the CI trend gate on noise alone
    import jax
    fn(*args)  # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _attention_rows(rng, reps=8):
    """Fused streaming kernel vs the staged Fig.-12 oracle, interleaved."""
    import jax
    import jax.numpy as jnp

    from repro.core.attention import raceit_attention
    from repro.kernels.ops import raceit_attention_fused

    B, H, S, D = 1, 8, 512, 64  # the tracked hot-path shape (B*H=8)
    q, k, v = (jnp.asarray(rng.normal(0, 1, (B, H, S, D)), jnp.float32)
               for _ in range(3))
    staged = lambda: raceit_attention(q, k, v)
    fused = lambda: raceit_attention_fused(q, k, v, block_q=512, block_k=512)
    staged(), fused()  # compile both before interleaved timing
    t_staged = t_fused = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(staged())
        t_staged = min(t_staged, time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fused())
        t_fused = min(t_fused, time.perf_counter() - t0)
    shape = f"{B * H}x{S}x{S}x{D}"
    return [
        (f"kernel/attention_staged_{shape}", t_staged * 1e6, "fig12_staged"),
        (f"kernel/attention_fused_{shape}", t_fused * 1e6,
         f"fig12_fused_{t_staged / t_fused:.2f}x"),
    ]


def _decode_attention_rows(rng, reps=8):
    """Decode step: Sq=1 against a KV cache, three candidates interleaved.

    - ``staged``   — the quantized Fig.-12 oracle (`raceit_attention`) on the
      valid cache slice: the fused kernel's bit-exactness partner, i.e. what
      a paper-faithful non-fused decode step costs;
    - ``floatref`` — the float-score + ACAM-softmax shortcut that was the
      raceit serving decode path *before* the fused default flip (different,
      less paper-faithful numerics: k/v and probs never quantized);
    - ``fused``    — `raceit_attention_decode_fused` at the exact serving
      configuration (default block sizes, traced ``kv_len`` over the
      fixed-shape buffer; at Sk=2048 this is the multi-tile streaming
      kernel, same as `layers._raceit_fused_decode`).

    Min-of-N with candidates interleaved, like the prefill pair. See
    EXPERIMENTS.md §Decode for methodology and the serving-numerics note.
    """
    import math

    import jax
    import jax.numpy as jnp

    from repro.core.attention import raceit_attention
    from repro.core.softmax import acam_softmax
    from repro.kernels.ops import raceit_attention_decode_fused

    B, H, D = 1, 8, 64  # B*H = 8, matching the tracked prefill shape
    scale = 1.0 / math.sqrt(D)

    @jax.jit
    def float_decode(q, k, v):  # the pre-fused-default serving decode path
        s = jnp.einsum("bhqd,bhcd->bhqc", q * scale, k)
        pr = acam_softmax(s, axis=-1, mode="pot")
        return jnp.einsum("bhqc,bhcd->bhqd", pr, v)

    rows = []
    for Sk in (512, 2048):
        q = jnp.asarray(rng.normal(0, 1, (B, H, 1, D)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, H, Sk, D)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, H, Sk, D)), jnp.float32)
        kv_len = jnp.int32(Sk)  # steady-state: cache fully filled
        fill = Sk // 4          # ramp-up: 3/4 of the key blocks are invalid
        cands = {
            "staged": lambda: raceit_attention(q, k, v),
            "floatref": lambda: float_decode(q, k, v),
            "fused": lambda: raceit_attention_decode_fused(q, k, v, kv_len),
        }
        if Sk > 512:  # multi-tile streaming shapes only: a single-tile grid
            # has no whole blocks to skip, so a partial-fill row there would
            # just time noise. This row exercises the scalar-prefetched grid
            # bounds: the kernel skips fully-invalid key blocks instead of
            # masking the whole cache buffer, so it should sit well under
            # the full-fill row (same executable — kv_len is traced).
            cands["fused_partial"] = lambda: raceit_attention_decode_fused(
                q, k, v, jnp.int32(fill))
        best = {}
        for fn in cands.values():
            fn()  # compile all before interleaved timing
        for _ in range(reps):
            for name, fn in cands.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best[name] = min(best.get(name, float("inf")),
                                 time.perf_counter() - t0)
        shape = f"{B * H}x1x{Sk}x{D}"
        rows += [
            (f"kernel/attention_decode_staged_{shape}",
             best["staged"] * 1e6, "fig12_staged_slice"),
            (f"kernel/attention_decode_floatref_{shape}",
             best["floatref"] * 1e6, "pre_pr2_serving_decode"),
            (f"kernel/attention_decode_fused_{shape}", best["fused"] * 1e6,
             f"fig12_fused_decode_{best['staged'] / best['fused']:.2f}x"),
        ]
        if "fused_partial" in best:
            rows.append(
                (f"kernel/attention_decode_fused_{shape}_fill{fill}",
                 best["fused_partial"] * 1e6,
                 f"grid_bounds_{best['fused'] / best['fused_partial']:.2f}"
                 f"x_vs_full"))
    return rows


def _decode_gqa_rows(rng, reps=8):
    """GQA-native decode vs the flat fused kernel on the repeated cache.

    The flat entry folds batch x *query* heads, so a GQA serving stack must
    repeat the KV cache codes to H before the kernel — rep x the cache
    bytes. The GQA-native entry keeps the cache in its (B, KV, Smax, D)
    layout and rides the rep sharing queries on one tile, so each KV tile
    is fetched once per head group. Bytes-moved (the KV-cache int8 traffic
    per call, k+v) is reported next to the timing — the ratio is exactly
    rep, and it is the quantity that scales with serving load; outputs are
    bit-identical (tests/test_attention_gqa.py).

    ``rep_1`` guards the degenerate end: at H == KV the two entries are
    the same dataflow, so the GQA row must match the flat row to noise
    (no regression from the grouping machinery itself).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import (raceit_attention_decode_fused,
                                   raceit_attention_decode_gqa)

    B, H, D, Smax = 1, 8, 64, 2048
    kv_len = jnp.int32(Smax)
    rows = []
    for rep in (1, 4, 8):
        KV = H // rep
        q = jnp.asarray(rng.normal(0, 1, (B, H, 1, D)), jnp.float32)
        kn = jnp.asarray(rng.normal(0, 1, (B, KV, Smax, D)), jnp.float32)
        vn = jnp.asarray(rng.normal(0, 1, (B, KV, Smax, D)), jnp.float32)
        kf, vf = (jnp.repeat(a, rep, axis=1) for a in (kn, vn))
        cands = {
            "fused": lambda: raceit_attention_decode_fused(q, kf, vf, kv_len),
            "gqa": lambda: raceit_attention_decode_gqa(q, kn, vn, kv_len),
        }
        best = {}
        for fn in cands.values():
            fn()  # compile all before interleaved timing
        for _ in range(reps):
            for name, fn in cands.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best[name] = min(best.get(name, float("inf")),
                                 time.perf_counter() - t0)
        kv_bytes_native = 2 * B * KV * Smax * D      # int8 k + v per call
        kv_bytes_flat = 2 * B * H * Smax * D
        shape = f"{B * H}x1x{Smax}x{D}"
        rows.append(
            (f"kernel/attention_decode_gqa_{shape}_rep{rep}",
             best["gqa"] * 1e6,
             f"native_kv_{best['fused'] / best['gqa']:.2f}x_vs_fused_"
             f"kvbytes_{kv_bytes_native}_vs_{kv_bytes_flat}"))
        if rep > 1:  # the flat-kernel partner row, for auditable speedups
            rows.append(
                (f"kernel/attention_decode_fused_{shape}_rep{rep}",
                 best["fused"] * 1e6, f"repeat_to_H_kvbytes_{kv_bytes_flat}"))
    return rows


def _decode_perrow_rows(rng, reps=8):
    """Per-row kv_len decode vs the flat kernel at the shared max fill.

    A mixed batch of requests at fills (2048, 512, 256, 128): the flat
    kernel decodes every row to the batch max (the pre-rows behavior a
    vector kv_len degrades to on the scalar backends), while the per-row
    kernel's group tiles stop streaming at their own request's fill
    frontier (per-tile scalar-prefetched skip bounds) — with block_g
    sized so each tile carries one request's heads, the short requests
    skip 3/4 to 15/16 of their key blocks. Outputs are bit-identical on
    zeroed tails (tests/test_attention_perrow.py).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.ops import raceit_attention_decode_fused

    B, H, Smax, D = 4, 2, 2048, 64
    fills = (2048, 512, 256, 128)
    mk = lambda s: jnp.asarray(rng.normal(0, 1, s), jnp.float32)
    q = mk((B, H, 1, D))
    k = jnp.zeros((B, H, Smax, D), jnp.float32)
    v = jnp.zeros((B, H, Smax, D), jnp.float32)
    for b, f in enumerate(fills):
        k = k.at[b, :, :f].set(mk((H, f, D)))
        v = v.at[b, :, :f].set(mk((H, f, D)))
    lens = jnp.asarray(fills, jnp.int32)
    flat_len = jnp.int32(max(fills))
    # block_g=2: each group tile is one request's H=2 heads, so the skip
    # bound is per request — the mixed-traffic serving shape
    cands = {
        "perrow": lambda: raceit_attention_decode_fused(q, k, v, lens,
                                                        block_g=2),
        "flatmax": lambda: raceit_attention_decode_fused(q, k, v, flat_len,
                                                         block_g=2),
    }
    best = {}
    for fn in cands.values():
        fn()  # compile all before interleaved timing
    for _ in range(reps):
        for name, fn in cands.items():
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            best[name] = min(best.get(name, float("inf")),
                             time.perf_counter() - t0)
    shape = f"{B * H}x1x{Smax}x{D}"
    mean_fill = sum(fills) / (len(fills) * Smax)
    return [
        (f"kernel/attention_decode_rows_{shape}_mixed", best["perrow"] * 1e6,
         f"perrow_kvlen_{best['flatmax'] / best['perrow']:.2f}x_vs_flatmax_"
         f"meanfill_{mean_fill:.2f}"),
        (f"kernel/attention_decode_rows_flatmax_{shape}",
         best["flatmax"] * 1e6, "shared_max_fill_baseline"),
    ]


def _decode_paged_rows(rng, reps=8):
    """Block-paged decode vs the contiguous per-row kernel, same content.

    The mixed-fill serving batch of `_decode_perrow_rows`, with the KV
    cache scattered into a shuffled page pool ((n_pages, page_size, KV, D)
    + per-row block table) instead of contiguous (B, ., Smax, D) rows.
    The paged kernel follows the table's indirection per key block
    in-kernel — the row tracks what that indirection costs next to the
    contiguous partner (outputs are bit-identical under page permutation:
    tests/test_attention_paged.py). The GQA row does the same on the
    KV-native layout the paged serving default resolves to
    (``raceit_gqa_paged``)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.ops import (raceit_attention_decode_fused,
                                   raceit_attention_decode_gqa,
                                   raceit_attention_decode_gqa_paged,
                                   raceit_attention_decode_paged)

    B, H, Smax, D = 4, 2, 2048, 64
    ps = 256
    mp = Smax // ps
    fills = (2048, 512, 256, 128)
    lens = jnp.asarray(fills, jnp.int32)
    rows = []
    for tag, KV in (("", H), ("gqa_", 1)):  # flat MHA + 2:1-grouped GQA
        n_pages = 1 + B * mp
        q = jnp.asarray(rng.normal(0, 1, (B, H, 1, D)), jnp.float32)
        kn = np.zeros((B, KV, Smax, D), np.float32)
        vn = np.zeros((B, KV, Smax, D), np.float32)
        for b, f in enumerate(fills):
            kn[b, :, :f] = rng.normal(0, 1, (KV, f, D))
            vn[b, :, :f] = rng.normal(0, 1, (KV, f, D))
        # scatter the same content into a page pool with shuffled physical
        # pages (page 0 stays the trash page)
        bt = np.asarray(rng.permutation(np.arange(1, n_pages)),
                        np.int32).reshape(B, mp)
        k_pool = np.zeros((n_pages, ps, KV, D), np.float32)
        v_pool = np.zeros((n_pages, ps, KV, D), np.float32)
        for b in range(B):
            for p in range(mp):
                sl = slice(p * ps, (p + 1) * ps)
                k_pool[bt[b, p]] = kn[b, :, sl].transpose(1, 0, 2)
                v_pool[bt[b, p]] = vn[b, :, sl].transpose(1, 0, 2)
        k_pool, v_pool = jnp.asarray(k_pool), jnp.asarray(v_pool)
        btj = jnp.asarray(bt)
        if KV == H:
            kf, vf = jnp.asarray(kn), jnp.asarray(vn)
            contig = lambda: raceit_attention_decode_fused(q, kf, vf, lens,
                                                           block_g=2)
            paged = lambda: raceit_attention_decode_paged(q, k_pool, v_pool,
                                                          lens, btj)
        else:
            kf, vf = jnp.asarray(kn), jnp.asarray(vn)
            contig = lambda: raceit_attention_decode_gqa(q, kf, vf, lens)
            paged = lambda: raceit_attention_decode_gqa_paged(
                q, k_pool, v_pool, lens, btj)
        best = {}
        cands = {"contig": contig, "paged": paged}
        for fn in cands.values():
            fn()  # compile all before interleaved timing
        for _ in range(reps):
            for name, fn in cands.items():
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                best[name] = min(best.get(name, float("inf")),
                                 time.perf_counter() - t0)
        shape = f"{B * H}x1x{Smax}x{D}"
        rows.append(
            (f"kernel/attention_decode_paged_{tag}{shape}_ps{ps}",
             best["paged"] * 1e6,
             f"page_table_indirection_"
             f"{best['contig'] / best['paged']:.2f}x_vs_contig"))
    return rows


def _serving_longprompt_rows():
    """Chunked prefill-into-slot on long prompts + the page-pool memory win.

    Prompts up to 4x the prefill chunk — longer than any width the
    contiguous admission path could pin without resizing every slot —
    stream through `ContinuousBatcher`'s paged default. Deterministic
    counter rows (zero run-to-run noise, lower is better):

    * ``calls_per_ktok``  — model executions (chunk + decode) per 1000
      emitted tokens: the long-prompt serving cost the chunk width tunes;
    * ``peak_kv_pct``     — peak pages-in-use x page_size as a percentage
      of the contiguous pool's ``n_slots x max_len`` columns: the
      footprint the block-paged pool actually touches vs what a
      contiguous slot pool must reserve up front.
    """
    import jax
    import numpy as np

    from repro.configs.base import ExecConfig, ModelConfig
    from repro.models import Model
    from repro.serve import ContinuousBatcher, GenerationEngine, Request

    cfg = ModelConfig(name="longp", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                      param_dtype="float32", compute_dtype="float32")
    model = Model(cfg, ExecConfig())
    params = model.init(jax.random.PRNGKey(0))
    eng = GenerationEngine(cfg, params, exec_cfg=ExecConfig(), max_len=128)
    ps = 16
    # prefix cache OFF: promotion would drain pages_in_use into the shared
    # pool mid-trace and the peak-footprint row would measure cache policy,
    # not the block-paged reservation this row tracks (the cache has its
    # own rows in _serving_prefix_router_rows)
    cb = ContinuousBatcher(eng, n_slots=4, page_size=ps, prefix_cache=False)
    assert cb.paged, "paged serving must be the default on this model"
    rng = np.random.default_rng(0)
    lens_nnew = ((48, 4), (17, 2), (33, 3), (8, 6), (64, 2), (21, 4),
                 (48, 1), (9, 3))
    for i, (ln, nn) in enumerate(lens_nnew):
        cb.submit(Request(i, rng.integers(0, 255, ln).astype(np.int32),
                          n_new=nn))
    peak = 0
    while cb.queue or any(s is not None for s in cb.slots):
        cb.step()
        peak = max(peak, cb.allocator.pages_in_use)
    if any(r.error is not None for r in cb.done.values()):
        raise SystemExit("long-prompt paged serving trace failed a request")
    longest = max(ln for ln, _ in lens_nnew)
    if longest < 4 * cb.prefill_chunk:
        raise SystemExit("trace no longer exercises multi-chunk prefill")
    baseline_cols = cb.n * eng.max_len  # contiguous slot-pool reservation
    peak_cols = peak * ps
    if peak_cols >= baseline_cols:
        raise SystemExit(
            f"paged pool peaked at {peak_cols} KV columns — no footprint "
            f"win over the {baseline_cols}-column contiguous reservation")
    calls_per_ktok = 1000.0 * cb.model_calls / cb.tokens_out
    return [
        ("serve/continuous_longprompt_calls_per_ktok", calls_per_ktok,
         f"{cb.chunk_calls}chunks_{cb.decode_steps}decodes_"
         f"longest{longest}_chunk{cb.prefill_chunk}"),
        ("serve/continuous_longprompt_peak_kv_pct",
         100.0 * peak_cols / baseline_cols,
         f"peak_{peak}pages_x{ps}_vs_{baseline_cols}cols_contiguous"),
    ]


def _serving_occupancy_rows():
    """Decode-engine occupancy: slot-level continuous batching vs buckets.

    Runs the real schedulers over a tiny digital-mode model on a mixed
    (prompt length, n_new) trace and reports decode *steps per 1000
    decode tokens* — deterministic scheduler counters, not wall-clock, so
    the CI trend gate sees zero run-to-run noise and the direction
    matches the gate (lower is better). The >= 1.3x acceptance bound
    (ISSUE 5) is asserted here outright: a scheduling regression fails
    the bench itself, not just the trend comparison.
    """
    import jax

    from repro.configs.base import ExecConfig, ModelConfig
    from repro.models import Model
    from repro.serve import (BatchScheduler, ContinuousBatcher,
                             GenerationEngine, Request)
    import numpy as np

    cfg = ModelConfig(name="occ", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                      param_dtype="float32", compute_dtype="float32")
    model = Model(cfg, ExecConfig())
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    lens_nnew = ((7, 8), (3, 1), (5, 2), (2, 6), (6, 1), (4, 2), (5, 8),
                 (3, 1), (6, 3), (2, 1), (7, 5), (4, 2))
    mk = lambda: [Request(i, rng.integers(0, 255, ln).astype(np.int32),
                          n_new=nn)
                  for i, (ln, nn) in enumerate(lens_nnew)]

    eng = GenerationEngine(cfg, params, exec_cfg=ExecConfig(), max_len=64)
    sched = BatchScheduler(eng, bucket_size=4)
    for r in mk():
        sched.submit(r)
    sched.run_all()
    cb = ContinuousBatcher(eng, n_slots=4)
    for r in mk():
        cb.submit(r)
    cb.run_all()
    assert sched.tokens_out == cb.tokens_out, "schedulers dropped tokens"
    bucketed = 1000.0 * sched.decode_steps / sched.decode_tokens
    continuous = 1000.0 * cb.decode_steps / cb.decode_tokens
    ratio = bucketed / continuous
    if ratio < 1.3:
        raise SystemExit(
            f"continuous-batching occupancy regressed: {ratio:.2f}x vs "
            f"bucketed (acceptance floor 1.3x) — "
            f"{cb.decode_tokens}/{cb.decode_steps} continuous vs "
            f"{sched.decode_tokens}/{sched.decode_steps} bucketed")
    return [
        ("serve/occupancy_bucketed_steps_per_ktok", bucketed,
         f"{sched.decode_tokens}tok_{sched.decode_steps}steps"),
        ("serve/continuous_occupancy_steps_per_ktok", continuous,
         f"{cb.decode_tokens}tok_{cb.decode_steps}steps_"
         f"{ratio:.2f}x_vs_bucketed"),
    ]


def _serving_prefix_router_rows():
    """Prefix-cache TTFT/footprint wins + weighted-fair routing fairness.

    Two deterministic scheduler traces (zero run-to-run noise), each with
    its acceptance gate asserted in-bench so a regression fails the run
    outright rather than waiting for the trend comparison:

    * a **shared-prefix trace** — six requests carrying the same 64-token
      system prompt with distinct tails — served twice on the same
      engine, prefix cache off then on. Emits
      ``prefix_hit_ttft_ratio`` (mean step-TTFT with the cache over
      without; must be < 1.0 — hits must actually skip chunk calls) and
      ``prefix_hit_pages_saved_pct`` (prompt pages mapped from cache as a
      % of all full prompt pages; floor 50 — the trace repeats one
      4-page prefix 6x, so anything lower means lookups or promotion
      broke). Outputs must match bitwise between the two runs (digital
      greedy hit-path parity).
    * a **two-tenant backlog** under the wfq router (weights 3:1),
      truncated mid-backlog so the *router* (not the offered load)
      determines who got served. Emits ``router_fairness_jain`` — Jain's
      index over weight-normalized served tokens, floor 0.8. Higher is
      better (the one board row that is): the floor is the gate; the
      trend row is for visibility.
    """
    import jax
    import numpy as np

    from repro.configs.base import ExecConfig, ModelConfig
    from repro.models import Model
    from repro.serve import ContinuousBatcher, GenerationEngine, Request

    cfg = ModelConfig(name="pfx", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16,
                      param_dtype="float32", compute_dtype="float32")
    model = Model(cfg, ExecConfig())
    params = model.init(jax.random.PRNGKey(0))
    eng = GenerationEngine(cfg, params, exec_cfg=ExecConfig(), max_len=128)
    ps = 16
    rng = np.random.default_rng(0)
    system = rng.integers(0, 255, 64).astype(np.int32)  # 4 shareable pages
    tails = [rng.integers(0, 255, t).astype(np.int32)
             for t in (5, 9, 3, 7, 11, 6)]

    def serve(prefix_on):
        cb = ContinuousBatcher(eng, n_slots=2, page_size=ps,
                               prefix_cache=prefix_on)
        for i, t in enumerate(tails):
            cb.submit(Request(i, np.concatenate([system, t]), n_new=4))
        cb.run_all()
        if any(r.error is not None for r in cb.done.values()):
            raise SystemExit("shared-prefix bench trace failed a request")
        return cb

    cold, hot = serve(False), serve(True)
    for rid, r in cold.done.items():
        if not np.array_equal(r.result, hot.done[rid].result):
            raise SystemExit(
                f"prefix-cache hit path diverged from the cold path on "
                f"req {rid}: {hot.done[rid].result.tolist()} vs "
                f"{r.result.tolist()} — shared pages must be bitwise "
                f"transparent in digital greedy mode")
    ttft_ratio = (hot.metrics.ttft.summary()["mean"]
                  / cold.metrics.ttft.summary()["mean"])
    if ttft_ratio >= 1.0:
        raise SystemExit(
            f"prefix-cache TTFT ratio {ttft_ratio:.2f} >= 1.0: hits are "
            f"not skipping chunk calls on a fully-shared prefix trace")
    stats = hot.prefix.stats()
    total = stats["prefix_hit_pages"] + stats["prefix_miss_pages"]
    saved_pct = 100.0 * stats["prefix_hit_pages"] / total
    if saved_pct < 50.0:
        raise SystemExit(
            f"prefix cache saved {saved_pct:.0f}% of prompt pages on a "
            f"6x-repeated 4-page prefix (floor 50%) — lookup or "
            f"promotion is broken")

    wfq = ContinuousBatcher(eng, n_slots=2, page_size=ps, router="wfq",
                            tenant_weights={"heavy": 3.0, "light": 1.0})
    rid = 0
    for tenant in ("heavy", "light"):
        for _ in range(6):
            wfq.submit(Request(rid, rng.integers(0, 255, 8).astype(np.int32),
                               n_new=8, tenant=tenant))
            rid += 1
    for _ in range(24):  # truncate mid-backlog: service reflects the policy
        wfq.step()
    fairness = wfq.metrics.fairness(wfq.queue.weights)
    if fairness < 0.8:
        raise SystemExit(
            f"wfq served a 3:1 two-tenant backlog at Jain fairness "
            f"{fairness:.3f} (floor 0.8) over weight-normalized tokens "
            f"{wfq.metrics.tenant_tokens}")
    return [
        ("serve/prefix_hit_ttft_ratio", ttft_ratio,
         f"ttft_mean_{hot.metrics.ttft.summary()['mean']:.1f}steps_vs_"
         f"{cold.metrics.ttft.summary()['mean']:.1f}cold"),
        ("serve/prefix_hit_pages_saved_pct", saved_pct,
         f"{stats['prefix_hit_pages']}hit_{stats['prefix_miss_pages']}miss_"
         f"gate_floor50"),
        ("serve/router_fairness_jain", fairness,
         f"tokens_{'_'.join(f'{t}{n}' for t, n in sorted(wfq.metrics.tenant_tokens.items()))}"
         f"_gate_floor0.8_higher_better"),
    ]


def _sharded_decode_rows():
    """Tensor-parallel paged decode on a 4-way simulated model mesh.

    Forks `tests/_sharded_parity_child.py bench` (the in-process device
    count is pinned to 1; XLA's forced-device-count flag only works
    before jax initializes): the child re-asserts *bitwise* parity of the
    ``raceit_gqa_tp`` backend against the single-device
    ``raceit_gqa_paged`` partner on the same page pool, then reports
    interleaved min-of-N us/call. The wall time includes the 4-way
    shard_map + probe/pmax/exact collective protocol, so the row tracks
    TP dispatch overhead on simulated devices — not real scaling (that
    needs real chips), but a trend wire for the sharded code path. A
    parity break fails the bench outright, like the noise-sweep gates.
    """
    import subprocess
    child = (Path(__file__).resolve().parent.parent / "tests" /
             "_sharded_parity_child.py")
    env = {"PYTHONPATH": str(Path(__file__).resolve().parent.parent / "src"),
           "PATH": "/usr/bin:/bin", "HOME": "/tmp"}
    out = subprocess.run([sys.executable, str(child), "bench"], env=env,
                         capture_output=True, text=True, timeout=600)
    if "BENCH_OK" not in out.stdout:
        raise SystemExit(f"sharded decode bench failed:\n{out.stdout}\n"
                         f"{out.stderr[-3000:]}")
    vals = {l.split()[0]: float(l.split()[1])
            for l in out.stdout.splitlines()
            if l.startswith(("TP_DECODE_US", "REF_DECODE_US"))}
    return [("kernel/attention_decode_tp_gqa_model4_ps64",
             vals["TP_DECODE_US"],
             f"bitwise_vs_1dev_{vals['REF_DECODE_US'] / vals['TP_DECODE_US']:.2f}x")]


def _noise_sweep_rows():
    """Fast accuracy-under-device-noise smoke (the CI noise gate).

    Delegates to `benchmarks.fig14_accuracy.run_sweep` at a reduced
    (steps, sigma-grid, eval) budget: a 3-point sweep over the nominal
    noise profile. The sweep's own SystemExit gates do the hard checking
    — sigma=0 must be bit-identical to the clean raceit path and error
    must be monotone non-decreasing in sigma — so a broken zero-noise
    contract or an injection that misses the compute path fails the bench
    outright; the emitted ``accuracy_noise/`` rows (error-%, lower is
    better) ride the artifact for cross-PR trend visibility.
    """
    try:  # benchmarks/ is a namespace dir: script runs see it as sys.path[0]
        from benchmarks import fig14_accuracy
    except ImportError:
        import fig14_accuracy
    return fig14_accuracy.run_sweep(steps=120, sigmas=(0.0, 1.0, 4.0),
                                    n_eval=2)


def run() -> list[tuple]:
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    rows = []
    x = jnp.asarray(rng.integers(-128, 128, (256, 1024)), jnp.int8)
    lut = jnp.asarray(rng.integers(-128, 128, 256), jnp.int32)
    us = _time(lambda a: kops.acam_lut(a, lut), x)
    rows.append(("kernel/acam_lut_256x1024", us, "int8_lut"))

    a = jnp.asarray(rng.integers(-128, 128, (128, 512)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (512, 256)), jnp.int8)
    us = _time(lambda p, q: kops.acam_mvm(p, q), a, b)
    rows.append(("kernel/acam_mvm_128x512x256", us, "exact_adc"))

    from repro.core.ops import LOGIT_FMT
    logits = LOGIT_FMT.encode(jnp.asarray(rng.normal(0, 3, (64, 1024)),
                                          jnp.float32))
    us = _time(lambda c: kops.acam_softmax_codes(c), logits)
    rows.append(("kernel/acam_softmax_64x1024", us, "fused_fig8"))

    rows.extend(_attention_rows(rng))
    rows.extend(_decode_attention_rows(rng))
    rows.extend(_decode_gqa_rows(rng))
    rows.extend(_decode_perrow_rows(rng))
    rows.extend(_decode_paged_rows(rng))
    rows.extend(_sharded_decode_rows())
    rows.extend(_serving_occupancy_rows())
    rows.extend(_serving_longprompt_rows())
    rows.extend(_serving_prefix_router_rows())
    rows.extend(_noise_sweep_rows())

    for name, us, derived in rows:
        print(f"  {name}: {us:.0f} us/call ({derived})")
    return rows


def write_artifact(rows, path: Path = ARTIFACT) -> None:
    """name -> value for every tracked row (machine-readable across PRs).

    ``kernel/`` rows are us/call; ``serve/`` rows are deterministic
    scheduler-occupancy counters (decode steps per 1000 tokens);
    ``accuracy_noise/`` rows are held-out error-% under device noise — all
    lower-is-better, so one trend gate covers the board.
    """
    payload = {name: round(us, 1) for name, us, _ in rows
               if name.startswith(("kernel/", "serve/", "accuracy_noise/"))}
    path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    print(f"  wrote {path.name}: {len(payload)} rows")


if __name__ == "__main__":
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    write_artifact(run())
