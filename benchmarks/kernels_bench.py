"""Kernel micro-benchmarks (interpret mode on CPU — correctness/latency probe;
the roofline for the real TPU path comes from the dry-run §Roofline)."""
from __future__ import annotations

import time


def _time(fn, *args, n=3):
    import jax
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n * 1e6


def run() -> list[tuple]:
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops as kops

    rng = np.random.default_rng(0)
    rows = []
    x = jnp.asarray(rng.integers(-128, 128, (256, 1024)), jnp.int8)
    lut = jnp.asarray(rng.integers(-128, 128, 256), jnp.int32)
    us = _time(lambda a: kops.acam_lut(a, lut), x)
    rows.append(("kernel/acam_lut_256x1024", us, "int8_lut"))

    a = jnp.asarray(rng.integers(-128, 128, (128, 512)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (512, 256)), jnp.int8)
    us = _time(lambda p, q: kops.acam_mvm(p, q), a, b)
    rows.append(("kernel/acam_mvm_128x512x256", us, "exact_adc"))

    from repro.core.ops import LOGIT_FMT
    logits = LOGIT_FMT.encode(jnp.asarray(rng.normal(0, 3, (64, 1024)),
                                          jnp.float32))
    us = _time(lambda c: kops.acam_softmax_codes(c), logits)
    rows.append(("kernel/acam_softmax_64x1024", us, "fused_fig8"))

    for name, us, derived in rows:
        print(f"  {name}: {us:.0f} us/call ({derived})")
    return rows
